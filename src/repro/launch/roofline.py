"""Roofline-term derivation from a compiled dry-run artifact.

Terms (TPU v5e constants from ``mesh.HW``), all in seconds per step:

    t_compute    = dot_FLOPs_global    / (chips * peak_FLOP/s)
    t_memory     = HLO_bytes_global    / (chips * HBM_bw)
    t_collective = collective_bytes_gl / (chips * link_bw)      [prompt form]
    t_wire       = wire_bytes_per_dev  / link_bw                 [ring model]

The per-device SPMD module gives per-device numbers; global = x chips.
``MODEL_FLOPS`` is the useful-work floor: 6*N*D (train), 2*N*D (prefill),
2*N*B (decode); N = active params for MoE.  ``useful_ratio`` < 1 exposes
remat/recompute and redundant compute; ``mfu_bound`` is the MFU the step
would achieve at the modeled bound (perfect overlap: step time =
max(term)).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.registry import ShapeSpec
from repro.models.config import ArchConfig

from .hlo_analysis import HloAnalysis
from .mesh import HW

__all__ = ["model_flops", "roofline_terms"]


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    n = cfg.param_count(active_only=(cfg.family == "moe"))
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * b * s
    if shape.kind == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b  # decode: one token


def roofline_terms(
    cfg: ArchConfig,
    shape: ShapeSpec,
    analysis: HloAnalysis,
    chips: int,
) -> Dict:
    peak, hbm, ici = HW["peak_flops_bf16"], HW["hbm_bw"], HW["ici_bw"]
    flops_dev = analysis.dot_flops
    bytes_dev = analysis.bytes_accessed
    coll_dev = analysis.collective_bytes
    wire_dev = analysis.wire_bytes

    t_compute = flops_dev / peak                      # == global/(chips*peak)
    t_memory = bytes_dev / hbm
    t_collective = coll_dev / ici
    t_wire = wire_dev / ici

    terms = {
        "compute": t_compute,
        "memory": t_memory,
        "collective": t_collective,
    }
    bottleneck = max(terms, key=terms.get)
    t_bound = max(terms.values())

    mf = model_flops(cfg, shape)
    useful_ratio = mf / (flops_dev * chips) if flops_dev else 0.0
    mfu_bound = mf / (chips * peak * t_bound) if t_bound else 0.0

    return {
        "chips": chips,
        "per_device": {
            "dot_flops": flops_dev,
            "bytes_accessed": bytes_dev,
            "collective_bytes": coll_dev,
            "wire_bytes": wire_dev,
        },
        "global": {
            "dot_flops": flops_dev * chips,
            "bytes_accessed": bytes_dev * chips,
            "collective_bytes": coll_dev * chips,
        },
        "terms_s": {**terms, "wire": t_wire},
        "bottleneck": bottleneck,
        "t_bound_s": t_bound,
        "model_flops": mf,
        "useful_ratio": useful_ratio,
        "mfu_bound": mfu_bound,
        "hw": HW["name"],
    }
