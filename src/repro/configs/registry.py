"""Architecture + input-shape registry: the 40-cell (arch x shape) grid.

Shapes (assignment):
    train_4k     seq_len=4096   global_batch=256   (training step)
    prefill_32k  seq_len=32768  global_batch=32    (inference prefill)
    decode_32k   seq_len=32768  global_batch=128   (one-token decode, KV=32k)
    long_500k    seq_len=524288 global_batch=1     (long-context decode)

``long_500k`` requires sub-quadratic attention: it runs for the SSM/hybrid
archs (mamba2-780m, recurrentgemma-9b) and is **skipped** for the pure
full-attention archs — see DESIGN.md §5.  Every arch here has a decoder, so
no decode-shape skips.

``reduced_config`` provides the smoke-test scale-down of each family
(small widths/layers/experts/vocab) — the full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ArchConfig

__all__ = [
    "ARCH_IDS", "SHAPES", "get_config", "reduced_config", "all_cells",
    "cell_applicable",
]

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2p7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "yi-9b": "yi_9b",
    "qwen3-14b": "qwen3_14b",
    "llama3-8b": "llama3_8b",
    "mamba2-780m": "mamba2_780m",
    "internvl2-1b": "internvl2_1b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-large-v3": "whisper_large_v3",
}
ARCH_IDS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def cell_applicable(arch_id: str, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch x shape) cell."""
    cfg = get_config(arch_id)
    if shape_name == "long_500k" and not cfg.is_subquadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{arch_id} is full-attention (DESIGN.md §5)"
        )
    return True, ""


def all_cells() -> List[Tuple[str, str, bool, str]]:
    """Every (arch, shape) with applicability: 40 rows."""
    rows = []
    for a in ARCH_IDS:
        for s in SHAPES:
            ok, why = cell_applicable(a, s)
            rows.append((a, s, ok, why))
    return rows


def reduced_config(arch_id: str) -> ArchConfig:
    """Family-faithful miniature for CPU smoke tests."""
    cfg = get_config(arch_id)
    common = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        vocab=128,
        rope_theta=cfg.rope_theta,
        rope_enabled=cfg.rope_enabled,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        tie_embeddings=cfg.tie_embeddings,
    )
    if cfg.family == "ssm":
        return ArchConfig(
            **common, n_layers=2, d_model=32, ssm_state=8, ssm_expand=2,
            ssm_head_dim=8, ssm_conv=4, ssm_chunk=8,
        )
    if cfg.family == "hybrid":
        return ArchConfig(
            **common, n_layers=3, d_model=32, n_heads=4, n_kv=1, d_ff=64,
            head_dim=8, window=8, hybrid_period=3, lru_width=32, ssm_conv=4,
        )
    if cfg.family == "moe":
        return ArchConfig(
            **common, n_layers=2, d_model=32, n_heads=4, n_kv=cfg.n_kv and 2,
            d_ff=48, head_dim=8, n_experts=4, top_k=min(2, cfg.top_k),
            n_shared=min(1, cfg.n_shared),
        )
    if cfg.family == "encdec":
        return ArchConfig(
            **common, n_layers=2, n_enc_layers=2, d_model=32, n_heads=4,
            n_kv=4, d_ff=64, head_dim=8,
        )
    if cfg.family == "vlm":
        return ArchConfig(
            **common, n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=64,
            head_dim=8, n_patches=4,
        )
    return ArchConfig(
        **common, n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=64, head_dim=8,
    )
