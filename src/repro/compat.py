"""Compatibility layer for jax APIs that moved between releases.

The distribution layer is written against the current jax spelling
(``jax.sharding.AxisType``, ``jax.shard_map(..., axis_names=, check_vma=)``,
``AbstractMesh(axis_sizes, axis_names)``).  Older releases (e.g. the 0.4.x
line pinned in CPU CI containers) spell these ``jax.experimental.shard_map``
with ``check_rep=``/``auto=``, have no ``AxisType``, and take
``AbstractMesh(((name, size), ...))``.  Every mesh/shard_map construction in
this repo goes through the helpers below so both lines work.
"""
from __future__ import annotations

import functools

import jax

__all__ = ["AxisType", "make_mesh", "abstract_mesh", "shard_map"]


try:
    from jax.sharding import AxisType  # jax >= 0.5-era API

    _HAS_AXIS_TYPE = True
except ImportError:
    import enum

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    _HAS_AXIS_TYPE = False


def make_mesh(axis_shapes, axis_names, axis_types=None):
    """``jax.make_mesh`` accepting (and dropping, pre-AxisType) axis_types."""
    if _HAS_AXIS_TYPE:
        kwargs = {} if axis_types is None else {"axis_types": axis_types}
        return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def abstract_mesh(axis_sizes, axis_names):
    """``AbstractMesh`` from parallel sizes/names tuples on any jax line."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        # older signature: one tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


if hasattr(jax, "shard_map"):

    def shard_map(f=None, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        if f is None:
            return functools.partial(jax.shard_map, **kwargs)
        return jax.shard_map(f, **kwargs)

else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f=None, *, mesh, in_specs, out_specs, axis_names=None,
                  check_vma=True):
        # axis_names lists the *manual* axes; the old API takes the
        # complement as ``auto``
        auto = (frozenset(mesh.axis_names) - frozenset(axis_names)
                if axis_names is not None else frozenset())
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)
        if f is None:
            return lambda fn: _shard_map(fn, **kwargs)
        return _shard_map(f, **kwargs)
