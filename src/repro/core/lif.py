"""Leaky integrate-and-fire neuron dynamics (paper §IV-B, eq. (3)).

Hardware convention (paper §III-C.2 / Algorithm 2): each iteration the PE
loads the membrane potential, applies the decay factor alpha, accumulates
the gated partial products, fires if the potential exceeds the threshold and
*soft-resets by subtracting theta at fire time* before writing the state
back.  (Eq. (3) subtracts theta*S_{t-1} after the decay instead; the two
conventions differ only by an alpha scaling of theta, which is absorbed by
the per-neuron trainable theta.)

alpha, theta and U_th are trainable per neuron (paper: "treated as trainable
parameters for each neuron").  alpha is parameterized through a sigmoid to
stay in (0, 1); theta and U_th are stored raw.

The spike nonlinearity is a Heaviside step with a fast-sigmoid surrogate
gradient for BPTT training (straight-through style), the standard approach
for SNN backprop.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "LIFParams",
    "init_lif_params",
    "spike",
    "lif_step",
    "lif_unroll",
]

SURROGATE_SLOPE = 4.0  # k in 1 / (1 + k|u|)^2


@jax.custom_vjp
def spike(v_minus_th: jax.Array) -> jax.Array:
    """Heaviside spike with fast-sigmoid surrogate gradient."""
    return (v_minus_th > 0).astype(v_minus_th.dtype)


def _spike_fwd(u):
    return spike(u), u


def _spike_bwd(u, g):
    # d/du fast_sigmoid(u) = 1 / (1 + k*|u|)^2
    surrogate = 1.0 / (1.0 + SURROGATE_SLOPE * jnp.abs(u)) ** 2
    return (g * surrogate,)


spike.defvjp(_spike_fwd, _spike_bwd)


@dataclasses.dataclass
class LIFParams:
    """Per-neuron trainable LIF parameters (pytree)."""

    alpha_logit: jax.Array  # sigmoid(alpha_logit) = decay in (0, 1)
    theta: jax.Array        # soft-reset amount
    v_th: jax.Array         # firing threshold

    @property
    def alpha(self) -> jax.Array:
        return jax.nn.sigmoid(self.alpha_logit)


jax.tree_util.register_pytree_node(
    LIFParams,
    lambda p: ((p.alpha_logit, p.theta, p.v_th), None),
    lambda _, c: LIFParams(*c),
)


def init_lif_params(
    shape: Tuple[int, ...],
    alpha: float = 0.9,
    theta: float = 1.0,
    v_th: float = 1.0,
    dtype=jnp.float32,
) -> LIFParams:
    alpha = float(jnp.clip(alpha, 1e-4, 1 - 1e-4))
    logit = float(jnp.log(alpha / (1.0 - alpha)))
    return LIFParams(
        alpha_logit=jnp.full(shape, logit, dtype=dtype),
        theta=jnp.full(shape, theta, dtype=dtype),
        v_th=jnp.full(shape, v_th, dtype=dtype),
    )


def lif_step(
    v: jax.Array, current: jax.Array, params: LIFParams
) -> Tuple[jax.Array, jax.Array]:
    """One LIF update (hardware write-back convention).

    v_dec   = alpha * v
    v_acc   = v_dec + current
    s       = H(v_acc - v_th)
    v_next  = v_acc - theta * s        (soft reset at fire time)

    Returns (v_next, s).  Broadcasting: params may be per-neuron, per-channel
    (broadcast over trailing dims) or scalar.
    """
    v_acc = params.alpha * v + current
    s = spike(v_acc - params.v_th)
    v_next = v_acc - params.theta * s
    return v_next, s


def lif_unroll(
    currents: jax.Array, params: LIFParams, v0: jax.Array | None = None
) -> Tuple[jax.Array, jax.Array]:
    """Run LIF over a leading time axis: currents (T, ...) -> spikes (T, ...).

    Returns (spikes, final_v).  Uses lax.scan (sequential in T, vectorized in
    the neuron dims) — the reference dynamics for training and for the fused
    Pallas kernel oracle.
    """
    if v0 is None:
        v0 = jnp.zeros(currents.shape[1:], dtype=currents.dtype)

    def step(v, c):
        v_next, s = lif_step(v, c, params)
        return v_next, s

    final_v, spikes = jax.lax.scan(step, v0, currents)
    return spikes, final_v
