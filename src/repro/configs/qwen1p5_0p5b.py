"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B (verified: hf).

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936; QKV bias.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, d_ff=2816,
    vocab=151936, head_dim=64,
    qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
    notes="QKV bias; tied embeddings (0.5B tier ties in HF config)",
)
