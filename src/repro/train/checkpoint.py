"""Fault-tolerant checkpointing: atomic, asynchronous, keep-N, elastic.

Design points for 1000+-node deployments, realized in-process here:

* **Atomicity** — write to ``<dir>/.tmp.<step>`` then ``os.rename`` (atomic
  on POSIX): a job killed mid-save can never leave a half-written
  checkpoint that a restart would load.
* **Async** — saves run on a background thread from a host copy of the
  arrays so the training loop never blocks on disk I/O; ``wait()`` drains
  before exit.
* **Keep-N GC** — bounded disk usage under frequent checkpoints.
* **Manifest** — ``manifest.json`` records step, leaf paths/shapes/dtypes
  and the mesh shape at save time; restore validates structure before
  touching the training state (fail-fast on config drift).
* **Elastic restore** — arrays are stored unsharded; ``restore`` rebuilds
  the pytree and the caller ``device_put``s with the *new* mesh's shardings,
  so save-on-mesh-A / resume-on-mesh-B (elastic scale up/down) works by
  construction.  Tested in tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Tuple[List[Tuple[str, np.ndarray]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    named = [(f"leaf_{i:05d}", np.asarray(l)) for i, l in enumerate(leaves)]
    return named, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot ``tree`` at ``step``.  Returns immediately if async."""
        self.wait()  # at most one save in flight
        named, _ = _flatten(tree)
        # host copy taken synchronously: the training loop may donate/mutate
        arrays = {k: np.array(v, copy=True) for k, v in named}
        manifest = {
            "step": int(step),
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in arrays.items()},
            "extra": extra or {},
        }
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, arrays, manifest), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, arrays, manifest)

    def _write(self, step: int, arrays: Dict[str, np.ndarray], manifest: Dict) -> None:
        try:
            tmp = os.path.join(self.directory, f".tmp.step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(f"async checkpoint save failed: {err!r}") from err

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> Dict:
        """Load a checkpoint's manifest without touching its arrays.

        Cheap metadata access for lifecycle tooling (e.g. the model
        registry's publish bridge records the source step and any
        ``extra`` fields the trainer stamped at save time).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        path = os.path.join(self.directory, f"step_{step}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, Dict]:
        """Rebuild a pytree shaped like ``like`` from checkpoint ``step``.

        Validates leaf count/shapes/dtypes against the manifest first.
        Returns (tree, manifest).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))

        leaves, treedef = jax.tree_util.tree_flatten(like)
        keys = sorted(data.files)
        if len(keys) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(keys)} leaves, expected {len(leaves)} "
                f"(model/optimizer structure changed?)"
            )
        restored = []
        for key, leaf in zip(keys, leaves):
            arr = data[key]
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(f"leaf {key}: shape {arr.shape} != expected {np.shape(leaf)}")
            restored.append(arr)
        return jax.tree_util.tree_unflatten(treedef, restored), manifest
