"""Fixed-point tier benchmark: float-vs-fixed accuracy, parity, throughput.

Three questions the hardware-parity tier makes answerable:

* **fidelity** — what does quantizing to Qm.n integer inference *cost in
  accuracy*, per channel scenario and SNR?  The float reference (``goap``)
  and the integer ``fixed`` backend sweep the same seeded cells, so each
  per-SNR delta isolates the quantization error from the channel draw.
* **parity** — do the backend's integer logits match the pure-NumPy golden
  datapath interpreter bit for bit, at both 8 and 16 bits?  A mismatch is
  a datapath bug, not a tolerance issue — the bench exits nonzero.
* **throughput** — what does integer inference cost (or save) next to the
  float backends on this host, same batch shape, steady state?

Run:  PYTHONPATH=src python benchmarks/fixed_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.data.pipeline import sigma_delta_encode_batch
from repro.data.radioml import generate_batch
from repro.eval import RobustnessConfig, evaluate_robustness
from repro.fixed import (FixedQuantFn, build_golden, fixed_encode_batch)
from repro.models.graph import compile_snn
from repro.plan import compile_plan
from repro.train.lsq import init_lsq_scales
from repro.train.pruning import make_mask_pytree

NAME = "fixed_bench"

SCENARIOS = ("static_awgn", "urban_fading")
FLOAT_BACKENDS = ("dense", "goap")
DENSITY = 0.5
BITS = 16                       # the paper datapath width (accuracy sweep)


def _time_fn(fn, x, reps: int) -> float:
    jax.block_until_ready(fn(x))  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(x))
    return (time.perf_counter() - t0) / reps


def _golden_parity(params, masks, scales, n_frames: int) -> dict:
    """Bit-exactness of the jitted fixed backend vs the NumPy golden."""
    program = compile_snn(CFG)
    iq, _, _ = generate_batch(3, n_frames, snr_db=10.0,
                              frame_len=CFG.input_width)
    out = {}
    for bits in (8, 16):
        plan = compile_plan(program, params, masks=masks,
                            quant_fn=FixedQuantFn(scales, bits=bits),
                            assignment="fixed")
        step = jax.jit(lambda x, p=plan: p.bound.batch(
            fixed_encode_batch(x, CFG.timesteps)))
        got = np.asarray(step(jnp.asarray(iq, jnp.float32)))
        golden = build_golden(CFG, params, masks=masks,
                              quant_fn=FixedQuantFn(scales, bits=bits))
        want = np.stack([golden.forward_iq(f) for f in iq])
        out[f"q{bits}"] = {
            "n_frames": n_frames,
            "bit_exact": bool(np.array_equal(got, want)),
            "max_abs_int_diff": int(np.abs(
                got.astype(np.int64) - want.astype(np.int64)).max()),
        }
    return out


def run(smoke: bool = False) -> dict:
    frames_per_cell = 16 if smoke else 48
    snr_grid = (0.0, 10.0) if smoke else (-10.0, 0.0, 10.0, 18.0)
    thr_batch = 16 if smoke else 64
    reps = 2 if smoke else 3
    parity_frames = 2 if smoke else 8

    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    scales = init_lsq_scales(params, BITS)

    # -- golden parity gate (both widths) ---------------------------------
    parity = _golden_parity(params, masks, scales, parity_frames)

    # -- accuracy: float reference sweep, then the quantized sweep --------
    # Both sweeps draw identical frames per cell (seeded by scenario+SNR),
    # so per-SNR accuracy deltas isolate the quantization error.  The
    # quantized sweep's ``dense`` leg serves fake-quantized float weights —
    # the dequantized reference — so its |dlogit| vs ``fixed`` measures the
    # genuine float-vs-fixed divergence on the shared logit scale.
    eval_cfg = RobustnessConfig(
        suite="quick", snr_grid=snr_grid, frames_per_cell=frames_per_cell,
        backends=("goap",), seed=0, include_clean=False)
    float_rep = evaluate_robustness(params, CFG, eval_cfg, masks=masks,
                                    scenarios=SCENARIOS)
    fixed_cfg = RobustnessConfig(
        suite="quick", snr_grid=snr_grid, frames_per_cell=frames_per_cell,
        backends=("dense", "fixed"), seed=0, include_clean=False,
        agreement_atol=float("inf"))
    fixed_rep = evaluate_robustness(
        params, CFG, fixed_cfg, masks=masks,
        quant_fn=FixedQuantFn(scales, bits=BITS), scenarios=SCENARIOS)

    def _acc(rep, scen, snr, backend):
        return rep["scenarios"][scen]["per_snr"][f"{snr:+.1f}"][
            "accuracy"][backend]

    accuracy = {}
    for scen in SCENARIOS:
        per_snr = {}
        for snr in snr_grid:
            f32 = _acc(float_rep, scen, snr, "goap")
            fq = _acc(fixed_rep, scen, snr, "dense")
            fx = _acc(fixed_rep, scen, snr, "fixed")
            per_snr[f"{snr:+.1f}"] = {
                "float": f32, "fakequant": fq, "fixed": fx,
                "delta_fixed_vs_float": round(fx - f32, 4),
            }
        deltas = [c["delta_fixed_vs_float"] for c in per_snr.values()]
        accuracy[scen] = {"per_snr": per_snr,
                          "mean_delta": float(np.mean(deltas)),
                          "worst_delta": float(np.min(deltas))}

    # -- throughput: integer step vs the float backends -------------------
    program = compile_snn(CFG)
    iq, _, _ = generate_batch(1, thr_batch, snr_db=10.0,
                              frame_len=CFG.input_width)
    x = jnp.asarray(iq, jnp.float32)
    throughput = {}
    for backend in FLOAT_BACKENDS:
        plan = compile_plan(program, params, masks=masks, assignment=backend)
        fn = jax.jit(lambda b, p=plan: p.bound.batch(
            sigma_delta_encode_batch(b, CFG.timesteps)))
        throughput[backend] = {"fps": thr_batch / _time_fn(fn, x, reps)}
    plan = compile_plan(program, params, masks=masks,
                        quant_fn=FixedQuantFn(scales, bits=BITS),
                        assignment="fixed")
    fn = jax.jit(lambda b, p=plan: p.bound.batch(
        fixed_encode_batch(b, CFG.timesteps)))
    throughput["fixed"] = {"fps": thr_batch / _time_fn(fn, x, reps)}

    return {
        "jax_backend": jax.default_backend(),
        "smoke": smoke,
        "density": DENSITY,
        "quant_bits": BITS,
        "frames_per_cell": frames_per_cell,
        "snr_grid": list(snr_grid),
        "scenarios": list(SCENARIOS),
        "golden_parity": parity,
        "accuracy": accuracy,
        "max_abs_logit_diff_fakequant_vs_fixed":
            fixed_rep["agreement"]["max_abs_logit_diff"],
        "throughput_batch": thr_batch,
        "throughput": throughput,
        "eval_wall_s": {"float": float_rep["wall_s_by_backend"],
                        "fixed": fixed_rep["wall_s_by_backend"]},
    }


def format_table(res: dict) -> str:
    lines = [
        f"Fixed-point tier bench ({res['jax_backend']} backend, "
        f"Q{res['quant_bits']}, {res['frames_per_cell']} frames/cell)",
    ]
    for bits, p in res["golden_parity"].items():
        status = "BIT-EXACT" if p["bit_exact"] else \
            f"MISMATCH (max |dint|={p['max_abs_int_diff']})"
        lines.append(f"  golden parity {bits:<4s} "
                     f"({p['n_frames']} frames): {status}")
    lines.append(f"  fake-quant float vs fixed: max |dlogit| = "
                 f"{res['max_abs_logit_diff_fakequant_vs_fixed']:.3g} "
                 "(dequantized scale)")
    lines.append("  scenario        SNR     acc(float)  acc(fixed)   delta")
    for scen, rec in res["accuracy"].items():
        for snr, cell in rec["per_snr"].items():
            lines.append(f"  {scen:<15s}{snr:>5s}dB"
                         f"{cell['float']:>12.3f}{cell['fixed']:>12.3f}"
                         f"{cell['delta_fixed_vs_float']:>+9.3f}")
        lines.append(f"  {scen:<15s} mean delta "
                     f"{rec['mean_delta']:+.4f}  worst "
                     f"{rec['worst_delta']:+.4f}")
    fps = {b: t["fps"] for b, t in res["throughput"].items()}
    lines.append("  throughput: " + "  ".join(
        f"{b}={v:.0f} fps" for b, v in fps.items()))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced cells/reps for CI smoke runs")
    ap.add_argument("--out", default="BENCH_fixed.json")
    args = ap.parse_args(argv)

    res = run(smoke=args.smoke)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    if not all(p["bit_exact"] for p in res["golden_parity"].values()):
        print("FAIL: fixed backend diverges from the golden datapath")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
