"""Warmup-race backend autotuner for the serving tier.

The layer-graph API makes every execution dataflow interchangeable
(``dense`` / ``goap`` / ``pallas`` / ``stream`` produce identical logits),
but their *cost* is wildly platform-dependent: the COO gather dataflow that
wins on the paper's accelerator loses to the im2col matmul oracle on a
wide-SIMD CPU, and the Pallas block-sparse kernel only pays off on a real
TPU (CPU interpret mode executes the kernel body in Python).

So the engine does what the hardware cannot: at bind time it **races** the
candidate backends on the exact batch shape it is about to serve — compile,
warm up, time a few repetitions — and pins the winner for the lifetime of
the binding.  A candidate that raises (missing TPU, unsupported layout,
bind-under-trace error) is recorded and excluded; if every candidate fails
the tuner falls back to ``goap``, the paper's reference dataflow, which
binds from plain numpy artifacts on any host.

Two granularities:

* :func:`autotune_backend` — one winner for the whole network (the
  classic mode);
* :func:`autotune_per_layer` — each conv/FC layer raced independently on
  its own input shape (the plan compiler's cost-model priors are logged
  alongside the measurements), producing a heterogeneous
  ``{layer: backend}`` assignment that :func:`repro.plan.compile_plan`
  turns into a fused streaming plan
  (``AsyncAMCServeEngine(backend="per-layer")``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "AutotuneReport",
    "PerLayerAutotuneReport",
    "default_candidates",
    "autotune_backend",
    "autotune_per_layer",
]

# Interpret-mode Pallas is orders of magnitude off the pace and only slows
# the race down; only let it compete where a real TPU will run it.
_CPU_CANDIDATES = ("dense", "goap")
_TPU_CANDIDATES = ("dense", "goap", "pallas", "pallas_fused")

# Backends whose fast path is the whole-network fused kernel, not the
# layer-by-layer bound program: raced through a compiled plan's
# ``preferred_batch`` so the stopwatch times what would actually serve.
_FUSED_BACKENDS = ("pallas_fused",)


@dataclasses.dataclass(frozen=True)
class _FusedBinding:
    """Minimal bound-program stand-in for fused-kernel candidates (the
    engine's ``make_fn`` only touches ``.batch`` and ``.backend``)."""

    backend: str
    batch: Callable


def default_candidates(quantized: bool = False) -> Tuple[str, ...]:
    """Backends worth racing on this host.

    ``quantized=True`` (the engine passes it when LSQ state is present)
    additionally races the integer ``fixed`` backend: quantized serving is
    exactly when integer inference is a like-for-like candidate.
    """
    base = _TPU_CANDIDATES if jax.default_backend() == "tpu" else _CPU_CANDIDATES
    return base + ("fixed",) if quantized else base


@dataclasses.dataclass(frozen=True)
class AutotuneReport:
    """Outcome of one warmup race (kept on the engine for introspection)."""

    choice: str
    timings_ms: Dict[str, float]      # successful candidates -> mean wall ms
    errors: Dict[str, str]            # failed candidates -> error summary
    batch_shape: Tuple[int, ...]
    fell_back: bool = False           # True when every candidate raised

    def summary(self) -> dict:
        return {
            "choice": self.choice,
            "timings_ms": dict(self.timings_ms),
            "errors": dict(self.errors),
            "batch_shape": list(self.batch_shape),
            "fell_back": self.fell_back,
        }


def autotune_backend(
    program,
    params,
    batch_shape: Sequence[int],
    *,
    masks=None,
    quant_fn=None,
    candidates: Optional[Sequence[str]] = None,
    reps: int = 2,
    budget_s: float = 5.0,
    fallback: str = "goap",
    make_fn: Optional[Callable] = None,
) -> AutotuneReport:
    """Race ``candidates`` on ``batch_shape`` and pin the fastest.

    ``make_fn(bound)`` builds the callable to time from a
    :class:`~repro.models.graph.BoundProgram` — the engine passes its full
    fused step (encode + forward + shard_map) so the race measures what
    will actually serve; default is the jitted ``bound.batch``.

    Candidates are always scored on post-warmup (steady-state) runs so a
    slow-to-compile but fast-to-run backend is never penalized for its
    compile time; a candidate whose warmup already exceeded ``budget_s``
    gets a single timed rep instead of ``reps`` (bounds how long a
    genuinely slow candidate can stall engine start-up).
    """
    candidates = tuple(candidates) if candidates is not None else default_candidates()
    timings: Dict[str, float] = {}
    errors: Dict[str, str] = {}
    probe = jnp.zeros(tuple(batch_shape), jnp.float32)
    for name in candidates:
        try:
            if hasattr(quant_fn, "reset"):
                # a candidate that raised mid-bind must not skew the next
                # candidate's layer-order fake-quant index
                quant_fn.reset()
            if name in _FUSED_BACKENDS:
                from repro.plan import compile_plan

                plan = compile_plan(program, params, masks=masks,
                                    quant_fn=quant_fn, assignment=name)
                bound = _FusedBinding(backend=name,
                                      batch=plan.preferred_batch())
            else:
                bound = program._bind(params, name, masks=masks,
                                      quant_fn=quant_fn)
            fn = jax.jit(bound.batch) if make_fn is None else make_fn(bound)
            timings[name] = _time_steady_state(fn, probe, reps, budget_s)
        except Exception as e:  # noqa: BLE001 — any failure disqualifies
            errors[name] = f"{type(e).__name__}: {e}"
    if timings:
        choice, fell_back = min(timings, key=timings.get), False
    else:
        choice, fell_back = fallback, True
    return AutotuneReport(choice=choice, timings_ms=timings, errors=errors,
                          batch_shape=tuple(batch_shape), fell_back=fell_back)


def _time_steady_state(fn, probe, reps: int, budget_s: float) -> float:
    """Mean post-warmup wall ms of ``fn(probe)`` (shared race stopwatch)."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(probe))           # compile + warm up
    warm = time.perf_counter() - t0
    n_reps = 1 if warm > budget_s else max(1, reps)
    t0 = time.perf_counter()
    for _ in range(n_reps):
        jax.block_until_ready(fn(probe))
    return (time.perf_counter() - t0) / n_reps * 1e3


# ---------------------------------------------------------------------------
# Per-layer mode: one race per weighted layer, priors from the plan compiler.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PerLayerAutotuneReport:
    """Outcome of a layer-by-layer backend race."""

    assignment: Dict[str, str]                 # layer name -> winning backend
    timings_ms: Dict[str, Dict[str, float]]    # layer -> candidate -> mean ms
    priors: Dict[str, Dict[str, float]]        # layer -> candidate -> predicted cost
    errors: Dict[str, Dict[str, str]]          # layer -> candidate -> error
    batch: int
    fell_back: Tuple[str, ...] = ()            # layers decided by prior/fallback

    def summary(self) -> dict:
        return {
            "assignment": dict(self.assignment),
            "timings_ms": {k: dict(v) for k, v in self.timings_ms.items()},
            "priors": {k: dict(v) for k, v in self.priors.items()},
            "errors": {k: dict(v) for k, v in self.errors.items()},
            "batch": self.batch,
            "fell_back": list(self.fell_back),
        }


def _layer_probe_shapes(program, batch: int):
    """(spec, probe shape) for every weighted layer, tracking pooling."""
    cfg = program.cfg
    width = cfg.input_width
    shapes = []
    for spec in program.layers:
        if spec.kind == "conv_lif":
            shapes.append((spec, (batch, cfg.timesteps, spec.ic, width)))
        elif spec.kind == "maxpool":
            width //= spec.pool
        elif spec.kind == "fc_lif":
            shapes.append((spec, (batch, cfg.timesteps, spec.din)))
    return shapes


def autotune_per_layer(
    program,
    params,
    batch: int,
    *,
    masks=None,
    quant_fn=None,
    candidates: Optional[Sequence[str]] = None,
    reps: int = 2,
    budget_s: float = 5.0,
    fallback: str = "goap",
    cache=None,
) -> PerLayerAutotuneReport:
    """Race candidate backends **layer by layer** on each layer's own
    input shape, producing a heterogeneous assignment map.

    Every surviving candidate is fully timed and the measured minimum
    wins; the plan compiler's cost-model predictions are advisory —
    they set the race order (cheapest-predicted compiles first) and are
    recorded per layer in the report for offline comparison against the
    measurements.  A layer whose every candidate raises falls back to
    ``fallback``, which the prior plan has already bound successfully on
    this host.  Each candidate's cells come from one cached
    ``compile_plan``, so the race never re-derives COO/schedule/
    block-sparse artifacts the artifact cache already holds.  Feed the
    returned ``assignment`` to :func:`repro.plan.compile_plan`.
    """
    from repro.models.graph import BoundProgram
    from repro.plan import compile_plan

    candidates = tuple(candidates) if candidates is not None else default_candidates()
    cache_kw = {"cache": cache} if cache is not None else {}
    # prior plan: derives each layer's artifacts once (shared with every
    # candidate plan through the artifact cache) and yields cost priors
    prior_plan = compile_plan(program, params, masks=masks, quant_fn=quant_fn,
                              assignment=fallback, **cache_kw)
    priors_all = prior_plan.cost_priors()

    # one (cached) whole-network plan per candidate; its per-layer cells
    # are raced in isolation below.  A candidate whose plan fails to
    # compile is excluded everywhere.
    candidate_plans = {fallback: prior_plan}
    candidate_errors: Dict[str, str] = {}

    def plan_for(cand: str):
        if cand in candidate_errors:
            return None
        if cand not in candidate_plans:
            try:
                candidate_plans[cand] = compile_plan(
                    program, params, masks=masks, quant_fn=quant_fn,
                    assignment=cand, **cache_kw)
            except Exception as e:  # noqa: BLE001 — exclude the candidate
                candidate_errors[cand] = f"{type(e).__name__}: {e}"
                return None
        return candidate_plans[cand]

    rng = np.random.default_rng(0)
    assignment: Dict[str, str] = {}
    timings: Dict[str, Dict[str, float]] = {}
    errors: Dict[str, Dict[str, str]] = {}
    priors: Dict[str, Dict[str, float]] = {}
    fell_back = []
    for spec, shape in _layer_probe_shapes(program, batch):
        prior = priors_all.get(spec.name, {})
        priors[spec.name] = {k: v for k, v in prior.items() if k in candidates}
        order = sorted(candidates, key=lambda c: prior.get(c, float("inf")))
        probe = jnp.asarray((rng.random(shape) < 0.5).astype(np.float32))
        lt: Dict[str, float] = {}
        le: Dict[str, str] = {}
        for cand in order:
            plan_c = plan_for(cand)
            if plan_c is None:
                le[cand] = candidate_errors[cand]
                continue
            cell = next(lp.cell for lp in plan_c.layers
                        if lp.spec.name == spec.name)
            bound = BoundProgram(backend=cand, stages=((spec, cell),))
            try:
                lt[cand] = _time_steady_state(jax.jit(bound.batch), probe,
                                              reps, budget_s)
            except Exception as e:  # noqa: BLE001 — exclude the candidate
                le[cand] = f"{type(e).__name__}: {e}"
        timings[spec.name], errors[spec.name] = lt, le
        if lt:
            assignment[spec.name] = min(lt, key=lt.get)
        else:
            # every candidate raised for this layer: use the fallback
            # backend, which the prior plan above already bound successfully
            # (a failed candidate must never land in the assignment — the
            # engine would re-hit the same error at compile_plan time)
            assignment[spec.name] = fallback
            fell_back.append(spec.name)
    return PerLayerAutotuneReport(
        assignment=assignment, timings_ms=timings, priors=priors,
        errors=errors, batch=batch, fell_back=tuple(fell_back))
