"""Declarative SNN layer graph with pluggable execution backends.

The paper's core claim is that one fixed SNN can be executed through very
different dataflows — dense sliding-window baseline vs. the sparsity-aware
GOAP/SAOCDS streaming pipeline — with identical numerics but very different
cost (paper §III, Tables I/III).  This module makes that claim structural:

* ``build_layer_graph(cfg)`` derives a tuple of :class:`LayerSpec` nodes
  (``Conv1dLIF`` / ``MaxPool`` / ``FCLIF`` / ``Readout``) from an
  :class:`~repro.models.snn.SNNConfig` — the *model definition*;
* :class:`SNNProgram` compiles the graph once and ``apply(params, frames,
  backend=...)`` dispatches per-layer to registered backends — the
  *execution strategy*;
* backends register via :func:`register_backend(name, layer_kind, fn)` so
  future execution strategies (sharded, batched-async, quantized) plug in
  without touching the model.

Built-in backends:

========  ==================================================================
name      per-layer implementation
========  ==================================================================
dense     im2col matmul oracle (differentiable; supports masks + LSQ quant)
goap      COO weight-priority iteration (vectorized Algorithm-1 gather)
pallas    static block-sparse TPU kernel (CPU ``interpret=True`` fallback)
stream    faithful Algorithm-2 schedule interpreter; also returns the
          compute/extra/empty iteration counters of paper Tables I/III
========  ==================================================================

``dense`` binds with pure-jax ops and may be traced (jit/grad/vmap over
params).  ``goap``/``pallas``/``stream`` precompute numpy artifacts (COO
kernels, static schedules, block-sparse tilings) at bind time and therefore
need **concrete** weights — bind outside jit, then jit the bound program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.goap import conv1d_dense_oracle, goap_conv_nnz
from repro.core.lif import lif_step
from repro.core.saocds import max_pool_spikes, pad_same, schedule_interpreter
from repro.core.sparse_format import (
    CooKernel,
    block_sparse_from_dense,
    build_schedule,
    coo_from_dense,
)
from repro.models.snn import SNNConfig

__all__ = [
    "LayerSpec",
    "Conv1dLIF",
    "MaxPool",
    "FCLIF",
    "Readout",
    "build_layer_graph",
    "register_backend",
    "available_backends",
    "get_backend",
    "SNNProgram",
    "BoundProgram",
    "compile_snn",
    "stream_totals",
]

# Layer kinds understood by the executor.
KIND_CONV = "conv_lif"
KIND_POOL = "maxpool"
KIND_FC = "fc_lif"
KIND_READOUT = "readout"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One node of the layer graph (pure metadata, no parameters)."""

    kind: str
    name: str
    index: int = 0        # position within its param group (conv i / fc i)
    # conv_lif
    kw: int = 0
    ic: int = 0
    oc: int = 0
    # maxpool
    pool: int = 0
    # fc_lif
    din: int = 0
    dout: int = 0
    # readout
    mode: str = ""


def Conv1dLIF(index: int, kw: int, ic: int, oc: int, name: str = "") -> LayerSpec:
    return LayerSpec(kind=KIND_CONV, name=name or f"conv{index + 1}",
                     index=index, kw=kw, ic=ic, oc=oc)


def MaxPool(pool: int, name: str = "") -> LayerSpec:
    return LayerSpec(kind=KIND_POOL, name=name or "pool", pool=pool)


def FCLIF(index: int, din: int, dout: int, name: str = "") -> LayerSpec:
    return LayerSpec(kind=KIND_FC, name=name or f"fc{index + 1}",
                     index=index, din=din, dout=dout)


def Readout(mode: str) -> LayerSpec:
    return LayerSpec(kind=KIND_READOUT, name="readout", mode=mode)


def build_layer_graph(cfg: SNNConfig) -> Tuple[LayerSpec, ...]:
    """Derive the declarative layer graph from an ``SNNConfig``."""
    cfg.validate()
    layers: List[LayerSpec] = []
    for i, (kw, ic, oc) in enumerate(cfg.conv_specs):
        layers.append(Conv1dLIF(i, kw, ic, oc))
        layers.append(MaxPool(cfg.pool, name=f"pool{i + 1}"))
    for i, (din, dout) in enumerate(cfg.fc_specs):
        layers.append(FCLIF(i, din, dout))
    layers.append(Readout(cfg.readout))
    return tuple(layers)


# ---------------------------------------------------------------------------
# Backend registry.
# ---------------------------------------------------------------------------

# A backend factory takes (spec, layer_params, cfg=, mask=, quant_fn=) and
# returns the bound stage callable for that layer.  Stage contracts:
#   conv_lif: stage(x (T, IC, W))  -> (spikes (T, OC, W), aux dict | None)
#   maxpool:  stage(x)             -> pooled x
#   fc_lif:   stage(x (T, ...))    -> (spikes (T, OUT), currents (T, OUT))
#   readout:  stage((spikes, currents)) -> logits
BackendFactory = Callable[..., Callable]

# Backends shared by every execution strategy (pooling and readout carry no
# weights, so there is nothing dataflow-specific about them) register under
# this pseudo-name; named backends may still override per layer kind.
COMMON = "common"

_REGISTRY: Dict[Tuple[str, str], BackendFactory] = {}


def register_backend(name: str, layer_kind: str, fn: BackendFactory) -> BackendFactory:
    """Register ``fn`` as backend ``name``'s implementation of ``layer_kind``."""
    _REGISTRY[(name, layer_kind)] = fn
    return fn


def available_backends() -> Tuple[str, ...]:
    """Names of all registered (non-common) backends."""
    return tuple(sorted({n for n, _ in _REGISTRY if n != COMMON}))


def get_backend(name: str, layer_kind: str) -> BackendFactory:
    """Resolve ``(name, layer_kind)``, falling back to the common pool."""
    if name not in {n for n, _ in _REGISTRY}:
        raise ValueError(
            f"unknown backend {name!r}; registered backends: "
            f"{list(available_backends())}"
        )
    fn = _REGISTRY.get((name, layer_kind)) or _REGISTRY.get((COMMON, layer_kind))
    if fn is None:
        raise ValueError(
            f"backend {name!r} has no implementation for layer kind "
            f"{layer_kind!r}"
        )
    return fn


# ---------------------------------------------------------------------------
# Bind-time helpers.
# ---------------------------------------------------------------------------

def _effective_weight(layer_params, mask, quant_fn):
    w = layer_params["w"]
    if mask is not None:
        w = w * mask
    if quant_fn is not None:
        w = quant_fn(w)
    return w


def _concrete_weight(spec: LayerSpec, layer_params, mask, quant_fn) -> np.ndarray:
    """Numpy weights for backends that precompute sparse artifacts."""
    try:
        return np.asarray(_effective_weight(layer_params, mask, quant_fn))
    except jax.errors.TracerArrayConversionError as e:
        raise ValueError(
            f"layer {spec.name!r}: this backend precomputes a sparse layout "
            "from concrete weights and cannot bind under jit/vmap/grad — "
            "bind the program outside the traced region (the 'dense' "
            "backend is fully traceable)"
        ) from e


def _layer_coo(spec: LayerSpec, layer_params, mask, quant_fn) -> CooKernel:
    # accept pre-sparsified params ({"coo": ...}) as produced by
    # ``sparsify_params`` as well as raw dense params ({"w": ...})
    if "coo" in layer_params:
        return layer_params["coo"]
    return coo_from_dense(_concrete_weight(spec, layer_params, mask, quant_fn))


# ---------------------------------------------------------------------------
# Common (backend-independent) stages.
# ---------------------------------------------------------------------------

def _common_maxpool(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    def stage(x):
        return max_pool_spikes(x, spec.pool)
    return stage


def _common_readout(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    def stage(fc_out):
        spikes, currents = fc_out
        if spec.mode == "current_sum":
            return currents.sum(axis=0)
        return spikes.sum(axis=0)
    return stage


register_backend(COMMON, KIND_POOL, _common_maxpool)
register_backend(COMMON, KIND_READOUT, _common_readout)


# ---------------------------------------------------------------------------
# dense backend — im2col oracle, differentiable (training path).
# ---------------------------------------------------------------------------

def _dense_conv(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    w = _effective_weight(layer_params, mask, quant_fn)
    lif = layer_params["lif"]

    def stage(x):
        padded = pad_same(x, spec.kw)

        def step(v, ifm):
            return lif_step(v, conv1d_dense_oracle(ifm, w), lif)

        v0 = jnp.zeros((spec.oc, x.shape[-1]), dtype=w.dtype)
        _, spikes = jax.lax.scan(step, v0, padded)
        return spikes, None

    return stage


def _dense_fc(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    w = _effective_weight(layer_params, mask, quant_fn)
    lif = layer_params["lif"]

    def stage(x):
        x = x.reshape(x.shape[0], -1)

        def step(v, s):
            cur = s.astype(w.dtype) @ w
            v_next, out = lif_step(v, cur, lif)
            return v_next, (out, cur)

        v0 = jnp.zeros((w.shape[1],), dtype=w.dtype)
        _, (spikes, currents) = jax.lax.scan(step, v0, x)
        return spikes, currents

    return stage


register_backend("dense", KIND_CONV, _dense_conv)
register_backend("dense", KIND_FC, _dense_fc)


# ---------------------------------------------------------------------------
# goap backend — COO weight-priority iteration (vectorized Algorithm 1).
# ---------------------------------------------------------------------------

def _goap_conv(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    coo = _layer_coo(spec, layer_params, mask, quant_fn)
    lif = layer_params["lif"]

    def stage(x):
        padded = pad_same(x, coo.kw)

        def step(v, ifm):
            return lif_step(v, goap_conv_nnz(ifm, coo), lif)

        v0 = jnp.zeros((coo.oc, x.shape[-1]), dtype=jnp.float32)
        _, spikes = jax.lax.scan(step, v0, padded)
        return spikes, None

    return stage


register_backend("goap", KIND_CONV, _goap_conv)
# FC layers use the weight-mask method (paper §III-B): zeros kept in the
# matrix *are* the mask, so the dense FC stage is numerically the WM stage.
register_backend("goap", KIND_FC, _dense_fc)


# ---------------------------------------------------------------------------
# pallas backend — static block-sparse TPU kernel (interpret=True on CPU).
# ---------------------------------------------------------------------------

PALLAS_BLOCK_OC = 8
PALLAS_BLOCK_K = 32


def _pallas_conv(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    # the Pallas path needs the dense layout to re-block; recover it from a
    # pre-sparsified COO kernel if that is all we were given
    if "coo" in layer_params:
        from repro.core.sparse_format import coo_to_dense
        w = coo_to_dense(layer_params["coo"]).astype(np.float32)
    else:
        w = _concrete_weight(spec, layer_params, mask, quant_fn)
    bs = block_sparse_from_dense(w, block_oc=PALLAS_BLOCK_OC, block_k=PALLAS_BLOCK_K)
    lif = layer_params["lif"]

    from repro.kernels.ops import goap_conv_op

    def stage(x):
        padded = pad_same(x, bs.kw)

        def step(v, ifm):
            return lif_step(v, goap_conv_op(ifm, bs), lif)

        v0 = jnp.zeros((bs.oc, x.shape[-1]), dtype=jnp.float32)
        _, spikes = jax.lax.scan(step, v0, padded)
        return spikes, None

    return stage


def _pallas_fc(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    w = jnp.asarray(_effective_weight(layer_params, mask, quant_fn))
    lif = layer_params["lif"]

    from repro.kernels.ops import lif_op, wm_fc_op

    def stage(x):
        x = x.reshape(x.shape[0], -1)
        # FC currents are memoryless in T: one batched WM matmul, then the
        # fused LIF kernel integrates over time.
        currents = wm_fc_op(x.astype(w.dtype), w)
        spikes, _ = lif_op(currents, lif)
        return spikes, currents

    return stage


register_backend("pallas", KIND_CONV, _pallas_conv)
register_backend("pallas", KIND_FC, _pallas_fc)


# ---------------------------------------------------------------------------
# stream backend — faithful Algorithm-2 emulator with Tables I/III counters.
# ---------------------------------------------------------------------------

def _stream_conv(spec: LayerSpec, layer_params, *, cfg, mask=None, quant_fn=None):
    coo = _layer_coo(spec, layer_params, mask, quant_fn)
    sched = build_schedule(coo)
    lif = layer_params["lif"]

    def stage(x):
        padded = pad_same(x, coo.kw)
        oi = x.shape[-1]
        spikes, _, counts = schedule_interpreter(padded, sched, lif, oi, coo.oc)
        return spikes, counts

    return stage


register_backend("stream", KIND_CONV, _stream_conv)
register_backend("stream", KIND_FC, _dense_fc)  # WM method, see goap above


def stream_totals(counters: Dict[str, Dict[str, Any]]) -> Dict[str, Any]:
    """Aggregate per-layer stream counters into whole-network totals."""
    totals = {"compute_iters": 0, "extra_iters": 0, "empty_iters": 0,
              "reps_per_timestep": 0, "accumulations": 0.0}
    for counts in counters.values():
        totals["compute_iters"] += counts["compute_iters"]
        totals["extra_iters"] += counts["extra_iters"]
        totals["empty_iters"] += counts["empty_iters"]
        totals["reps_per_timestep"] += counts["reps_per_timestep"]
        totals["accumulations"] = totals["accumulations"] + counts["accumulations"]
    return totals


# ---------------------------------------------------------------------------
# The compiled program.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BoundProgram:
    """A layer graph bound to parameters under one backend."""

    backend: str
    stages: Tuple[Tuple[LayerSpec, Callable], ...]

    def run(self, frames: jax.Array) -> Tuple[jax.Array, Dict[str, Dict]]:
        """(T, IC0, W) frames -> (logits, per-conv-layer counters)."""
        x = frames
        fc_out = None
        logits = None
        counters: Dict[str, Dict] = {}
        for spec, stage in self.stages:
            if spec.kind == KIND_CONV:
                x, aux = stage(x)
                if aux is not None:
                    counters[spec.name] = aux
            elif spec.kind == KIND_POOL:
                x = stage(x)
            elif spec.kind == KIND_FC:
                spikes, currents = stage(x)
                fc_out = (spikes, currents)
                x = spikes
            elif spec.kind == KIND_READOUT:
                logits = stage(fc_out)
            else:  # pragma: no cover - specs are built internally
                raise ValueError(f"unknown layer kind {spec.kind!r}")
        return (logits if logits is not None else x), counters

    def __call__(self, frames: jax.Array) -> jax.Array:
        return self.run(frames)[0]

    def batch(self, frames_b: jax.Array) -> jax.Array:
        """(B, T, IC0, W) -> (B, n_classes)."""
        return jax.vmap(lambda f: self.run(f)[0])(frames_b)


@dataclasses.dataclass(frozen=True)
class SNNProgram:
    """An ``SNNConfig`` compiled into an executable layer graph."""

    cfg: SNNConfig
    layers: Tuple[LayerSpec, ...]

    @classmethod
    def from_config(cls, cfg: SNNConfig) -> "SNNProgram":
        return cls(cfg=cfg, layers=build_layer_graph(cfg))

    # -- binding / execution ------------------------------------------------

    def bind(self, params, backend: str = "dense", *, masks=None,
             quant_fn=None, layers: Optional[Sequence[LayerSpec]] = None) -> BoundProgram:
        """Resolve every layer against ``backend`` and close over params."""
        stages = []
        for spec in (self.layers if layers is None else tuple(layers)):
            factory = get_backend(backend, spec.kind)
            lp, m = self._layer_params(spec, params, masks)
            stages.append((spec, factory(spec, lp, cfg=self.cfg, mask=m,
                                         quant_fn=quant_fn)))
        return BoundProgram(backend=backend, stages=tuple(stages))

    def apply(self, params, frames: jax.Array, backend: str = "dense", *,
              masks=None, quant_fn=None, return_counters: bool = False):
        """One sample (T, IC0, W) -> logits (n_classes,).

        With ``return_counters=True`` also returns the per-conv-layer
        iteration counters (populated by the ``stream`` backend: the
        compute/extra/empty reps and gated accumulation counts of paper
        Tables I/III; empty for the other backends).
        """
        bound = self.bind(params, backend, masks=masks, quant_fn=quant_fn)
        logits, counters = bound.run(frames)
        return (logits, counters) if return_counters else logits

    def apply_batch(self, params, frames_b: jax.Array, backend: str = "dense",
                    *, masks=None, quant_fn=None) -> jax.Array:
        """(B, T, IC0, W) -> (B, n_classes)."""
        return self.bind(params, backend, masks=masks,
                         quant_fn=quant_fn).batch(frames_b)

    def run_layers(self, layers: Sequence[LayerSpec], params, x: jax.Array,
                   backend: str = "dense", *, masks=None, quant_fn=None):
        """Execute a contiguous slice of the graph (pipeline stages)."""
        return self.bind(params, backend, masks=masks, quant_fn=quant_fn,
                         layers=layers).run(x)[0]

    # -- graph slicing (pipeline-parallel stage construction) ---------------

    def conv_block(self, i: int) -> Tuple[LayerSpec, ...]:
        """The (Conv1dLIF, MaxPool) pair for conv stage ``i``."""
        convs = [j for j, s in enumerate(self.layers) if s.kind == KIND_CONV]
        j = convs[i]
        return self.layers[j:j + 2]

    def head_layers(self) -> Tuple[LayerSpec, ...]:
        """Everything from the first FC layer through the readout."""
        first_fc = next(j for j, s in enumerate(self.layers) if s.kind == KIND_FC)
        return self.layers[first_fc:]

    # -- params plumbing ----------------------------------------------------

    @staticmethod
    def _layer_params(spec: LayerSpec, params, masks):
        if spec.kind == KIND_CONV:
            return params["conv"][spec.index], (
                masks["conv"][spec.index] if masks else None)
        if spec.kind == KIND_FC:
            return params["fc"][spec.index], (
                masks["fc"][spec.index] if masks else None)
        return None, None


@functools.lru_cache(maxsize=None)
def compile_snn(cfg: SNNConfig) -> SNNProgram:
    """Compile (and cache) the layer graph for ``cfg``."""
    return SNNProgram.from_config(cfg)
