"""Weighted A/B + canary traffic splitting across bound model versions.

The async engine's worker loop asks its router for a version label once
per micro-batch (batch granularity keeps the fixed-bucket shapes and the
zero-padding story intact — a batch is always served end-to-end by one
plan).  Routers are plain callables returning a label, so anything from a
hash ring to a bandit can be plugged in; the built-in
:class:`WeightedRouter` implements **smooth weighted round-robin** (the
nginx algorithm): deterministic, exactly proportional over any window,
and trivially testable — no RNG in the serving hot path.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

__all__ = ["WeightedRouter", "canary_router", "Router"]

Router = Callable[[], str]


class WeightedRouter:
    """Smooth weighted round-robin over version labels.

    Each pick adds every label's weight to its running credit, selects the
    label with the most credit, then debits the selected label by the
    total weight.  Over any window of N picks each label is chosen
    ``round(N * weight / total)`` times, with the picks interleaved (no
    bursts) — so a 5% canary sees traffic *throughout* the window, not a
    tail of it.
    """

    def __init__(self, weights: Dict[str, float]):
        self._lock = threading.Lock()
        self.counts: Dict[str, int] = {}
        self.set_weights(weights)

    def set_weights(self, weights: Dict[str, float]) -> None:
        clean = {str(k): float(v) for k, v in weights.items() if v > 0}
        if not clean:
            raise ValueError(f"no positive weights in {weights!r}")
        with self._lock:
            self.weights = clean
            self._credit = {k: 0.0 for k in clean}

    def __call__(self) -> str:
        with self._lock:
            total = sum(self.weights.values())
            for label, w in self.weights.items():
                self._credit[label] = self._credit.get(label, 0.0) + w
            pick = max(self._credit, key=lambda k: (self._credit[k], k))
            self._credit[pick] -= total
            self.counts[pick] = self.counts.get(pick, 0) + 1
            return pick

    def fractions(self) -> Dict[str, float]:
        """Observed traffic split (by routed batches)."""
        with self._lock:
            total = sum(self.counts.values())
            return {k: v / total for k, v in self.counts.items()} if total \
                else {}

    def summary(self) -> dict:
        with self._lock:
            total = sum(self.weights.values())
            return {
                "weights": {k: v / total for k, v in self.weights.items()},
                "routed_batches": dict(self.counts),
            }


def canary_router(primary: str, canary: str,
                  canary_pct: float) -> Optional[WeightedRouter]:
    """Router sending ``canary_pct``% of batches to the canary version.

    Returns ``None`` for a 0% canary (serve the primary directly — no
    router indirection in the hot path) and an all-canary router at 100%.
    """
    if not 0.0 <= canary_pct <= 100.0:
        raise ValueError(f"canary_pct must be in [0, 100], got {canary_pct}")
    if canary_pct == 0.0:
        return None
    if canary_pct == 100.0:
        return WeightedRouter({canary: 1.0})
    return WeightedRouter({primary: 100.0 - canary_pct, canary: canary_pct})
