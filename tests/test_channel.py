"""Channel-impairment subsystem: impairment properties, scenario suite,
robustness harness, and the drift-injection path into the canary monitor.

Covers the ISSUE-5 acceptance bars:

* determinism in (seed, scenario) and jit/vmap traceability with no host
  callbacks;
* unit average power preserved by every multiplicative impairment, and
  analytically-known output power for the additive ones;
* the clean-AWGN scenario path is bit-equal to the legacy
  ``radioml._apply_channel`` (which now *is* the channel package's
  implementation) — pinned with generator golden hashes;
* all four execution backends agree on impaired frames to atol 1e-5;
* a ``doppler_drift`` frame source injected into ``CanaryMonitor``
  triggers rollback for a drift-divergent canary — and does *not* falsely
  roll back an equivalent one.
"""
import hashlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import SNNConfig, compile_snn, init_snn
from repro.channel import (
    SCENARIOS,
    SUITES,
    ChannelScenario,
    apply_scenario,
    avg_power,
    awgn,
    carrier_offset,
    interferer_tones,
    iq_imbalance,
    legacy_awgn_channel,
    make_frame_source,
    multipath_fading,
    normalize_power,
    phase_noise,
    scenario_fn,
    suite_scenarios,
    timing_offset,
    to_complex,
    to_iq,
)
from repro.data import radioml
from repro.data.radioml import generate_batch, generate_sample

# same reduced model family as test_deploy/test_serve: binds stay cheap
CFG = SNNConfig(
    conv_specs=((3, 2, 4), (3, 4, 8)),
    pool=2,
    fc_specs=((32, 16), (16, 5)),
    input_width=16,
    timesteps=3,
    n_classes=5,
)


def _unit_sig(seed=0, n=128):
    rng = np.random.default_rng(seed)
    sig = rng.normal(size=n) + 1j * rng.normal(size=n)
    return normalize_power(jnp.asarray(sig, jnp.complex64))


# ---------------------------------------------------------------------------
# impairment properties
# ---------------------------------------------------------------------------

MULTIPLICATIVE = [
    ("carrier_offset", lambda s, k: carrier_offset(s, k, 0.02, True)),
    ("phase_noise", lambda s, k: phase_noise(s, k, 3e-3)),
    ("timing_offset", lambda s, k: timing_offset(s, k, 2e-3, 0.5)),
    ("iq_imbalance", lambda s, k: iq_imbalance(s, k, 1.5, 8.0)),
    ("rayleigh", lambda s, k: multipath_fading(
        s, k, (0, 2, 5), (1.0, 0.6, 0.3), doppler=0.01)),
    ("rician", lambda s, k: multipath_fading(
        s, k, (0, 3), (1.0, 0.3), doppler=2e-3, rician_k=4.0)),
]


@pytest.mark.parametrize("name,fn", MULTIPLICATIVE, ids=[n for n, _ in MULTIPLICATIVE])
def test_impairment_preserves_unit_power(name, fn):
    sig = _unit_sig()
    out = fn(sig, jax.random.PRNGKey(3))
    assert out.shape == sig.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(avg_power(out)) == pytest.approx(1.0, abs=1e-3)


def test_additive_impairments_hit_target_power():
    """AWGN and interference add analytically-known energy on top of a
    unit-power signal: E[p] = 1 + 10^(-x/10)."""
    sig = _unit_sig(n=4096)  # long frame -> tight sample estimate
    for x_db in (0.0, 10.0):
        p = float(avg_power(awgn(sig, jax.random.PRNGKey(7), x_db)))
        assert p == pytest.approx(1.0 + 10 ** (-x_db / 10), rel=0.1)
        p = float(avg_power(interferer_tones(sig, jax.random.PRNGKey(8), x_db)))
        assert p == pytest.approx(1.0 + 10 ** (-x_db / 10), rel=0.1)


def test_iq_complex_roundtrip():
    iq = jnp.asarray(np.random.default_rng(0).normal(size=(2, 32)),
                     jnp.float32)
    np.testing.assert_allclose(np.asarray(to_iq(to_complex(iq))),
                               np.asarray(iq), atol=1e-7)


# ---------------------------------------------------------------------------
# scenarios: determinism + traceability
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_deterministic_and_traceable(name):
    sc = SCENARIOS[name]
    iq, _, snrs = generate_batch(0, 3, frame_len=64, apply_channel=False)
    key = jax.random.PRNGKey(11)
    a = np.asarray(apply_scenario(sc, iq, snrs, key))
    b = np.asarray(apply_scenario(sc, iq, snrs, key))
    np.testing.assert_array_equal(a, b)          # deterministic in key
    c = np.asarray(apply_scenario(sc, iq, snrs, jax.random.PRNGKey(12)))
    assert not np.array_equal(a, c)              # and actually random
    # jitted twin (scenario_fn) matches eager to float32 tolerance
    d = np.asarray(scenario_fn(sc)(jnp.asarray(iq), jnp.asarray(snrs), key))
    np.testing.assert_allclose(a, d, atol=1e-5)
    assert a.shape == iq.shape and np.isfinite(a).all()


def test_scenarios_trace_without_host_callbacks():
    """apply_scenario must stay pure jax: traceable under jit(vmap(...))
    with no callback primitives in the jaxpr."""
    iq, _, snrs = generate_batch(1, 2, frame_len=32, apply_channel=False)
    for name in SUITES["default"]:
        sc = SCENARIOS[name]
        fn = lambda f, s, k: apply_scenario(sc, f, s, k)
        jaxpr = jax.make_jaxpr(fn)(jnp.asarray(iq), jnp.asarray(snrs),
                                   jax.random.PRNGKey(0))
        assert "callback" not in str(jaxpr), name
        out = jax.jit(fn)(jnp.asarray(iq), jnp.asarray(snrs),
                          jax.random.PRNGKey(0))
        assert out.shape == iq.shape


def test_scenario_single_frame_and_per_batch_snr():
    sc = SCENARIOS["urban_fading"]
    iq, _, _ = generate_batch(2, 4, frame_len=32, apply_channel=False)
    one = apply_scenario(sc, iq[0], 10.0, jax.random.PRNGKey(0))
    assert one.shape == (2, 32)
    snrs = jnp.asarray([-10.0, 0.0, 5.0, 18.0])
    out = apply_scenario(sc, iq, snrs, jax.random.PRNGKey(0))
    assert out.shape == iq.shape


def test_scenario_validation_and_lookup():
    with pytest.raises(ValueError, match="fading"):
        ChannelScenario(fading="bogus")
    with pytest.raises(ValueError, match="path_delays"):
        ChannelScenario(path_delays=(0, 1), path_powers=(1.0,))
    with pytest.raises(ValueError, match="unknown channel scenario"):
        apply_scenario("nope", jnp.zeros((2, 8)), 0.0, jax.random.PRNGKey(0))
    assert [s.name for s in suite_scenarios("quick")] == list(SUITES["quick"])
    assert suite_scenarios("static_awgn,iq_impaired")[1].name == "iq_impaired"


# ---------------------------------------------------------------------------
# clean-AWGN scenario == the legacy radioml channel
# ---------------------------------------------------------------------------

def test_legacy_channel_is_the_channel_packages():
    """The generator's channel and the channel package share one function
    (delegation, not duplication) — identical rng stream, identical bytes."""
    assert radioml._apply_channel is legacy_awgn_channel
    rng_a, rng_b = np.random.default_rng(5), np.random.default_rng(5)
    sig = np.random.default_rng(1).normal(size=128) + 0j
    a = legacy_awgn_channel(rng_a, sig, 6.0)
    b = radioml._apply_channel(rng_b, sig, 6.0)
    np.testing.assert_array_equal(a, b)


def test_generator_goldens_unchanged():
    """The channel/taps refactor must not move a single generator bit:
    hashes pinned from the pre-refactor implementation."""
    pins = {
        ("QPSK", 0, 10.0): "e9bac8d57aa86330",
        ("WBFM", 12345, -6.0): "a104d6d3649fb995",
    }
    for (mod, seed, snr), want in pins.items():
        s = generate_sample(seed, mod, snr)
        assert hashlib.sha256(s.tobytes()).hexdigest()[:16] == want, mod
    iq, _, _ = generate_batch(7, 8)
    assert hashlib.sha256(iq.tobytes()).hexdigest()[:16] == "54a18ccbf9c0a49d"


def test_jax_awgn_matches_legacy_noise_math():
    """Given the same noise realization, the traceable AWGN applies the
    exact normalize-then-add math of the legacy channel."""
    rng = np.random.default_rng(9)
    sig64 = rng.normal(size=128) + 1j * rng.normal(size=128)
    noise = rng.normal(size=128) + 1j * rng.normal(size=128)
    for snr in (-10.0, 0.5, 18.0):
        ref = sig64 / np.sqrt(np.mean(np.abs(sig64) ** 2) + 1e-12)
        ref = ref + noise * np.sqrt(10 ** (-snr / 10) / 2)
        out = awgn(jnp.asarray(sig64, jnp.complex64), None, snr,
                   _noise=jnp.asarray(noise, jnp.complex64))
        np.testing.assert_allclose(np.asarray(out), ref.astype(np.complex64),
                                   atol=1e-6)


def test_clean_scenario_is_identity_up_to_rms_norm():
    iq, _, _ = generate_batch(4, 2, frame_len=64, apply_channel=False)
    clean = ChannelScenario(name="clean", add_noise=False)
    out = np.asarray(apply_scenario(clean, iq, 10.0, jax.random.PRNGKey(0)))
    # frames are already unit-RMS from the generator; identity channel +
    # the same normalization convention returns them unchanged
    np.testing.assert_allclose(out, iq, atol=1e-5)


# ---------------------------------------------------------------------------
# vectorized/cached pulse-shaping taps
# ---------------------------------------------------------------------------

def _rrc_reference(beta, span, sps):
    """The original per-tap loop, kept as the vectorization oracle."""
    n = span * sps
    t = (np.arange(-n // 2, n // 2 + 1)) / sps
    taps = np.zeros_like(t)
    for i, ti in enumerate(t):
        if abs(ti) < 1e-9:
            taps[i] = 1.0 - beta + 4 * beta / np.pi
        elif abs(abs(4 * beta * ti) - 1.0) < 1e-9:
            taps[i] = (beta / np.sqrt(2)) * (
                (1 + 2 / np.pi) * np.sin(np.pi / (4 * beta))
                + (1 - 2 / np.pi) * np.cos(np.pi / (4 * beta))
            )
        else:
            num = np.sin(np.pi * ti * (1 - beta)) + 4 * beta * ti * np.cos(np.pi * ti * (1 + beta))
            den = np.pi * ti * (1 - (4 * beta * ti) ** 2)
            taps[i] = num / den
    return taps / np.sqrt(np.sum(taps**2))


def test_rrc_taps_vectorization_bit_equal():
    # default params and a beta that hits the |4*beta*t| = 1 singularity
    # on the tap grid (beta=0.5 -> t=0.5 is a grid point at sps=8)
    for beta in (0.35, 0.5):
        got = radioml._rrc_taps(beta=beta)
        np.testing.assert_array_equal(got, _rrc_reference(beta, 8, 8))


def test_taps_are_cached():
    a = radioml._rrc_taps()
    assert radioml._rrc_taps() is a            # lru_cache hit
    assert not a.flags.writeable               # shared -> immutable
    g = radioml._gaussian_taps()
    assert radioml._gaussian_taps() is g
    assert radioml._rrc_taps.cache_info().hits >= 1


# ---------------------------------------------------------------------------
# cross-backend agreement on impaired frames
# ---------------------------------------------------------------------------

def test_all_backends_agree_on_impaired_frames():
    """Acceptance bar: dense/goap/pallas/stream produce the same logits on
    scenario-impaired frames to atol 1e-5."""
    from repro.data.pipeline import sigma_delta_encode_np
    from repro.train.pruning import make_mask_pytree

    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, 0.5)
    program = compile_snn(CFG)
    iq, _, snrs = generate_batch(3, 4, frame_len=CFG.input_width,
                                 apply_channel=False)
    impaired = np.asarray(apply_scenario(
        SCENARIOS["doppler_drift"], iq, snrs, jax.random.PRNGKey(1)))
    frames = jnp.asarray(sigma_delta_encode_np(impaired, CFG.timesteps))
    ref = None
    for backend in ("dense", "goap", "pallas", "stream"):
        logits = np.asarray(program.apply_batch(params, frames, backend,
                                                masks=masks))
        if ref is None:
            ref = logits
        else:
            np.testing.assert_allclose(logits, ref, atol=1e-5,
                                       err_msg=backend)


# ---------------------------------------------------------------------------
# robustness harness
# ---------------------------------------------------------------------------

def test_robustness_harness_report_structure():
    from repro.eval import RobustnessConfig, evaluate_robustness, format_report

    params = init_snn(jax.random.PRNGKey(0), CFG)
    ecfg = RobustnessConfig(suite="quick", snr_grid=(0.0, 10.0),
                            frames_per_cell=8, backends=("dense", "goap"),
                            seed=3)
    rep = evaluate_robustness(params, CFG, ecfg)
    assert list(rep["scenarios"]) == list(SUITES["quick"])
    for s in rep["scenarios"].values():
        assert set(s["per_snr"]) == {"+0.0", "+10.0"}
        for cell in s["per_snr"].values():
            cm = np.asarray(cell["confusion"])
            assert cm.shape == (CFG.n_classes, CFG.n_classes)
            assert cm.sum() == 8 == cell["n_frames"]
            assert set(cell["accuracy"]) == {"dense", "goap"}
    surf = np.asarray(rep["surface"]["accuracy"])
    assert surf.shape == (2, 2)
    assert rep["agreement"]["agrees"]
    assert "clean" in rep and set(rep["clean"]) == {"+0.0", "+10.0"}
    assert format_report(rep)  # renders
    # deterministic in config
    rep2 = evaluate_robustness(params, CFG, ecfg)
    assert rep2["surface"]["accuracy"] == rep["surface"]["accuracy"]


def test_stable_cell_seed_separates_fractional_snrs():
    from repro.eval import stable_cell_seed

    assert stable_cell_seed("clean", 0.5) != stable_cell_seed("clean", 0.9)
    assert stable_cell_seed("clean", 0.5) != stable_cell_seed("fade", 0.5)
    assert stable_cell_seed("clean", 0.5) == stable_cell_seed("clean", 0.5)


def test_monitor_snr_bin_seed_fix():
    """Fractional SNR buckets must draw distinct frames (the old
    ``int(snr) * 131`` derivation collapsed 0.5 and 0.9 onto one seed)."""
    from repro.deploy.monitor import _snr_bin_seed

    assert _snr_bin_seed(0.5) != _snr_bin_seed(0.9)
    assert _snr_bin_seed(-10.0) != _snr_bin_seed(10.0)
    a, _, _ = generate_batch(1000 + _snr_bin_seed(0.5), 4, snr_db=0.5)
    b, _, _ = generate_batch(1000 + _snr_bin_seed(0.9), 4, snr_db=0.9)
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# pipeline + trainer integration
# ---------------------------------------------------------------------------

def test_pipeline_scenario_augmentation_stage():
    from repro.data.pipeline import SpikeBatchPipeline

    pipe = SpikeBatchPipeline(batch_size=4, osr=3, prefetch=2,
                              scenario="doppler_drift")
    try:
        frames, labels, snrs = next(pipe)
        assert frames.shape == (4, 3, 2, 128) and labels.shape == (4,)
        assert set(np.unique(frames)) <= {0.0, 1.0}
    finally:
        pipe.close()


def test_trainer_scenario_augmentation_and_eval():
    from repro.train.trainer import SNNTrainer, TrainerConfig

    tcfg = TrainerConfig(total_steps=2, batch_size=4, seed=0,
                         augment_scenario="urban_fading", osr=CFG.timesteps)
    trainer = SNNTrainer(CFG, tcfg)
    hist = trainer.run(steps=2, log_every=1)
    assert np.isfinite(hist["loss"]).all()
    acc = trainer.evaluate(n_batches=1, snr_db=10.0,
                           scenario="doppler_drift")
    assert 0.0 <= acc <= 1.0


# ---------------------------------------------------------------------------
# drift injection -> canary monitor (acceptance bar)
# ---------------------------------------------------------------------------

def _drift_monitor(engine, registry=None, **cfg_kw):
    from repro.deploy import CanaryMonitor, MonitorConfig

    base = dict(snr_bins=(0.0, 10.0), frames_per_bin=8, window=3,
                min_rounds=1, promote_after=2, score="agreement")
    base.update(cfg_kw)
    return CanaryMonitor(
        engine, baseline="prod", canary="canary",
        config=MonitorConfig(**base),
        frame_source=make_frame_source("doppler_drift",
                                       frame_len=CFG.input_width))


def test_doppler_drift_frame_source_triggers_rollback():
    """Acceptance bar: a CanaryMonitor shadow-evaluating under an injected
    doppler_drift channel auto-rolls-back a canary that diverges from the
    baseline under drift."""
    from repro.serve import AsyncAMCServeEngine

    params = init_snn(jax.random.PRNGKey(0), CFG)
    # drift-divergent canary: rolled head disagrees with production
    permuted = {
        "conv": params["conv"],
        "fc": [params["fc"][0],
               dict(params["fc"][1],
                    w=np.roll(np.asarray(params["fc"][1]["w"]), 1, axis=1))],
    }
    with AsyncAMCServeEngine(params, CFG, backend="dense", max_batch=8,
                             version_label="prod") as engine:
        engine.bind_version("canary", permuted, backend="dense")
        mon = _drift_monitor(engine)
        assert mon.run(max_rounds=8) == "rollback"
        assert "regression" in mon.reason
        assert "canary" not in engine.versions()
        assert engine.active_version == "prod"


def test_doppler_drift_does_not_falsely_roll_back_equivalent_canary():
    """Shared drift moves both sides together: an identical canary must
    survive the same injected channel (and promote on clean rounds)."""
    from repro.serve import AsyncAMCServeEngine

    params = init_snn(jax.random.PRNGKey(0), CFG)
    same = jax.tree_util.tree_map(np.asarray, params)
    with AsyncAMCServeEngine(params, CFG, backend="dense", max_batch=8,
                             version_label="prod") as engine:
        engine.bind_version("canary", same, backend="dense")
        mon = _drift_monitor(engine)
        assert mon.run(max_rounds=8) == "promote"
        assert engine.active_version == "canary"
