"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (unverified).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU recurrent
blocks with local (window 2048) attention every third layer (1:2 ratio),
lru_width = d_model.  Sub-quadratic: runs the long_500k shape.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv=1, d_ff=12288,
    vocab=256000, head_dim=256,
    window=2048, hybrid_period=3, lru_width=4096, ssm_conv=4,
    rope_theta=10_000.0,
    notes="(rglru, rglru, local-attn) period-3 pattern; 38 = 12*3 + 2 tail",
)
