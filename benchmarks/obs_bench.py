"""Observability overhead benchmark: traced vs untraced serving.

The observability layer's contract is "on by default, invisible in the
numbers": full request tracing (sample_every=1) plus the metrics-registry
mirrors must cost <5% of both throughput and p99 latency on the async
serving path.  This bench measures exactly that and records the verdict
to ``BENCH_obs.json``:

* **untraced** — tracing disabled (the hot path pays one module-global
  read per request), metrics registry still live (it always is);
* **traced** — ``enable_tracing(sample_every=1)``: every request carries
  a full span timeline through submit -> enqueue -> dequeue ->
  batch-form -> jit-step -> complete.

Each attempt runs the same frame pile through a fresh ``ServeStats``
window in both modes and compares; the gate passes if ANY attempt lands
under the overhead bar on both axes (scheduler noise on shared CI boxes
produces occasional outlier attempts — requiring every attempt to pass
gates on the machine, not the code).

Also recorded: spans/sec the tracer absorbed, and an **activity-gauge
sanity block** — the live per-batch gauges replayed over the pinned
``tests/test_stream_golden.py`` input must reproduce the paper's
Tables I/III totals bit-exactly.

Run:  PYTHONPATH=src python benchmarks/obs_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

import jax

from repro.api import init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.obs import (
    AlertManager,
    BurnRateEngine,
    BurnRateWatcher,
    MetricsRegistry,
    SeriesWatcher,
    TimeSeriesRecorder,
    default_serve_slos,
    disable_tracing,
    enable_tracing,
    scaled_windows,
    set_default_registry,
    to_perfetto,
    validate_perfetto,
)
from repro.serve import AsyncAMCServeEngine
from repro.serve.engine import ServeStats
from repro.train.pruning import make_mask_pytree

NAME = "obs_bench"

DENSITY = 0.5
MAX_BATCH = 64
MAX_DELAY_MS = 2.0
OVERHEAD_BAR = 0.05      # <5% on throughput AND p99
P99_SLACK_MS = 0.25      # absolute floor: sub-ms p99s jitter more than 5%

#: Pinned Tables I/III golden totals for the paper config at 50% density
#: (the literals asserted by tests/test_stream_golden.py; duplicated here
#: so the bench artifact is self-contained).
GOLDEN_ACCUMULATIONS = {"conv1": 88895, "conv2": 437602, "conv3": 263433}
GOLDEN_TOTAL = 789930


def _synthetic_frames(n: int) -> np.ndarray:
    rng = np.random.default_rng(0)
    iq = rng.normal(size=(n, 2, CFG.input_width)).astype(np.float32)
    return iq / np.sqrt(np.mean(iq**2, axis=(-2, -1), keepdims=True))


def _one_pass(engine, iq: np.ndarray) -> dict:
    """Serve the pile through a fresh stats window; return its summary.

    Throughput is wall-clock around *this* pass: the engine-maintained
    ``stats.wall_s`` window opens at the engine's first-ever enqueue, so
    on a reused engine it spans every earlier pass and would make each
    successive measurement look mechanically slower.
    """
    engine.stats = ServeStats(backend=engine.backend)
    t0 = time.perf_counter()
    engine.classify(iq)
    wall = time.perf_counter() - t0
    s = engine.stats.summary()
    s["throughput_fps"] = iq.shape[0] / max(wall, 1e-9)
    return s


def measure_overhead(n_frames: int, attempts: int = 3) -> dict:
    """Traced vs untraced passes over one warm engine; per-attempt pairs.

    The traced side now carries the *whole* analysis plane live — full
    tracing plus a :class:`TimeSeriesRecorder` sweeping the registry and
    a burn-rate + drift evaluation on every sweep — so the <5% bar gates
    recorder and SLO-evaluation overhead too, not just span appends.

    Each attempt installs a **fresh per-pass** :class:`TraceLog` sized to
    the pass (``capacity >= n_frames``) and validates its dump before
    the next pass begins: an earlier pass's ring can never evict this
    pass's traces (the regression tests/test_obs_analysis.py pins).
    """
    import threading

    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    iq = _synthetic_frames(n_frames)

    engine = AsyncAMCServeEngine(
        params, CFG, masks=masks, backend="dense", max_batch=MAX_BATCH,
        max_delay_ms=MAX_DELAY_MS, workers=1, count_activity=False,
        activity_gauges=False, name="obs-bench")
    engine.classify(iq[:MAX_BATCH])      # warm the serving path
    pairs = []
    spans_per_s = 0.0
    perfetto = {"n_events": 0, "problems": ["no traced pass ran"]}
    try:
        for _ in range(max(1, attempts)):
            disable_tracing()
            untraced = _one_pass(engine, iq)
            # fresh per-pass ring, never smaller than the pass itself
            log = enable_tracing(sample_every=1,
                                 capacity=max(4096, n_frames))
            # live analysis plane riding on the traced pass (0.1s:
            # 5x denser than the serve driver's 0.5s default — a GIL
            # headroom test, not just a liveness check)
            recorder = TimeSeriesRecorder(interval_s=0.1, capacity=4096)
            burn = BurnRateEngine(recorder, default_serve_slos(),
                                  windows=scaled_windows(1.0 / 600.0))
            manager = AlertManager()
            watchers = [SeriesWatcher(recorder, manager),
                        BurnRateWatcher(burn, manager)]
            stop = threading.Event()

            def analysis_loop(rec=recorder, ws=watchers, ev=stop):
                while not ev.wait(rec.interval_s):
                    rec.sample()
                    for w in ws:
                        w.step()

            analysis = threading.Thread(target=analysis_loop, daemon=True)
            analysis.start()
            t0 = time.perf_counter()
            traced = _one_pass(engine, iq)
            traced_wall = time.perf_counter() - t0
            stop.set()
            analysis.join(timeout=5.0)
            # per-pass dump validation *before* the next pass can touch
            # any tracer state: every frame of this pass must be present
            dump = log.dump()
            traced["dump_completed"] = dump["n_completed"]
            traced["dump_complete"] = bool(
                dump["n_completed"] == n_frames
                and len(dump["traces"]) == n_frames)
            doc = to_perfetto(dump)
            perfetto = {"n_events": len(doc["traceEvents"]),
                        "problems": validate_perfetto(doc)}
            traced["analysis_sweeps"] = recorder.n_sweeps
            n_events = sum(len(tr.events) for tr in log.completed())
            spans_per_s = max(spans_per_s, n_events / max(traced_wall, 1e-9))
            tput_over = (untraced["throughput_fps"] /
                         max(traced["throughput_fps"], 1e-9)) - 1.0
            p99_over_ms = traced["p99_ms"] - untraced["p99_ms"]
            p99_ok = (traced["p99_ms"] <= untraced["p99_ms"]
                      * (1.0 + OVERHEAD_BAR) + P99_SLACK_MS)
            pairs.append({
                "untraced": untraced,
                "traced": traced,
                "throughput_overhead": tput_over,
                "p99_delta_ms": p99_over_ms,
                "pass": bool(tput_over < OVERHEAD_BAR and p99_ok
                             and traced["dump_complete"]),
            })
    finally:
        disable_tracing()
        engine.close()
    return {
        "attempts": pairs,
        "spans_per_s": spans_per_s,
        "best_throughput_overhead":
            min(p["throughput_overhead"] for p in pairs),
        "dumps_complete": all(p["traced"]["dump_complete"] for p in pairs),
        "perfetto": perfetto,
        "pass": any(p["pass"] for p in pairs),
    }


def activity_sanity() -> dict:
    """Replay the golden stream input through the live activity gauges.

    Same recipe as ``tests/test_stream_golden.py``: paper config, seed-0
    init, 50% masks, seed-0 binary frames.  The per-batch gauges must
    land on the pinned Tables I/III accumulation literals *exactly* —
    fp32 counters are integral below 2**24.
    """
    import jax.numpy as jnp

    from repro.api import compile_plan, compile_snn
    from repro.obs import ActivityObserver
    from repro.plan import PlanCache

    program = compile_snn(CFG)
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    plan = compile_plan(program, params, masks=masks, assignment="stream",
                        cache=PlanCache(disk_dir=""))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(
        (rng.random((1, CFG.timesteps, CFG.conv_specs[0][1],
                     CFG.input_width)) < 0.5).astype(np.float32))
    _, accs = plan.batch_counters(frames)
    reg = MetricsRegistry()
    obs = ActivityObserver(plan, registry=reg, engine="sanity")
    obs.observe({k: np.asarray(v) for k, v in accs.items()}, n_real=1)
    got = {name: int(reg.value("repro_activity_accumulations_total",
                               engine="sanity", layer=name))
           for name in GOLDEN_ACCUMULATIONS}
    return {
        "golden": GOLDEN_ACCUMULATIONS,
        "observed": got,
        "total": sum(got.values()),
        "golden_total": GOLDEN_TOTAL,
        "exact": bool(got == GOLDEN_ACCUMULATIONS
                      and sum(got.values()) == GOLDEN_TOTAL),
    }


def alert_pipeline(n_baseline: int = 24, n_shift: int = 24,
                   n_revert: int = 64) -> dict:
    """Injected-drift scenario: density shift -> drift alert -> revert.

    The full detection pipeline on a fake clock: live Tables I/III
    activity gauges (``ActivityObserver`` over the streaming plan's
    in-graph counters) -> ``TimeSeriesRecorder`` -> EWMA drift detectors
    -> ``AlertManager``.  Phase 1 feeds frames at the paper's 50% input
    density (baseline learned, nothing may fire); phase 2 swaps the
    scenario to 15% density and counts samples until ``sparsity_drift``
    fires; phase 3 reverts and counts samples until it resolves.  The
    verdict (fired within the budget AND resolved after revert AND no
    baseline false positive) is part of the bench gate.
    """
    import jax.numpy as jnp

    from repro.api import compile_plan, compile_snn
    from repro.obs import ActivityObserver
    from repro.plan import PlanCache

    program = compile_snn(CFG)
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    plan = compile_plan(program, params, masks=masks, assignment="stream",
                        cache=PlanCache(disk_dir=""))
    rng = np.random.default_rng(0)

    t = {"now": 0.0}
    reg = MetricsRegistry()
    obs = ActivityObserver(plan, registry=reg, engine="drift")
    recorder = TimeSeriesRecorder(reg, interval_s=1.0, capacity=4096,
                                  clock=lambda: t["now"])
    manager = AlertManager(registry=reg, clock=lambda: t["now"])
    watcher = SeriesWatcher(recorder, manager)

    def feed(density: float) -> None:
        frames = jnp.asarray(
            (rng.random((1, CFG.timesteps, CFG.conv_specs[0][1],
                         CFG.input_width)) < density).astype(np.float32))
        _, accs = plan.batch_counters(frames)
        obs.observe({k: np.asarray(v) for k, v in accs.items()}, n_real=1)
        t["now"] += 1.0
        recorder.sample()
        watcher.step()

    def drift_firing() -> bool:
        return any(a.name == "sparsity_drift" for a in manager.firing())

    for _ in range(n_baseline):
        feed(DENSITY)
    baseline_clean = not manager.firing()

    fired_after = None
    for i in range(n_shift):
        feed(0.15)
        if fired_after is None and drift_firing():
            fired_after = i + 1

    resolved_after = None
    for i in range(n_revert):
        feed(DENSITY)
        if resolved_after is None and not drift_firing():
            resolved_after = i + 1

    gauge = reg.value("repro_alerts_firing", alert="sparsity_drift")
    return {
        "n_baseline": n_baseline,
        "n_shift": n_shift,
        "n_revert": n_revert,
        "baseline_clean": bool(baseline_clean),
        "fired_after_samples": fired_after,
        "resolved_after_samples": resolved_after,
        "firing_gauge_after_revert": float(gauge),
        "transitions": len(manager.history),
        "pass": bool(baseline_clean and fired_after is not None
                     and resolved_after is not None and gauge == 0.0),
    }


def run(n_frames: int = 4096, attempts: int = 3) -> dict:
    # isolate the bench from whatever the process registry accumulated
    prev = set_default_registry(MetricsRegistry())
    try:
        overhead = measure_overhead(n_frames, attempts=attempts)
        sanity = activity_sanity()
        drift = alert_pipeline()
    finally:
        set_default_registry(prev)
    return {
        "n_frames": n_frames,
        "density": DENSITY,
        "jax_backend": jax.default_backend(),
        "overhead_bar": OVERHEAD_BAR,
        "overhead": overhead,
        "activity_sanity": sanity,
        "alert_pipeline": drift,
        "pass": bool(overhead["pass"] and sanity["exact"]
                     and drift["pass"]
                     and not overhead["perfetto"]["problems"]),
    }


def check(res: dict) -> list:
    """Regression-gate hook for benchmarks/run.py: list of failures."""
    fails = []
    if not res["overhead"]["pass"]:
        best = res["overhead"]["best_throughput_overhead"]
        fails.append(f"tracing overhead above {OVERHEAD_BAR:.0%} on every "
                     f"attempt (best throughput overhead {best:.1%})")
    if not res["activity_sanity"]["exact"]:
        fails.append(f"activity gauges diverged from Tables I/III goldens: "
                     f"{res['activity_sanity']['observed']}")
    if not res["overhead"].get("dumps_complete", True):
        fails.append("per-pass trace dump incomplete: an earlier pass's "
                     "ring evicted traces before validation")
    perfetto = res["overhead"].get("perfetto", {})
    if perfetto.get("problems"):
        fails.append(f"perfetto export schema-invalid: "
                     f"{perfetto['problems'][:3]}")
    drift = res.get("alert_pipeline", {})
    if drift and not drift.get("pass"):
        fails.append(
            f"alert pipeline: baseline_clean={drift.get('baseline_clean')} "
            f"fired_after={drift.get('fired_after_samples')} "
            f"resolved_after={drift.get('resolved_after_samples')}")
    return fails


def format_table(res: dict) -> str:
    o = res["overhead"]
    lines = [f"Obs bench: {res['n_frames']} frames, "
             f"{res['jax_backend']} backend, bar {res['overhead_bar']:.0%}"]
    for i, p in enumerate(o["attempts"]):
        lines.append(
            f"  attempt {i}: untraced {p['untraced']['throughput_fps']:8.1f} "
            f"frames/s  traced {p['traced']['throughput_fps']:8.1f}  "
            f"overhead {p['throughput_overhead']:+6.1%}  "
            f"p99 delta {p['p99_delta_ms']:+6.2f}ms  "
            f"{'PASS' if p['pass'] else 'fail'}")
    lines.append(f"  spans/sec absorbed: {o['spans_per_s']:.0f}")
    p = o.get("perfetto", {})
    lines.append(f"  perfetto export: {p.get('n_events', 0)} events, "
                 f"{'VALID' if not p.get('problems') else p['problems'][:2]}"
                 f"  per-pass dumps "
                 f"{'complete' if o.get('dumps_complete') else 'EVICTED'}")
    s = res["activity_sanity"]
    lines.append(f"  activity gauges vs Tables I/III: "
                 f"{'EXACT' if s['exact'] else 'DIVERGED'} "
                 f"(total {s['total']} vs golden {s['golden_total']})")
    d = res.get("alert_pipeline", {})
    if d:
        lines.append(
            f"  alert pipeline: drift fired after "
            f"{d['fired_after_samples']} shifted samples, resolved after "
            f"{d['resolved_after_samples']} reverted samples "
            f"({'PASS' if d['pass'] else 'fail'})")
    lines.append(f"  verdict: {'PASS' if res['pass'] else 'FAIL'}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced frame count for CI smoke runs")
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--attempts", type=int, default=3)
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args(argv)

    n = args.frames if args.frames else (256 if args.smoke else 4096)
    res = run(n_frames=n, attempts=args.attempts)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    if not args.smoke and not res["pass"]:
        print("FAIL: observability overhead / sanity gate")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
