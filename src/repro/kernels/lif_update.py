"""Fused LIF neuron update over time as a Pallas TPU kernel.

The paper's MVTU fuses decay -> accumulate -> threshold -> soft-reset ->
write-back into a single pipeline stage; the membrane potential is loaded
and stored exactly once per output-channel pass.  The TPU analogue: keep
the membrane row in **VMEM scratch across the whole T loop** — HBM sees one
read of the currents per timestep and one write of the spikes, the state
never round-trips (vs. 3 HBM touches/step for the naive unfused chain).

Grid: (neuron-tiles, T) with T the minor (sequential) dimension; the state
scratch carries across T iterations of the same neuron tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["lif_update_fused"]


def _kernel(cur_ref, v0_ref, alpha_ref, theta_ref, vth_ref,
            spikes_ref, vfin_ref, v_scratch):
    t = pl.program_id(1)
    n_t = pl.num_programs(1)

    @pl.when(t == 0)
    def _load():
        v_scratch[...] = v0_ref[...]

    v = v_scratch[...] * alpha_ref[...] + cur_ref[0]
    s = (v > vth_ref[...]).astype(v.dtype)
    v = v - theta_ref[...] * s
    spikes_ref[0] = s
    v_scratch[...] = v

    @pl.when(t == n_t - 1)
    def _store():
        vfin_ref[...] = v_scratch[...]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lif_update_fused(
    currents: jax.Array,  # (T, N)
    v0: jax.Array,        # (N,)
    alpha: jax.Array,     # (N,) decay in (0,1)
    theta: jax.Array,     # (N,) soft-reset amount
    v_th: jax.Array,      # (N,) threshold
    *,
    block_n: int = 128,
    interpret: bool = True,
):
    """Returns (spikes (T, N), v_final (N,)). One HBM pass over currents."""
    t_steps, n = currents.shape
    pad_n = (-n) % block_n
    cur = jnp.pad(currents, ((0, 0), (0, pad_n)))
    pad1 = lambda a: jnp.pad(a, (0, pad_n))
    v0p, al, th, vt = pad1(v0), pad1(alpha), pad1(theta), pad1(v_th)
    # avoid spurious spikes in the padded region (v_th would be 0 there)
    vt = vt.at[n:].set(jnp.inf) if pad_n else vt

    n_tiles = cur.shape[1] // block_n
    grid = (n_tiles, t_steps)
    vec = lambda: pl.BlockSpec((block_n,), lambda i, t: (i,))
    spikes, v_fin = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, t: (t, i)),
            vec(), vec(), vec(), vec(),
        ],
        out_specs=[
            pl.BlockSpec((1, block_n), lambda i, t: (t, i)),
            vec(),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(cur.shape, currents.dtype),
            jax.ShapeDtypeStruct((cur.shape[1],), currents.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_n,), currents.dtype)],
        interpret=interpret,
        name="lif_update_fused",
    )(cur, v0p, al, th, vt)
    return spikes[:, :n], v_fin[:n]
