"""Process-wide metrics registry: counters, gauges, histograms with labels.

One shared taxonomy for every tier (serve -> fleet -> deploy) instead of
per-subsystem ad-hoc counters.  The design is deliberately the Prometheus
client model, minus the dependency:

* a :class:`MetricsRegistry` holds **families** (one metric name + type +
  help + label names); ``family.labels(engine="r0")`` resolves a **child**
  (one label-value combination) with ``inc`` / ``set`` / ``observe``;
* children are cached, so the hot path resolves its labels once at
  construction and pays one guarded float add per event afterwards —
  instrumentation must never become the thing it measures;
* :meth:`MetricsRegistry.to_prometheus` writes text exposition format
  0.0.4 (what ``launch/serve.py --metrics-port`` serves on ``/metrics``);
  :meth:`MetricsRegistry.snapshot` is the JSON form;
* :meth:`MetricsRegistry.merged` adds registries together — the fleet
  aggregation primitive (counters/histograms add; gauges add too, which
  is only meaningful when per-replica gauges carry a replica label — the
  convention every gauge in this repo follows).

Naming scheme (see README "Observability"): every metric is prefixed
``repro_``, subsystem second (``serve``/``fleet``/``autoscale``/
``canary``/``deploy``/``activity``/``plan``), unit suffixes follow the
Prometheus convention (``_total`` counters, ``_seconds`` histograms).
"""
from __future__ import annotations

import bisect
import math
import re
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "MetricsRegistry",
    "default_registry",
    "set_default_registry",
    "DEFAULT_LATENCY_BUCKETS",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: Default histogram buckets (seconds): sub-ms to tens of seconds — spans
#: the jitted-step latencies (~ms) and drain/bind walls (~s) in one ladder.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)


def _escape_label(v: str) -> str:
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _escape_help(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace("\n", "\\n")


def _fmt(v: float) -> str:
    """Exposition number format: exact integers stay integral."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _labels_text(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{_escape_label(v)}"'
                     for n, v in zip(names, values))
    return "{" + inner + "}"


class _Counter:
    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc {amount})")
        with self._lock:
            self.value += amount


class _Gauge:
    __slots__ = ("_lock", "value")

    def __init__(self, lock: threading.Lock):
        self._lock = lock
        self.value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)


class _Histogram:
    __slots__ = ("_lock", "_bounds", "counts", "sum", "count")

    def __init__(self, lock: threading.Lock, bounds: Tuple[float, ...]):
        self._lock = lock
        self._bounds = bounds
        self.counts = [0] * (len(bounds) + 1)  # per-bucket, +Inf last
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        i = bisect.bisect_left(self._bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1


_CHILD_TYPES = {"counter": _Counter, "gauge": _Gauge, "histogram": _Histogram}


class _Family:
    """One metric name: type + help + label names + child per label set."""

    def __init__(self, kind: str, name: str, help: str,
                 labelnames: Tuple[str, ...],
                 buckets: Optional[Tuple[float, ...]] = None):
        self.kind = kind
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self.buckets = buckets
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], object] = {}

    def _make_child(self):
        if self.kind == "histogram":
            return _Histogram(self._lock, self.buckets)
        return _CHILD_TYPES[self.kind](self._lock)

    def labels(self, **labelvalues: str):
        if set(labelvalues) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, "
                f"got {tuple(sorted(labelvalues))}")
        key = tuple(str(labelvalues[n]) for n in self.labelnames)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = self._children[key] = self._make_child()
            return child

    # no-label convenience: the family itself acts as its single child
    def _solo(self):
        if self.labelnames:
            raise ValueError(
                f"{self.name} takes labels {self.labelnames}; call "
                f".labels(...) first")
        return self.labels()

    def inc(self, amount: float = 1.0) -> None:
        self._solo().inc(amount)

    def set(self, value: float) -> None:
        self._solo().set(value)

    def dec(self, amount: float = 1.0) -> None:
        self._solo().dec(amount)

    def observe(self, value: float) -> None:
        self._solo().observe(value)

    def set_exclusive(self, **labelvalues: str) -> None:
        """Gauge-info pattern: set the matching child to 1, all others 0
        (e.g. ``repro_deploy_production_info{version=...} 1``)."""
        if self.kind != "gauge":
            raise ValueError(f"{self.name}: set_exclusive is gauge-only")
        target = self.labels(**labelvalues)
        with self._lock:
            for child in self._children.values():
                child.value = 1.0 if child is target else 0.0

    def items(self) -> List[Tuple[Tuple[str, ...], object]]:
        with self._lock:
            return list(self._children.items())


class MetricsRegistry:
    """Thread-safe registry of metric families (see module docstring)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    # -- family constructors (idempotent: same spec returns the family) -----

    def _family(self, kind: str, name: str, help: str,
                labelnames: Sequence[str],
                buckets: Optional[Sequence[float]] = None) -> _Family:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        labelnames = tuple(labelnames)
        for ln in labelnames:
            if not _LABEL_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"invalid label name {ln!r} on {name}")
        b = None
        if kind == "histogram":
            b = tuple(sorted(float(x) for x in
                             (buckets or DEFAULT_LATENCY_BUCKETS)))
            if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
                raise ValueError(f"{name}: buckets must be strictly "
                                 f"increasing and non-empty, got {b}")
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.kind != kind or fam.labelnames != labelnames:
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{fam.kind}{fam.labelnames}, requested "
                        f"{kind}{labelnames}")
                return fam
            fam = _Family(kind, name, help, labelnames, buckets=b)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> _Family:
        return self._family("counter", name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> _Family:
        return self._family("gauge", name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> _Family:
        return self._family("histogram", name, help, labelnames,
                            buckets=buckets)

    def families(self) -> List[_Family]:
        with self._lock:
            return [self._families[k] for k in sorted(self._families)]

    def get(self, name: str) -> Optional[_Family]:
        with self._lock:
            return self._families.get(name)

    def value(self, name: str, **labelvalues) -> float:
        """Read one counter/gauge child's current value (0.0 if unseen)."""
        fam = self.get(name)
        if fam is None:
            return 0.0
        key = tuple(str(labelvalues.get(n, "")) for n in fam.labelnames)
        with fam._lock:
            child = fam._children.get(key)
            return float(child.value) if child is not None else 0.0

    # -- exposition ----------------------------------------------------------

    def to_prometheus(self) -> str:
        """Text exposition format 0.0.4 (one scrape body)."""
        out: List[str] = []
        for fam in self.families():
            out.append(f"# HELP {fam.name} {_escape_help(fam.help)}")
            out.append(f"# TYPE {fam.name} {fam.kind}")
            for key, child in sorted(fam.items()):
                lt = _labels_text(fam.labelnames, key)
                if fam.kind == "histogram":
                    cum = 0
                    with fam._lock:
                        counts = list(child.counts)
                        hsum, hcount = child.sum, child.count
                    for bound, n in zip(fam.buckets + (float("inf"),),
                                        counts):
                        cum += n
                        le = _labels_text(fam.labelnames + ("le",),
                                          key + (_fmt(bound),))
                        out.append(f"{fam.name}_bucket{le} {cum}")
                    out.append(f"{fam.name}_sum{lt} {_fmt(hsum)}")
                    out.append(f"{fam.name}_count{lt} {hcount}")
                else:
                    out.append(f"{fam.name}{lt} {_fmt(child.value)}")
        return "\n".join(out) + "\n"

    def snapshot(self) -> dict:
        """JSON-ready dump (what the fleet ships between processes)."""
        out: Dict[str, dict] = {}
        for fam in self.families():
            series = []
            for key, child in sorted(fam.items()):
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    with fam._lock:
                        series.append({
                            "labels": labels,
                            "buckets": {_fmt(b): n for b, n in
                                        zip(fam.buckets + (float("inf"),),
                                            child.counts)},
                            "sum": child.sum, "count": child.count})
                else:
                    series.append({"labels": labels, "value": child.value})
            out[fam.name] = {"type": fam.kind, "help": fam.help,
                             "labelnames": list(fam.labelnames),
                             "series": series}
        return out

    # -- fleet aggregation ---------------------------------------------------

    @classmethod
    def merged(cls, parts: Iterable["MetricsRegistry"]) -> "MetricsRegistry":
        """Add registries together (fleet aggregation).

        Counters and histograms add exactly.  Gauges add too — correct
        under this repo's convention that per-replica gauges carry a
        replica-identifying label (so same-name children never collide
        across replicas); same-label gauges from different parts sum,
        which a caller aggregating e.g. queue depths actually wants.
        Conflicting family definitions (type / label names) raise.
        """
        merged = cls()
        for part in parts:
            for fam in part.families():
                mfam = merged._family(fam.kind, fam.name, fam.help,
                                      fam.labelnames, buckets=fam.buckets)
                if fam.kind == "histogram" and mfam.buckets != fam.buckets:
                    raise ValueError(
                        f"{fam.name}: bucket ladders differ across parts")
                for key, child in fam.items():
                    dst = mfam.labels(**dict(zip(fam.labelnames, key)))
                    with mfam._lock:
                        if fam.kind == "histogram":
                            for i, n in enumerate(child.counts):
                                dst.counts[i] += n
                            dst.sum += child.sum
                            dst.count += child.count
                        else:
                            dst.value += child.value
        return merged


_default = MetricsRegistry()
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-wide registry every subsystem records into."""
    with _default_lock:
        return _default


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry (tests isolate through this);
    returns the previous one."""
    global _default
    with _default_lock:
        old, _default = _default, registry
        return old
