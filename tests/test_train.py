"""Optimizers, pruning schedule, LSQ quantization, encoder properties."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hyp import given, st

from repro.core.encoder import sigma_delta_decode, sigma_delta_encode
from repro.models.snn import SNNConfig, init_snn
from repro.train.lsq import dequantize, init_lsq_scales, lsq_fake_quant, quantize_to_int
from repro.train.optimizer import adamw, apply_updates, clip_by_global_norm, sgd
from repro.train.pruning import (
    block_magnitude_masks,
    magnitude_masks,
    make_mask_pytree,
    target_density_at,
)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", ["adamw", "sgd"])
def test_optimizer_converges_on_quadratic(opt):
    init_fn, update_fn = adamw(0.1) if opt == "adamw" else sgd(0.05)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = init_fn(params)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        updates, state = update_fn(g, state, params)
        params = apply_updates(params, updates)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    from repro.train.optimizer import global_norm

    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_adamw_weight_decay_shrinks():
    init_fn, update_fn = adamw(1e-2, weight_decay=0.5)
    params = {"x": jnp.asarray([1.0])}
    state = init_fn(params)
    zero_g = {"x": jnp.asarray([0.0])}
    for _ in range(50):
        updates, state = update_fn(zero_g, state, params)
        params = apply_updates(params, updates)
    assert float(params["x"][0]) < 1.0


# ---------------------------------------------------------------------------
# pruning
# ---------------------------------------------------------------------------

def test_three_phase_schedule():
    """Paper §IV-C.1: 20% dense warmup, 60% ramp, 20% fine-tune frozen."""
    total, target = 100, 0.25
    assert target_density_at(0, total, target) == 1.0
    assert target_density_at(19, total, target) == 1.0
    mid = target_density_at(50, total, target)
    assert target < mid < 1.0
    assert target_density_at(80, total, target) == pytest.approx(target)
    assert target_density_at(99, total, target) == pytest.approx(target)
    # monotone nonincreasing
    ds = [target_density_at(s, total, target) for s in range(total)]
    assert all(a >= b - 1e-9 for a, b in zip(ds, ds[1:]))


@given(st.integers(0, 2**31 - 1), st.sampled_from([0.05, 0.25, 0.5, 0.9]))
def test_magnitude_mask_exact_density(seed, density):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(40, 25)).astype(np.float32))
    m = magnitude_masks(w, density)
    got = float(m.mean())
    assert got == pytest.approx(density, abs=1.5 / w.size * 40 * 25 * 0.01 + 2e-3)
    # kept entries are the largest-magnitude ones
    kept = np.abs(np.asarray(w))[np.asarray(m) == 1]
    dropped = np.abs(np.asarray(w))[np.asarray(m) == 0]
    if kept.size and dropped.size:
        assert kept.min() >= dropped.max() - 1e-6


def test_per_layer_mask_pytree():
    params = init_snn(jax.random.PRNGKey(0), SNNConfig())
    densities = {"conv1": 0.25, "conv2": 0.20, "conv3": 0.15, "fc1": 0.20, "fc2": 0.25}
    masks = make_mask_pytree(params, densities)
    from repro.train.pruning import mask_density

    got = mask_density(masks)
    for k, v in densities.items():
        assert got[k] == pytest.approx(v, abs=0.02), k


def test_block_pruning_yields_block_tile_density():
    """The TPU co-design: block pruning makes tile density == density,
    unlike unstructured pruning (tile density ~1)."""
    from repro.core.sparse_format import block_sparse_from_dense

    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(5, 32, 64)).astype(np.float32))
    target = 0.25
    m_block = block_magnitude_masks(w, target, block_oc=8, block_k=32)
    m_unstruct = magnitude_masks(w, target)
    bs_block = block_sparse_from_dense(np.asarray(w * m_block), block_oc=8, block_k=32)
    bs_unstr = block_sparse_from_dense(np.asarray(w * m_unstruct), block_oc=8, block_k=32)
    assert bs_block.tile_density == pytest.approx(target, abs=0.05)
    assert bs_unstr.tile_density > 0.9  # unstructured does not empty tiles
    assert float(m_block.mean()) == pytest.approx(target, abs=0.05)


# ---------------------------------------------------------------------------
# LSQ
# ---------------------------------------------------------------------------

def test_lsq_fake_quant_error_bound():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32) * 0.1)
    step = jnp.asarray(0.001)
    wq = lsq_fake_quant(w, step, bits=16)
    assert float(jnp.max(jnp.abs(wq - w))) <= float(step) / 2 + 1e-7


def test_lsq_gradients_flow_to_step_and_weights():
    w = jnp.asarray(np.linspace(-0.5, 0.5, 32).astype(np.float32))
    step = jnp.asarray(0.01)
    gw, gs = jax.grad(lambda w, s: jnp.sum(lsq_fake_quant(w, s) ** 2), argnums=(0, 1))(
        w, step
    )
    assert float(jnp.abs(gw).sum()) > 0
    assert np.isfinite(float(gs))


def test_quantize_roundtrip_int16():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(100,)).astype(np.float32) * 0.2)
    step = jnp.asarray(2.0 * float(jnp.mean(jnp.abs(w))) / np.sqrt(2**15 - 1))
    codes = quantize_to_int(w, step, bits=16)
    assert codes.dtype == jnp.int16
    w2 = dequantize(codes, step)
    assert float(jnp.max(jnp.abs(w2 - w))) <= float(step) / 2 + 1e-7


def test_lsq_scales_init_structure():
    params = init_snn(jax.random.PRNGKey(0), SNNConfig())
    scales = init_lsq_scales(params)
    assert len(scales["conv"]) == 3 and len(scales["fc"]) == 2
    assert all(float(s) > 0 for s in scales["conv"] + scales["fc"])


def test_lsq_scales_floor_on_all_zero_layer():
    """A fully-pruned (all-zero) layer must not init a zero step.

    ``init_lsq_scales`` derives each step from ``2*mean|w|/sqrt(qmax)``;
    an all-zero weight gives step 0, and every downstream ``w / step``
    (fake-quant, integer conversion) then emits NaN/inf.  The init floors
    at ``STEP_FLOOR`` instead, so the degenerate layer quantizes to all
    zeros without poisoning the pytree.
    """
    from repro.train.lsq import STEP_FLOOR

    params = init_snn(jax.random.PRNGKey(0), SNNConfig())
    params["conv"][1]["w"] = jnp.zeros_like(params["conv"][1]["w"])
    scales = init_lsq_scales(params, bits=16)
    for s in scales["conv"] + scales["fc"]:
        # the floor lives in the pytree's float32 precision
        assert np.isfinite(float(s)) and float(s) >= np.float32(STEP_FLOOR)
    step = scales["conv"][1]
    wq = lsq_fake_quant(params["conv"][1]["w"], step, bits=16)
    assert np.all(np.isfinite(np.asarray(wq))) and not np.any(np.asarray(wq))
    codes = quantize_to_int(params["conv"][1]["w"], step, bits=16)
    assert not np.any(np.asarray(codes))


# ---------------------------------------------------------------------------
# sigma-delta encoder
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.sampled_from([8, 16, 64]))
def test_sigma_delta_reconstruction_bound(seed, osr):
    """First-order sigma-delta: mean reconstruction error is O(1/OSR)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(64).astype(np.float32))
    bits = sigma_delta_encode(x, osr)
    assert set(np.unique(np.asarray(bits))) <= {0.0, 1.0}
    rec = sigma_delta_decode(bits)
    assert float(jnp.max(jnp.abs(rec - x))) <= 1.5 / osr + 1e-6


def test_sigma_delta_np_matches_jax():
    from repro.data.pipeline import sigma_delta_encode_np
    from repro.core.encoder import encode_frames

    rng = np.random.default_rng(0)
    iq = rng.normal(size=(3, 2, 32)).astype(np.float32)
    got = sigma_delta_encode_np(iq, 8)                    # (B, T, 2, L)
    want = np.asarray(jax.vmap(lambda s: encode_frames(s, 8))(jnp.asarray(iq)))
    # encode_frames returns (B) leading? vmap gives (B, T, 2, L) with T axis 1
    np.testing.assert_allclose(got, want, atol=1e-6)
