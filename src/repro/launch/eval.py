"""Robustness-evaluation driver: ``python -m repro.launch.eval``.

Sweeps the :mod:`repro.channel` scenario suite x an SNR grid x one or more
execution backends through :func:`repro.eval.evaluate_robustness`, prints
the accuracy surface, and writes the full JSON report (per-(scenario, SNR)
accuracy + per-modulation confusion matrices).

Examples::

    # default suite, goap backend, fresh 50%-density weights (paper model)
    python -m repro.launch.eval --suite default --backend goap

    # all four backends on the reduced config with cross-backend agreement
    python -m repro.launch.eval --suite quick --backend \\
        dense,goap,pallas,stream --reduced --frames 16

    # a trained model from the lifecycle registry
    python -m repro.launch.eval --registry ./registry --model amc@production
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax

from repro.channel import SUITES
from repro.eval import RobustnessConfig, evaluate_robustness, format_report

__all__ = ["main"]

# A reduced config for smoke runs: same topology family as the paper
# model, ~100x cheaper to bind and sweep.
REDUCED_SMOKE_CFG = dict(
    conv_specs=((5, 2, 8), (5, 8, 16)),
    pool=2,
    fc_specs=((128, 32), (32, 11)),
    input_width=32,
    timesteps=4,
    n_classes=11,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--suite", default="default",
                    help=f"scenario suite ({', '.join(sorted(SUITES))}) or "
                         "comma-joined scenario names")
    ap.add_argument("--backend", default="goap",
                    help="backend, or comma-joined list (first is primary; "
                         "extra backends add a cross-backend agreement "
                         "check)")
    ap.add_argument("--snr", default="-10,0,10,18",
                    help="comma-joined SNR grid in dB")
    ap.add_argument("--frames", type=int, default=64,
                    help="frames per (scenario, SNR) cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--density", type=float, default=0.5,
                    help="mask density for fresh random weights")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced smoke config instead of the paper model")
    ap.add_argument("--no-clean", action="store_true",
                    help="skip the legacy-channel clean reference section")
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="evaluate a model from a deploy registry")
    ap.add_argument("--model", default="amc", metavar="NAME[@VER|@ALIAS]")
    ap.add_argument("--out", default="robustness_report.json")
    args = ap.parse_args(argv)

    lsq_scales, quant_bits = None, 16
    if args.registry:
        from repro.deploy import ModelRegistry

        loaded = ModelRegistry(args.registry).load(args.model)
        params, masks, model_cfg = loaded.params, loaded.masks, loaded.cfg
        lsq_scales = loaded.lsq_scales
        quant_bits = loaded.version.quant_bits
        print(f"registry: evaluating {loaded.version.spec} "
              f"(digest {loaded.version.digest[:12]}…)")
    else:
        from repro.configs.saocds_amc import CONFIG
        from repro.models.snn import SNNConfig, init_snn
        from repro.train.pruning import make_mask_pytree

        model_cfg = (SNNConfig(**REDUCED_SMOKE_CFG) if args.reduced
                     else CONFIG)
        params = init_snn(jax.random.PRNGKey(args.seed), model_cfg)
        masks = make_mask_pytree(params, args.density)

    quant_fn = None
    if lsq_scales is not None:
        from repro.train.lsq import make_serving_quant_fn

        quant_fn = make_serving_quant_fn(lsq_scales, quant_bits)

    eval_cfg = RobustnessConfig(
        suite=args.suite,
        snr_grid=tuple(float(s) for s in args.snr.split(",")),
        frames_per_cell=args.frames,
        backends=tuple(b.strip() for b in args.backend.split(",")),
        seed=args.seed,
        include_clean=not args.no_clean,
    )
    report = evaluate_robustness(params, model_cfg, eval_cfg, masks=masks,
                                 quant_fn=quant_fn)
    print(format_report(report))
    print("wall per backend: " + ", ".join(
        f"{b}={w:.1f}s" for b, w in report["wall_s_by_backend"].items()))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(report, indent=1))
    print(f"wrote {out}")
    if "agreement" in report and not report["agreement"]["agrees"]:
        print("FAIL: backends disagree on impaired frames")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
