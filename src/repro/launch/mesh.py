"""Production mesh definitions (TPU v5e target).

Functions, not module-level constants: importing this module never touches
jax device state, so tests/benches keep their 1-CPU view and only
``dryrun.py`` (which sets ``xla_force_host_platform_device_count=512``
before any jax import) ever builds the full meshes.

Axes:
    single-pod  (16, 16)      -> ("data", "model")       256 chips
    multi-pod   (2, 16, 16)   -> ("pod", "data", "model") 512 chips

``pod`` composes with ``data`` for batch sharding (pure DP across pods —
gradient all-reduce is the only cross-pod collective, matching the
slow-inter-pod/fast-intra-pod DCN/ICI hierarchy).  ``model`` carries
TP/SP/EP (see distributed/sharding.py).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.compat import AxisType, make_mesh

__all__ = ["make_production_mesh", "make_dev_mesh", "HW"]


# TPU v5e hardware constants used by the roofline analysis (per chip).
HW = {
    "name": "tpu-v5e",
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw": 50e9,                # B/s per link
    "hbm_bytes": 16 * 1024**3,
}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_dev_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh for CPU multi-device tests (needs host_device_count)."""
    return make_mesh(
        (data, model), ("data", "model"), axis_types=(AxisType.Auto,) * 2
    )
