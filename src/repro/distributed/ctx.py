"""Activation-sharding constraint context.

Models stay mesh-agnostic: they call :func:`constrain_acts` /
:func:`constrain_logits` at the canonical cut points (post-embedding,
between layers, pre-unembedding).  Outside a context these are identity;
inside ``activation_constraints(...)`` they apply
``jax.lax.with_sharding_constraint`` with the registered specs.

This is the software analogue of the paper's fixed output-channel
dataflow: the residual stream's layout between layers is pinned once, so
XLA's sharding propagation cannot drift layer by layer — every layer
hands the next one the exact same distribution, like the accelerator's
channel-ordered stream (DESIGN.md §2).
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

__all__ = [
    "activation_constraints",
    "constrain_acts",
    "constrain_logits",
    "constrain_head",
    "current_act_sharding",
]

_STACK: list = []  # (act_sharding, logits_sharding, head_sharding)


@contextlib.contextmanager
def activation_constraints(act_sharding=None, logits_sharding=None,
                           head_sharding=None):
    """Register shardings for the residual stream, the logits, and the
    pre-unembedding residual (``head``).

    Any may be ``None`` (identity).  Shardings are
    ``jax.sharding.NamedSharding`` (or anything accepted by
    ``with_sharding_constraint``) over (batch, seq, feature) arrays.

    ``head_sharding`` exists because the unembedding wants the residual
    sequence-REPLICATED: with a sequence-sharded ``x`` and vocab-sharded
    ``d_logits``, XLA's only consistent contraction for ``d_unemb`` is to
    all-gather the full (B, S, V) logits grad — 39.8 GB/device on the
    qwen1.5-0.5b train_4k dry-run.  Gathering the (B, S, d) residual
    instead is ~150x smaller (Megatron does exactly this before the LM
    head).
    """
    _STACK.append((act_sharding, logits_sharding, head_sharding))
    try:
        yield
    finally:
        _STACK.pop()


def current_act_sharding():
    return _STACK[-1][0] if _STACK else None


def _apply(x: jax.Array, sharding) -> jax.Array:
    if sharding is None:
        return x
    # Drop trailing spec dims beyond x's rank (decode steps are (B, 1, d)
    # like train acts, so rank always matches; guard anyway).
    return jax.lax.with_sharding_constraint(x, sharding)


def constrain_acts(x: jax.Array) -> jax.Array:
    """Pin the residual-stream layout (batch, seq, d_model)."""
    if not _STACK:
        return x
    return _apply(x, _STACK[-1][0])


def constrain_logits(x: jax.Array) -> jax.Array:
    """Pin the logits layout (batch, seq, vocab)."""
    if not _STACK:
        return x
    return _apply(x, _STACK[-1][1])


def constrain_head(x: jax.Array) -> jax.Array:
    """Pin the pre-unembedding residual layout (batch, seq, d_model)."""
    if not _STACK:
        return x
    return _apply(x, _STACK[-1][2])


def constrain_expert(x: jax.Array, e_axis: int) -> jax.Array:
    """Pin an MoE dispatch tensor so the expert dim shards over `model`
    (expert parallelism): the expert FFN einsums then keep the e-sharded
    weights local.  Without the anchor XLA all-gathered the full expert
    weights every layer (~20 GB/layer on llama4-scout train).  No-op when
    E doesn't divide the model axis (TP-inside-experts handles those)."""
    if not _STACK:
        return x
    act = _STACK[-1][0]
    if act is None or not hasattr(act, "mesh"):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = act.mesh
    model_n = mesh.shape.get("model", 1)
    if model_n <= 1 or x.shape[e_axis] % model_n != 0:
        return x
    batch_ax = act.spec[0] if len(act.spec) else None
    dims = [None] * x.ndim
    dims[0] = batch_ax
    dims[e_axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*dims)))


def constrain_seq_gathered(x: jax.Array) -> jax.Array:
    """Explicitly replicate a (B, S, ...) tensor over the model axis
    (keeping the batch axis): one clean all-gather.  Used for K/V before
    kv-chunked attention — slicing a sequence-sharded K with a loop-
    variable offset makes XLA mask+push the partial through the score dot
    and ALL-REDUCE the full (B, H, S, qc) scores (5.4 GB x 1024 on the
    whisper prefill cell)."""
    if not _STACK:
        return x
    act = _STACK[-1][0]
    if act is None or not hasattr(act, "mesh"):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = act.mesh
    batch_ax = act.spec[0] if len(act.spec) else None
    spec = P(batch_ax, *(None,) * (x.ndim - 1))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_hidden(x: jax.Array) -> jax.Array:
    """Pin a (B, S, hidden) stream whose LAST dim is TP-wide (an RG-LRU /
    MLP inner stream, not the residual): batch keeps the registered act
    sharding's batch axis, sequence replicates (Megatron-style inside the
    block), hidden shards over `model` when divisible.  Without this XLA
    dropped the batch sharding of the w-wide RG-LRU stream (1.07 GB f32
    buffers on recurrentgemma train)."""
    if not _STACK:
        return x
    act = _STACK[-1][0]
    if act is None or not hasattr(act, "mesh"):
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = act.mesh
    batch_ax = act.spec[0] if len(act.spec) else None
    model_n = mesh.shape.get("model", 1)
    h_ax = "model" if (model_n > 1 and x.shape[-1] % model_n == 0) else None
    spec = P(batch_ax, *(None,) * (x.ndim - 2), h_ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
