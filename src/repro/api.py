"""Public facade: one model definition, four interchangeable backends.

Everything an application needs to train, compress, and serve the paper's
SNN AMC classifier through the unified layer-graph API:

    from repro.api import SNNConfig, compile_snn, init_snn

    cfg = SNNConfig()
    program = compile_snn(cfg)                    # LayerSpec graph, compiled once
    params = init_snn(jax.random.PRNGKey(0), cfg)

    logits = program.apply(params, frames)                     # dense oracle
    logits = program.apply(params, frames, backend="goap")     # COO streaming
    logits = program.apply(params, frames, backend="pallas")   # TPU block-sparse
    logits, counters = program.apply(params, frames, backend="stream",
                                     return_counters=True)     # Tables I/III

New execution strategies plug in via ``register_backend`` without touching
the model definition.
"""
from __future__ import annotations

from repro.models.graph import (
    BoundProgram,
    Conv1dLIF,
    FCLIF,
    LayerSpec,
    MaxPool,
    Readout,
    SNNProgram,
    available_backends,
    build_layer_graph,
    compile_snn,
    get_backend,
    register_backend,
    stream_totals,
)
from repro.models.snn import (
    SNNConfig,
    density_report,
    init_snn,
    param_count,
    sparsify_params,
)

__all__ = [
    # graph / program
    "LayerSpec",
    "Conv1dLIF",
    "MaxPool",
    "FCLIF",
    "Readout",
    "build_layer_graph",
    "SNNProgram",
    "BoundProgram",
    "compile_snn",
    # backend registry
    "register_backend",
    "available_backends",
    "get_backend",
    "stream_totals",
    # model definition / params
    "SNNConfig",
    "init_snn",
    "sparsify_params",
    "param_count",
    "density_report",
]
