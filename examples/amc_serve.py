"""Serving example: streaming AMC classification (the paper's deployment).

Trains briefly so predictions are meaningful, prunes to 50%, then runs the
batched streaming engine over a pile of I/Q requests — reporting
throughput, accuracy, and the activity counters that drive the power model
(accumulations + fetched bits, paper §V).

Run:  PYTHONPATH=src python examples/amc_serve.py [--requests 64]
"""
import argparse

import numpy as np

from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.core.cost_model import PAPER_TABLE5, PowerModel
from repro.data.radioml import MODULATIONS, generate_batch
from repro.serve.engine import AMCServeEngine
from repro.train.trainer import SNNTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--train-steps", type=int, default=120)
    ap.add_argument("--density", type=float, default=0.5)
    args = ap.parse_args()

    print(f"pre-training {args.train_steps} steps at density {args.density}")
    trainer = SNNTrainer(SNN_CONFIG, TrainerConfig(
        total_steps=args.train_steps, batch_size=48, lr=2e-3,
        final_density=args.density, snr_db=10.0))
    trainer.run()

    engine = AMCServeEngine(trainer.params, SNN_CONFIG, masks=trainer.masks,
                            batch_size=16, count_activity=True)
    iq, labels, _ = generate_batch(seed=4242, batch=args.requests, snr_db=10.0)
    preds = engine.classify(iq)
    st = engine.stats
    acc = float((preds == labels).mean())
    print(f"served {st.requests} requests in {st.batches} batches: "
          f"{st.throughput_samples_per_s() / 1e3:.1f} kS/s (CPU), "
          f"accuracy {acc:.3f}")
    print("sample predictions:",
          [MODULATIONS[p] for p in preds[:6]], "...")
    print(f"activity: {st.accumulations} accumulations, "
          f"{st.fetched_bits} fetched bits")
    # feed the activity into the paper-calibrated power model
    pm = PowerModel(c_acc=1e-9, c_bit=1e-10, c_util=0.3)
    watts = pm.predict(st.accumulations / max(st.wall_s, 1e-9),
                       st.fetched_bits / max(st.wall_s, 1e-9), 0.5)
    print(f"activity-model dynamic power (uncalibrated demo): {watts:.3f} W "
          f"(paper Table V at 50%: {PAPER_TABLE5[0.5][0]} W)")


if __name__ == "__main__":
    main()
