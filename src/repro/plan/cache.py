"""Content-addressed plan cache: in-memory LRU + on-disk artifact store.

Two tiers with different lifetimes:

* **plans** (memory only) — a compiled :class:`~repro.plan.compile.
  ExecutionPlan` holds live cells closing over device arrays, so it is
  cached per-process, keyed by the plan digest (config + assignment +
  effective weight/mask/LIF bytes).
* **layer artifacts** (memory + disk) — the expensive numpy derivations
  (COO kernels, Algorithm-2 schedules, block-sparse tilings) depend only
  on one layer's effective weights, so they are keyed per layer *without*
  the backend name: ``goap`` and ``stream`` share one COO entry, and a
  process restart (serve engine redeploy) reloads them from disk instead
  of rebuilding.

The disk directory defaults to ``~/.cache/repro/plans`` and can be moved
with ``REPRO_PLAN_CACHE_DIR`` (set it empty to disable the disk tier).
All disk I/O is best-effort: a corrupt or unwritable cache degrades to a
rebuild, never to an error.
"""
from __future__ import annotations

import collections
import os
import pathlib
import pickle
import tempfile
import threading
from typing import Any, Dict, Optional

__all__ = ["PlanCache", "default_cache", "set_default_cache",
           "default_store_root"]

ENV_DIR = "REPRO_PLAN_CACHE_DIR"
_DEFAULT_DIR = os.path.join("~", ".cache", "repro", "plans")


def default_store_root() -> pathlib.Path:
    """Root of the on-disk artifact stores (``~/.cache/repro`` by default).

    The plan cache's disk tier lives under ``<root>/plans``; sibling
    stores — the model registry's version tree in particular — default to
    directories next to it so one cache root holds every persisted
    artifact tier.  Follows ``REPRO_PLAN_CACHE_DIR`` when it is set (the
    registry then lands next to the relocated plan tier).
    """
    d = os.environ.get(ENV_DIR) or _DEFAULT_DIR
    return pathlib.Path(d).expanduser().parent


class PlanCache:
    # NOTE: every cached plan's cells close over device arrays of the
    # effective weights, so ``max_plans`` bounds how many full (possibly
    # stale) weight sets stay alive — keep it small; raise it only for
    # workloads that genuinely alternate between a few weight sets.
    def __init__(self, disk_dir: Optional[str] = None, *,
                 max_plans: int = 8, max_layer_entries: int = 512,
                 max_disk_entries: int = 512):
        if disk_dir is None:
            disk_dir = os.environ.get(ENV_DIR, _DEFAULT_DIR)
        self.disk_dir = pathlib.Path(disk_dir).expanduser() if disk_dir else None
        self.max_plans = max_plans
        self.max_layer_entries = max_layer_entries
        self.max_disk_entries = max_disk_entries
        self._plans: "collections.OrderedDict[str, Any]" = collections.OrderedDict()
        self._layers: "collections.OrderedDict[str, Dict[str, Any]]" = collections.OrderedDict()
        self._lock = threading.RLock()
        self.stats: collections.Counter = collections.Counter()

    # -- whole plans (memory tier) ------------------------------------------

    def get_plan(self, digest: str):
        with self._lock:
            plan = self._plans.get(digest)
            if plan is not None:
                self._plans.move_to_end(digest)
                self.stats["plan_hits"] += 1
            else:
                self.stats["plan_misses"] += 1
            return plan

    def put_plan(self, digest: str, plan) -> None:
        with self._lock:
            self._plans[digest] = plan
            self._plans.move_to_end(digest)
            while len(self._plans) > self.max_plans:
                self._plans.popitem(last=False)

    # -- per-layer artifacts (memory + disk tiers) --------------------------

    def _layer_path(self, key: str) -> Optional[pathlib.Path]:
        return self.disk_dir / f"{key}.pkl" if self.disk_dir else None

    @staticmethod
    def _stored_form(artifacts: Dict[str, Any]) -> Dict[str, Any]:
        """What the cache retains: the expensive derivations only.

        Effective weights are re-derived from the live params on every
        compile (they feed the content hash before the cache is even
        consulted), so keeping ``w_eff`` copies in either tier would only
        pin stale weight sets in memory / bloat the disk tier.
        """
        return {k: v for k, v in artifacts.items() if k != "w_eff"}

    def get_artifacts(self, key: str) -> Optional[Dict[str, Any]]:
        # a *copy* is returned: callers mutate their dict freely while
        # concurrent compiles sharing the entry stay isolated (values are
        # immutable artifact objects, so sharing them by reference is safe)
        with self._lock:
            hit = self._layers.get(key)
            if hit is not None:
                self._layers.move_to_end(key)
                self.stats["layer_memory_hits"] += 1
                return dict(hit)
        path = self._layer_path(key)
        if path is not None and path.exists():
            try:
                with open(path, "rb") as f:
                    artifacts = pickle.load(f)
            except Exception:  # noqa: BLE001 — corrupt entry -> rebuild
                self.stats["layer_disk_errors"] += 1
            else:
                if isinstance(artifacts, dict):
                    self.stats["layer_disk_hits"] += 1
                    with self._lock:
                        self._layers[key] = dict(artifacts)
                        self._trim_layers()
                    return artifacts
        self.stats["layer_misses"] += 1
        return None

    def put_artifacts(self, key: str, artifacts: Dict[str, Any]) -> None:
        stored = self._stored_form(artifacts)
        if not stored:
            return
        with self._lock:
            self._layers[key] = stored
            self._layers.move_to_end(key)
            self._trim_layers()
        path = self._layer_path(key)
        if path is None:
            return
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    pickle.dump(stored, f, protocol=pickle.HIGHEST_PROTOCOL)
                os.replace(tmp, path)  # atomic: readers never see partials
            finally:
                if os.path.exists(tmp):
                    os.unlink(tmp)
            self._trim_disk()
        except Exception:  # noqa: BLE001 — disk tier is best-effort
            self.stats["layer_disk_errors"] += 1

    def _trim_layers(self) -> None:
        while len(self._layers) > self.max_layer_entries:
            self._layers.popitem(last=False)

    def _trim_disk(self) -> None:
        """Bound the disk tier: evict least-recently-written entries."""
        entries = sorted(self.disk_dir.glob("*.pkl"),
                         key=lambda p: p.stat().st_mtime)
        for p in entries[: max(0, len(entries) - self.max_disk_entries)]:
            try:
                p.unlink()
            except OSError:
                pass

    # -- maintenance --------------------------------------------------------

    def clear(self, *, memory_only: bool = False) -> None:
        with self._lock:
            self._plans.clear()
            self._layers.clear()
        if not memory_only and self.disk_dir is not None and self.disk_dir.exists():
            for p in self.disk_dir.glob("*.pkl"):
                try:
                    p.unlink()
                except OSError:
                    pass


_default: Optional[PlanCache] = None
_default_lock = threading.Lock()


def default_cache() -> PlanCache:
    """Process-wide cache used when ``compile_plan`` gets no explicit one."""
    global _default
    with _default_lock:
        if _default is None:
            _default = PlanCache()
        return _default


def set_default_cache(cache: Optional[PlanCache]) -> None:
    """Swap (or reset, with None) the process-wide default cache."""
    global _default
    with _default_lock:
        _default = cache
