"""Offline float -> fixed-point conversion (paper §IV-C.2, FPGA datapath).

This module is the *bind-time* half of the ``fixed`` backend: it turns
LSQ-trained (or calibrated) float weights and per-neuron LIF parameters
into the integer constants the hardware datapath consumes.  The runtime
halves — the jnp cells in :mod:`repro.fixed.backend` and the NumPy golden
interpreter in :mod:`repro.fixed.golden` — both consume the structures
built here, so the conversion is a single source of truth and any
backend/golden disagreement is a *datapath* bug, never a conversion skew.

Number formats (Jelly-style Qm.n, see README "Fixed-point hardware-parity
tier"):

* weights      — int8/int16 codes; one per-tensor step size ``s`` per
  layer (LSQ-trained, or max-abs calibrated when no LSQ state exists).
* currents     — int32 accumulators in *code units* (spike in {0,1} times
  weight code), i.e. one code unit = ``s``.
* membrane     — int16, in *membrane units* of ``s * 2**acc_shift``:
  currents enter the membrane through an arithmetic right shift chosen so
  the quantized threshold lands near ``TARGET_VTH`` (12-bit headroom
  inside the int16 membrane).
* leak         — ``v - (v >> k)`` approximates ``alpha * v`` with
  ``k = round(-log2(1 - alpha))`` per neuron (shift-based decay).

All conversion arithmetic is float32 (matching what jnp would compute) so
codes derived here and fake-quant values computed on device agree bit for
bit: ``round(fakequant(w) / s) == clip(round(w / s))`` exactly, because
``fakequant(w) / s`` recovers the integer code without rounding error in
float32 for |code| < 2**23.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

import jax.numpy as jnp

from repro.train.lsq import lsq_fake_quant, make_serving_quant_fn

__all__ = [
    "I16_MIN",
    "I16_MAX",
    "TARGET_VTH",
    "FIXED_DEFAULT_BITS",
    "QuantizedLayer",
    "FixedLIF",
    "FixedQuantFn",
    "calibrate_step",
    "quantize_codes",
    "lif_to_fixed",
    "derive_fixed_layer",
    "fixed_logit_scale",
    "serving_quant_fn",
    "assignment_uses_fixed",
]

I16_MIN = -(2 ** 15)
I16_MAX = 2 ** 15 - 1
# Quantized-threshold target in membrane units: leaves ~3 bits of int16
# headroom above threshold before the membrane write-back saturates.
TARGET_VTH = 4096
MAX_ACC_SHIFT = 24
MAX_LEAK_SHIFT = 15
FIXED_DEFAULT_BITS = 16
_STEP_FLOOR = 1e-8


@dataclasses.dataclass(frozen=True)
class QuantizedLayer:
    """Integer weight codes plus the step size they were derived with.

    The (codes, step) pair travels together: the membrane/threshold
    constants of :class:`FixedLIF` are always derived from *this* step, so
    the datapath stays self-consistent even if an equivalent
    representation with a different step produced the same float weights.
    """

    codes: np.ndarray  # int8 (bits<=8) or int16 codes, original weight shape
    step: float        # float32-exact step size (one code unit)
    bits: int


@dataclasses.dataclass(frozen=True)
class FixedLIF:
    """Per-neuron integer LIF constants (FPGA register file contents)."""

    leak_shift: np.ndarray  # int32, per neuron: alpha*v ~= v - (v >> k)
    vth: np.ndarray         # int32 threshold, membrane units
    theta: np.ndarray       # int32 soft-reset amount, membrane units
    acc_shift: int          # current (code units) >> acc_shift -> membrane
    mem_scale: float        # float value of one membrane unit


def _f32(x) -> np.ndarray:
    return np.asarray(x, dtype=np.float32)


def calibrate_step(w, bits: int = FIXED_DEFAULT_BITS) -> float:
    """Max-abs step size for layers without trained LSQ state.

    Returns a float32-exact value with a floor so all-zero (fully pruned)
    layers still get a usable format instead of a degenerate zero step.
    """
    qmax = 2 ** (bits - 1) - 1
    peak = float(np.max(np.abs(_f32(w)))) if np.size(w) else 0.0
    return float(np.float32(max(peak / qmax, _STEP_FLOOR)))


def quantize_codes(w_eff, step: float, bits: int = FIXED_DEFAULT_BITS) -> np.ndarray:
    """Float weights -> integer codes (round-half-even, saturating clip)."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    codes = np.clip(np.round(_f32(w_eff) / np.float32(step)), qmin, qmax)
    return codes.astype(np.int8 if bits <= 8 else np.int16)


def lif_to_fixed(lif, step: float) -> FixedLIF:
    """Convert per-neuron float LIF parameters to the integer register set.

    ``step`` is the layer's weight step size (one current code unit).  The
    accumulator shift is chosen per layer so the mean quantized threshold
    lands near :data:`TARGET_VTH` membrane units, keeping thresholds and
    soft-reset amounts well inside int16 while preserving sub-threshold
    resolution.
    """
    alpha = 1.0 / (1.0 + np.exp(-np.asarray(lif.alpha_logit, np.float64)))
    one_minus = np.maximum(1.0 - alpha, 2.0 ** -20)
    leak_shift = np.clip(np.round(-np.log2(one_minus)), 0, MAX_LEAK_SHIFT)
    leak_shift = leak_shift.astype(np.int32)

    vth_units = np.asarray(lif.v_th, np.float64) / float(step)
    mean_vth = float(np.mean(np.abs(vth_units)))
    ratio = max(mean_vth, 1.0) / TARGET_VTH
    acc_shift = int(np.clip(np.floor(np.log2(ratio)) if ratio > 1.0 else 0,
                            0, MAX_ACC_SHIFT))
    scale = float(2 ** acc_shift)
    vth_q = np.round(vth_units / scale).astype(np.int32)
    theta_q = np.round(
        np.asarray(lif.theta, np.float64) / float(step) / scale).astype(np.int32)
    return FixedLIF(leak_shift=leak_shift, vth=vth_q, theta=theta_q,
                    acc_shift=acc_shift, mem_scale=float(step) * scale)


class FixedQuantFn:
    """Serving ``quant_fn`` for the fixed tier.

    Plays both roles the bind paths need:

    * ``__call__(w)`` — fake-quantization, value-identical to
      :func:`repro.train.lsq.lsq_fake_quant`, so the plan compiler's
      content hashing and any float backend racing against ``fixed`` see
      exactly the weights the integer datapath represents.  Like
      :func:`make_serving_quant_fn` it walks the weighted layers in graph
      order via a stateful index (wrapping modulo the layer count), so use
      a fresh instance per bind *or* rely on whole-pass alignment.
    * ``step_for(group, index, w)`` — stateless per-layer step lookup used
      by the fixed backend factory and the golden builder: the trained LSQ
      step when available, max-abs calibration from ``w`` otherwise.
    """

    def __init__(self, lsq_scales: Optional[Dict] = None,
                 bits: int = FIXED_DEFAULT_BITS):
        if bits not in (8, 16):
            raise ValueError(f"fixed tier supports 8- or 16-bit weights, got {bits}")
        self.lsq_scales = lsq_scales
        self.bits = int(bits)
        self._flat = (list(lsq_scales["conv"]) + list(lsq_scales["fc"])
                      if lsq_scales is not None else None)
        self._idx = 0

    def reset(self) -> None:
        """Rewind the layer-order index (start of a fresh bind pass)."""
        self._idx = 0

    def step_for(self, group: str, index: int, w) -> float:
        if self.lsq_scales is None:
            return calibrate_step(w, self.bits)
        s = float(np.float32(self.lsq_scales[group][index]))
        return float(np.float32(max(s, _STEP_FLOOR)))

    def __call__(self, w):
        if self._flat is None:
            s = calibrate_step(w, self.bits)
        else:
            s = float(np.float32(self._flat[self._idx % len(self._flat)]))
            s = float(np.float32(max(s, _STEP_FLOOR)))
            self._idx += 1
        qmax = 2 ** (self.bits - 1) - 1
        qmin = -(2 ** (self.bits - 1))
        return jnp.clip(jnp.round(w / s), qmin, qmax) * jnp.float32(s)


def _group_of(kind_or_group: str) -> str:
    # accept either a layer-graph kind ("conv_lif"/"fc_lif") or the param
    # group name ("conv"/"fc")
    if kind_or_group in ("conv", "conv_lif"):
        return "conv"
    if kind_or_group in ("fc", "fc_lif"):
        return "fc"
    raise ValueError(f"no fixed-point conversion for layer kind {kind_or_group!r}")


def derive_fixed_layer(group: str, index: int, w, mask=None, quant_fn=None,
                       w_eff=None, bits: Optional[int] = None) -> QuantizedLayer:
    """Derive one layer's integer weight codes.

    ``w_eff`` (the masked + fake-quantized float weights) may be passed in
    when already computed (plan-compiler artifact); otherwise it is derived
    here exactly the way :func:`repro.models.graph._effective_weight` does.
    The step size comes from ``quant_fn.step_for`` when a
    :class:`FixedQuantFn` drives the bind, else from max-abs calibration of
    ``w_eff`` — in both cases ``round(w_eff / step)`` recovers the integer
    codes exactly (see module docstring).
    """
    group = _group_of(group)
    masked = np.asarray(w)
    if mask is not None:
        masked = masked * np.asarray(mask)
    if w_eff is None:
        w_eff = np.asarray(quant_fn(masked)) if quant_fn is not None else masked
    else:
        w_eff = np.asarray(w_eff)
    if isinstance(quant_fn, FixedQuantFn):
        step = quant_fn.step_for(group, index, masked)
        bits = quant_fn.bits
    else:
        bits = int(bits or FIXED_DEFAULT_BITS)
        step = calibrate_step(w_eff, bits)
    return QuantizedLayer(codes=quantize_codes(w_eff, step, bits),
                          step=step, bits=bits)


def fixed_logit_scale(params, cfg, masks=None, quant_fn=None) -> float:
    """Float value of one logit unit of the fixed datapath.

    With a ``current_sum`` readout the fixed logits are int32 sums of the
    last FC layer's currents in that layer's code units, so multiplying by
    its step size lands them on the float backends' logit scale (argmax is
    invariant either way).  ``spike_count`` readouts already emit unit
    spikes — scale 1.  Exact for :class:`FixedQuantFn` and for plain
    calibration; for other quant closures the calibration here matches the
    backend's because both calibrate from the same effective weights.
    """
    if cfg.readout != "current_sum":
        return 1.0
    i = len(params["fc"]) - 1
    w = np.asarray(params["fc"][i]["w"])
    if masks is not None:
        w = w * np.asarray(masks["fc"][i])
    if isinstance(quant_fn, FixedQuantFn):
        return quant_fn.step_for("fc", i, w)
    return calibrate_step(w, FIXED_DEFAULT_BITS)


def assignment_uses_fixed(assignment) -> bool:
    """True when a plan assignment routes any layer to the fixed backend."""
    if isinstance(assignment, str):
        return assignment == "fixed"
    if isinstance(assignment, dict):
        return "fixed" in assignment.values()
    return False


def serving_quant_fn(lsq_scales, quant_bits: int = FIXED_DEFAULT_BITS,
                     assignment=None):
    """The one rule for which quant_fn a serving bind gets.

    Fixed assignments always get a :class:`FixedQuantFn` (it calibrates
    when no LSQ state exists); float assignments keep the existing
    behavior — the trained fake-quant closure with LSQ state, nothing
    without.  Engine and registry share this helper so their plan digests
    agree and prewarmed caches hit.
    """
    if assignment_uses_fixed(assignment):
        return FixedQuantFn(lsq_scales, quant_bits)
    if lsq_scales is None:
        return None
    return make_serving_quant_fn(lsq_scales, quant_bits)


# re-export for golden/backend symmetry checks in tests
_ = lsq_fake_quant
