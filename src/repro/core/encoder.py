"""Sigma-Delta spike encoding of I/Q frames (paper §IV-A, following [12]).

The raw RadioML sample is a (2, 128) float I/Q frame.  The encoder
oversamples each of the 2x128 values by OSR (zero-order hold), runs a
first-order sigma-delta modulator along the oversampled axis and emits a
binary stream of shape (2, 128, OSR); the SNN then consumes one (2, 128)
binary frame per timestep for T = OSR timesteps.

First-order sigma-delta (unipolar, input normalized to [0, 1]):

    integ_t = integ_{t-1} + x_t - y_{t-1}
    y_t     = 1  if integ_t >= 0.5 else 0

The time-average of y reconstructs x to within O(1/OSR) (noise-shaped
quantization error pushed to high frequency, removed by the implicit
low-pass of LIF integration) — this property is asserted in tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["normalize_iq", "sigma_delta_encode", "sigma_delta_decode", "encode_frames"]


def normalize_iq(iq: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Map an I/Q frame (..., 2, L) into [0, 1] per frame (max-abs scaling)."""
    peak = jnp.max(jnp.abs(iq), axis=(-2, -1), keepdims=True)
    return 0.5 * (iq / (peak + eps) + 1.0)


def sigma_delta_encode(x: jax.Array, osr: int) -> jax.Array:
    """First-order sigma-delta modulation.

    x: (...,) values in [0, 1]  ->  bits: (osr, ...) in {0, 1}.
    """
    def step(carry, _):
        integ, y_prev = carry
        integ = integ + x - y_prev
        y = (integ >= 0.5).astype(x.dtype)
        return (integ, y), y

    init = (jnp.zeros_like(x), jnp.zeros_like(x))
    _, bits = jax.lax.scan(step, init, None, length=osr)
    return bits


def sigma_delta_decode(bits: jax.Array) -> jax.Array:
    """Low-pass (mean over the time axis 0) reconstruction of the rate."""
    return bits.mean(axis=0)


def encode_frames(iq: jax.Array, osr: int) -> jax.Array:
    """(..., 2, L) float I/Q -> (T=osr, ..., 2, L) binary spike frames."""
    x = normalize_iq(iq)
    return sigma_delta_encode(x, osr)
