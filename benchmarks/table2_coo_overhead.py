"""Paper Table II: COO storage overhead vs dense, per conv layer.

Reproduces the exact bit-widths (W.D/W.RI/W.CI), dense totals, COO totals
(as a function of density X) and break-even densities for the three conv
layers, plus the BRAM-granularity caveat the paper raises (§III-C.3): on
TPU the same analysis is HBM-byte exact because memory is byte-addressable
— recorded as the hardware-adaptation delta (DESIGN.md §2).
"""
from __future__ import annotations

from repro.core.sparse_format import (
    break_even_density,
    coo_bit_widths,
    coo_storage_bits,
    dense_storage_bits,
)

NAME = "table2_coo_overhead"

# (layer, kw, ic, oc) for the paper's three conv layers
LAYERS = [("L1", 11, 2, 16), ("L2", 11, 16, 32), ("L3", 5, 32, 64)]
PAPER = {  # layer -> (RI bits, CI bits, total len, amount, dense bits, break-even %)
    "L1": (5, 4, 25, 352, 5632, 64.00),
    "L2": (9, 4, 29, 5632, 90112, 55.17),
    "L3": (11, 3, 30, 10240, 163840, 53.33),
}


def run() -> dict:
    rows = []
    for name, kw, ic, oc in LAYERS:
        d_bits, ri, ci = coo_bit_widths(kw, ic, oc)
        total_len = d_bits + ri + ci
        amount = kw * ic * oc
        dense = dense_storage_bits(kw, ic, oc)
        coo_at_1 = coo_storage_bits(kw, ic, oc, 1.0)
        be = break_even_density(kw, ic, oc)
        p = PAPER[name]
        rows.append({
            "layer": name, "ri_bits": ri, "ci_bits": ci,
            "total_len": total_len, "amount": amount,
            "dense_bits": dense, "coo_bits_at_X1": coo_at_1,
            "break_even": be,
            "paper": p,
            "match": (ri, ci, total_len, amount, dense) == p[:5]
            and abs(be * 100 - p[5]) < 0.01,
        })
    return {"rows": rows}


def format_table(res: dict) -> str:
    lines = [
        "Table II — COO vs dense storage (paper values in [])",
        f"  {'layer':6s}{'RI':>4s}{'CI':>4s}{'len':>5s}{'amount':>8s}"
        f"{'dense-bit':>10s}{'break-even':>12s}{'ok':>4s}",
    ]
    for r in res["rows"]:
        p = r["paper"]
        lines.append(
            f"  {r['layer']:6s}{r['ri_bits']:>2d}[{p[0]:d}]"
            f"{r['ci_bits']:>2d}[{p[1]:d}]{r['total_len']:>3d}[{p[2]:d}]"
            f"{r['amount']:>6d}[{p[3]:d}]{r['dense_bits']:>8d}[{p[4]:d}]"
            f"  {r['break_even'] * 100:6.2f}%[{p[5]:.2f}%]"
            f"{'Y' if r['match'] else 'N':>4s}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
