"""Chrome-trace / Perfetto JSON export for request traces.

Turns a :class:`~repro.obs.trace.TraceLog` dump into a Chrome
trace-event file loadable in ``ui.perfetto.dev`` (or
``chrome://tracing``): open the dumped JSON and every request becomes a
nested span stack — the outer ``request`` span wraps one child span per
phase gap (``submit→admit``, ``enqueue→dequeue``, ``jit-step``, ...), so
queueing vs batching vs jitted-step time is visible per request, and
batch formation shows up as the same ``jit-step`` span lighting up
across riders simultaneously.

Layout:

* one Perfetto *process* (``pid``) per replica/engine — the ``replica``
  attr stamped at fleet ``admit`` wins, else the ``engine`` attr from
  ``submit``, else a single ``serve`` track;
* one *thread* (``tid``) per concurrency lane inside that process.
  Chrome trace ``B``/``E`` events form a stack per (pid, tid), so two
  overlapping requests must not share a tid — a greedy lane allocator
  reuses the lowest lane whose previous request already ended;
* ``ts`` is microseconds on a common axis (the dump's ``t0`` anchors,
  normalized to the earliest event so Perfetto opens at t=0);
* ``M``etadata events name the tracks;
* per-layer timings from
  :func:`~repro.plan.streaming.profile_layer_steps` land as ``X``
  (complete) events on a dedicated ``layers`` process so kernel-level
  cost sits beside request-level latency.

:func:`validate_perfetto` is the schema gate shared by the tests, the
bench, and the obs-smoke CI job: required keys, monotonic ``ts`` per
track, and strictly matching ``B``/``E`` pairs.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["to_perfetto", "write_perfetto", "validate_perfetto"]

_US = 1e6


def _trace_pid(trace: Dict[str, Any]) -> str:
    """Replica (fleet admit) > engine (submit) > 'serve'.

    Only the ``admit`` event's replica counts — ``replica-full`` also
    carries a ``replica`` attr, but that names the replica that refused.
    """
    for ev in trace.get("events", ()):
        if ev.get("name") == "admit" and ev.get("replica"):
            return str(ev["replica"])
    for ev in trace.get("events", ()):
        if ev.get("engine"):
            return str(ev["engine"])
    return "serve"


class _LaneAllocator:
    """Greedy per-pid lane (tid) assignment for non-overlapping stacking."""

    def __init__(self):
        self._lanes: List[float] = []   # lane -> end time of last span

    def take(self, t_start: float, t_end: float) -> int:
        for i, busy_until in enumerate(self._lanes):
            if t_start >= busy_until:
                self._lanes[i] = t_end
                return i
        self._lanes.append(t_end)
        return len(self._lanes) - 1


def to_perfetto(dump: Dict[str, Any],
                layer_ms: Optional[Dict[str, float]] = None
                ) -> Dict[str, Any]:
    """Convert a :meth:`TraceLog.dump` dict (+ optional per-layer ms
    from ``profile_layer_steps``) to Chrome trace-event JSON."""
    traces = [t for t in dump.get("traces", []) if t.get("events")]
    # absolute event times: t0 + t_rel_s (older dumps without t0 still
    # render, each anchored at its own zero)
    def abs_t(trace, ev):
        return float(trace.get("t0", 0.0)) + float(ev["t_rel_s"])

    t_min = min((abs_t(tr, tr["events"][0]) for tr in traces),
                default=0.0)

    events: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}
    lanes: Dict[int, _LaneAllocator] = {}
    seen_tids: set = set()

    def pid_of(name: str) -> int:
        if name not in pids:
            pids[name] = len(pids) + 1
            events.append({"ph": "M", "name": "process_name",
                           "pid": pids[name], "tid": 0,
                           "args": {"name": name}})
        return pids[name]

    for tr in sorted(traces, key=lambda t: abs_t(t, t["events"][0])):
        evs = tr["events"]
        pid = pid_of(_trace_pid(tr))
        t_start = (abs_t(tr, evs[0]) - t_min) * _US
        t_end = (abs_t(tr, evs[-1]) - t_min) * _US
        lane = lanes.setdefault(pid, _LaneAllocator())
        tid = lane.take(t_start, t_end) + 1
        if (pid, tid) not in seen_tids:
            seen_tids.add((pid, tid))
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pid, "tid": tid,
                           "args": {"name": f"lane-{tid}"}})
        terminal = tr.get("terminal") or "open"
        rid = tr.get("request_id")
        # outer request span
        events.append({
            "ph": "B", "name": f"request ({terminal})", "pid": pid,
            "tid": tid, "ts": t_start, "cat": "request",
            "args": {"request_id": rid, "terminal": terminal,
                     "total_s": tr.get("total_s")},
        })
        # nested per-phase spans: the gap from event i to event i+1
        for a, b in zip(evs, evs[1:]):
            ta = (abs_t(tr, a) - t_min) * _US
            tb = (abs_t(tr, b) - t_min) * _US
            name = ("jit-step" if a["name"] == "jit-step-start"
                    else f"{a['name']}→{b['name']}")
            args = {k: v for k, v in a.items()
                    if k not in ("name", "t_rel_s")}
            events.append({"ph": "B", "name": name, "pid": pid,
                           "tid": tid, "ts": ta, "cat": "phase",
                           "args": args})
            events.append({"ph": "E", "pid": pid, "tid": tid, "ts": tb,
                           "cat": "phase"})
        events.append({"ph": "E", "pid": pid, "tid": tid, "ts": t_end,
                       "cat": "request"})

    if layer_ms:
        pid = pid_of("layers")
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": 1, "args": {"name": "per-layer step"}})
        # sequential X spans: one profiled step per layer, end to end
        cursor = 0.0
        for layer, ms in layer_ms.items():
            dur = float(ms) * 1000.0      # ms -> us
            events.append({"ph": "X", "name": layer, "pid": pid,
                           "tid": 1, "ts": cursor, "dur": dur,
                           "cat": "layer",
                           "args": {"ms_per_step": float(ms)}})
            cursor += dur

    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_perfetto(path: str, dump: Dict[str, Any],
                   layer_ms: Optional[Dict[str, float]] = None
                   ) -> Dict[str, Any]:
    doc = to_perfetto(dump, layer_ms=layer_ms)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def validate_perfetto(doc: Dict[str, Any]) -> List[str]:
    """Chrome trace-event schema check; returns a list of problems
    (empty = valid).  Shared by tests, the bench gate, and obs-smoke CI.

    Checks: ``traceEvents`` list present; every event has ``ph`` and
    ``pid``/``tid``; duration/begin/end events have numeric ``ts``
    (``X`` also ``dur`` >= 0); per-(pid, tid) timestamps are monotonic
    non-decreasing in file order; and ``B``/``E`` events pair exactly
    (no unclosed begins, no stray ends).
    """
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    last_ts: Dict[Tuple, float] = {}
    depth: Dict[Tuple, int] = {}
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph is None:
            problems.append(f"event {i}: missing ph")
            continue
        if "pid" not in ev or "tid" not in ev:
            problems.append(f"event {i} ({ph}): missing pid/tid")
            continue
        if ph == "M":
            if "name" not in ev or "args" not in ev:
                problems.append(f"event {i}: metadata without name/args")
            continue
        if ph not in ("B", "E", "X"):
            problems.append(f"event {i}: unsupported ph {ph!r}")
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i} ({ph}): non-numeric ts {ts!r}")
            continue
        if ph in ("B", "X") and "name" not in ev:
            problems.append(f"event {i} ({ph}): missing name")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i} (X): bad dur {dur!r}")
        key = (ev["pid"], ev["tid"])
        if key in last_ts and ts < last_ts[key] - 1e-9:
            problems.append(
                f"event {i} ({ph}): ts {ts} < previous {last_ts[key]} "
                f"on track {key}")
        last_ts[key] = ts
        if ph == "B":
            depth[key] = depth.get(key, 0) + 1
        elif ph == "E":
            d = depth.get(key, 0)
            if d <= 0:
                problems.append(f"event {i}: E without matching B "
                                f"on track {key}")
            else:
                depth[key] = d - 1
    for key, d in sorted(depth.items()):
        if d:
            problems.append(f"track {key}: {d} unclosed B event(s)")
    return problems
