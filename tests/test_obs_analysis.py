"""Analysis plane: time-series store, SLO burn rates, anomaly alerting,
Perfetto export, and the health/readiness surface.

Everything here is deterministic: recorders and alert managers run on
fake clocks, burn-rate fixtures are hand-computed (the numbers in the
asserts are derived in comments, not re-derived from the code under
test), and the Perfetto validator is exercised on both valid exports and
hand-broken documents.  The only real-engine test is the readiness probe
one, because ``/readyz`` semantics ("first successful jit step") cannot
be faked meaningfully.
"""
import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax

from repro.api import SNNConfig, init_snn
from repro.fleet import Autoscaler
from repro.obs import (
    AlertManager,
    BurnRateEngine,
    BurnRateWatcher,
    EwmaDetector,
    MetricsRegistry,
    MetricsServer,
    SLO,
    SeriesWatcher,
    TimeSeriesRecorder,
    TraceLog,
    WatchSpec,
    alert_health_check,
    autoscaler_sink,
    canary_shadow_sink,
    default_serve_slos,
    disable_tracing,
    enable_tracing,
    engine_health_check,
    engine_ready_probe,
    get_tracer,
    log_file_sink,
    parse_slo_spec,
    scaled_windows,
    set_default_alert_manager,
    set_default_recorder,
    set_default_registry,
    to_perfetto,
    validate_perfetto,
)
from repro.obs.slo import DEFAULT_BURN_WINDOWS, BurnWindow
from repro.serve import AsyncAMCServeEngine
from repro.train.pruning import make_mask_pytree


@pytest.fixture(autouse=True)
def isolated_obs():
    """Fresh default registry, no tracing, no default recorder/manager."""
    prev = set_default_registry(MetricsRegistry())
    disable_tracing()
    prev_rec = set_default_recorder(None)
    prev_mgr = set_default_alert_manager(None)
    try:
        yield
    finally:
        disable_tracing()
        set_default_recorder(prev_rec)
        set_default_alert_manager(prev_mgr)
        set_default_registry(prev)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# ---------------------------------------------------------------------------
# registry edge cases feeding the analysis plane
# ---------------------------------------------------------------------------

def test_merged_differing_histogram_buckets_raises():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.histogram("lat", "", buckets=(0.1, 1.0)).observe(0.5)
    b.histogram("lat", "", buckets=(0.2, 2.0)).observe(0.5)
    with pytest.raises(ValueError, match="bucket"):
        MetricsRegistry.merged([a, b])


def test_value_on_labeled_family_without_labels():
    reg = MetricsRegistry()
    reg.counter("reqs_total", "", ("engine",)).labels(engine="e0").inc(3)
    # asking for the (nonexistent) unlabeled child is a clean 0.0, not a
    # crash — the SLO engine probes metric names it cannot assume exist
    assert reg.value("reqs_total") == 0.0
    assert reg.value("reqs_total", engine="nope") == 0.0
    assert reg.value("reqs_total", engine="e0") == 3.0
    assert reg.value("never_registered") == 0.0


def test_concurrent_sample_vs_registry_mutation():
    """A sweep racing family/child creation must neither crash nor
    corrupt: whatever it sees mid-mutation, the final sweep sees all."""
    reg = MetricsRegistry()
    clock = FakeClock()
    rec = TimeSeriesRecorder(reg, clock=clock)
    n_threads, per = 4, 40
    stop = threading.Event()
    errors = []

    def mutate(tid):
        try:
            for i in range(per):
                reg.counter(f"m{tid}_{i}_total", "", ("k",)).labels(
                    k=str(i % 3)).inc()
                reg.histogram(f"h{tid}_{i}", "", buckets=(1.0,)).observe(0.5)
        except Exception as e:  # pragma: no cover — the failure signal
            errors.append(e)

    def sweep():
        try:
            while not stop.is_set():
                rec.sample(clock.advance(1.0))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    sampler = threading.Thread(target=sweep)
    workers = [threading.Thread(target=mutate, args=(t,))
               for t in range(n_threads)]
    sampler.start()
    for w in workers:
        w.start()
    for w in workers:
        w.join()
    stop.set()
    sampler.join()
    assert not errors
    rec.sample(clock.advance(1.0))  # one quiescent sweep sees everything
    assert len(rec.series()) == n_threads * per * 2


# ---------------------------------------------------------------------------
# time-series store
# ---------------------------------------------------------------------------

def test_series_monotonic_append_and_ring_bound():
    clock = FakeClock()
    reg = MetricsRegistry()
    c = reg.counter("n_total", "")
    rec = TimeSeriesRecorder(reg, capacity=4, clock=clock)
    for i in range(10):
        c.inc()
        rec.sample(clock.advance(1.0))
    s = rec.get("n_total")
    assert len(s) == 4                       # ring bound
    assert [t for t, _ in s.points()] == [7.0, 8.0, 9.0, 10.0]
    # a sweep whose clock did not advance is dropped, not reordered
    assert rec.sample(5.0) == 0
    assert [t for t, _ in s.points()] == [7.0, 8.0, 9.0, 10.0]


def test_counter_delta_rate_and_window_left_edge():
    clock = FakeClock()
    reg = MetricsRegistry()
    c = reg.counter("n_total", "")
    rec = TimeSeriesRecorder(reg, clock=clock)
    # samples at t=1..5 with cumulative values 10,20,40,40,70
    for v in (10, 20, 40, 40, 70):
        c.inc(v - reg.value("n_total"))
        rec.sample(clock.advance(1.0))
    s = rec.get("n_total")
    # trailing 2s window ending at t=5 covers [3,5]; window() keeps one
    # point left of the edge (t=3, v=40) so the delta is computable
    assert [t for t, _ in s.window(2.0)] == [3.0, 4.0, 5.0]
    assert s.delta(2.0) == 70 - 40
    assert s.rate(2.0) == (70 - 40) / 2.0
    # whole-history window: delta from the first sample
    assert s.delta(100.0) == 70 - 10
    # per-interval rates, negative deltas clamped (registry swap)
    assert [r for _, r in s.rates()] == [10.0, 20.0, 0.0, 30.0]
    assert s.values() == [10.0, 20.0, 40.0, 40.0, 70.0]


def test_histogram_fraction_over_and_quantile():
    clock = FakeClock()
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "", buckets=(0.1, 0.5, 1.0))
    rec = TimeSeriesRecorder(reg, clock=clock)
    rec.sample(clock.advance(1.0))           # empty baseline at t=1
    for v in (0.05, 0.05, 0.3, 0.3, 0.3, 0.3, 0.7, 2.0):
        h.observe(v)
    rec.sample(clock.advance(1.0))           # t=2: 8 observations
    s = rec.get("lat_seconds")
    # 2 of 8 over 0.5s; bound snaps to the 0.5 bucket edge
    assert s.fraction_over(0.5, 10.0) == pytest.approx(2 / 8)
    assert s.fraction_over(0.4, 10.0) == pytest.approx(2 / 8)  # snapped up
    assert s.fraction_over(1.0, 10.0) == pytest.approx(1 / 8)
    # median: target 4 of 8 lands at the top of the (0.1, 0.5] bucket
    # with 2 below it -> 0.1 + 0.4 * (4-2)/4 = 0.3
    assert s.quantile_over(0.5, 10.0) == pytest.approx(0.3)
    # windows before any observation answer None, not zero
    assert s.fraction_over(0.5, 0.5, now=1.0) is None


def test_recorder_fleet_merged_callable_and_export():
    clock = FakeClock()
    parts = [MetricsRegistry(), MetricsRegistry()]
    for i, reg in enumerate(parts):
        reg.counter("reqs_total", "", ("replica",)).labels(
            replica=f"r{i}").inc(5 * (i + 1))
    rec = TimeSeriesRecorder(lambda: MetricsRegistry.merged(parts),
                             clock=clock)
    rec.sample(clock.advance(1.0))
    parts[0].counter("reqs_total", "", ("replica",)).labels(
        replica="r0").inc(5)
    rec.sample(clock.advance(1.0))
    assert rec.get("reqs_total", replica="r0").values() == [5.0, 10.0]
    assert rec.get("reqs_total", replica="r1").values() == [10.0, 10.0]
    doc = json.loads(json.dumps(rec.to_json()))   # JSON-clean
    assert doc["n_sweeps"] == 2
    assert {s["name"] for s in doc["series"]} == {"reqs_total"}
    assert len(doc["series"]) == 2


def test_recorder_validation():
    with pytest.raises(ValueError):
        TimeSeriesRecorder(capacity=1)
    with pytest.raises(ValueError):
        TimeSeriesRecorder(interval_s=0.0)
    rec = TimeSeriesRecorder(MetricsRegistry())
    rec.start()
    with pytest.raises(RuntimeError):
        rec.start()
    rec.stop()


# ---------------------------------------------------------------------------
# SLO burn rates: hand-computed fixtures on a fake clock
# ---------------------------------------------------------------------------

def _ratio_fixture(shed_per_tick, submitted_per_tick=100, ticks=20):
    """Counters advancing per 1s tick; returns (recorder, clock)."""
    clock = FakeClock()
    reg = MetricsRegistry()
    sub = reg.counter("repro_fleet_submitted_total", "")
    shed = reg.counter("repro_fleet_shed_total", "")
    rec = TimeSeriesRecorder(reg, capacity=1024, clock=clock)
    sub.inc(0)                               # materialize the children so
    shed.inc(0)                              # the t=0 baseline records 0s
    rec.sample(clock.t)
    for i in range(ticks):
        sub.inc(submitted_per_tick)
        shed.inc(shed_per_tick(i) if callable(shed_per_tick)
                 else shed_per_tick)
        rec.sample(clock.advance(1.0))
    return rec, clock


def test_burn_rate_ratio_hand_computed():
    # 5 shed per 100 submitted -> error rate 0.05; objective 0.999 ->
    # budget 0.001 -> burn = 0.05 / 0.001 = 50, over any window
    rec, _ = _ratio_fixture(5)
    slo = default_serve_slos()[0]
    assert slo.budget == pytest.approx(0.001)
    eng = BurnRateEngine(rec, [slo])
    assert eng.burn_rate(slo, 10.0) == pytest.approx(50.0)
    assert eng.burn_rate(slo, 5.0) == pytest.approx(50.0)


def test_burn_rate_windows_disagree_and_firing_needs_both():
    # shed 5/tick for ticks 0..9, clean for 10..19: at t=20 the 4s short
    # window is clean while the 20s long window still carries the burn
    rec, clock = _ratio_fixture(lambda i: 5 if i < 10 else 0)
    slo = SLO(name="avail", kind="ratio", objective=0.999,
              total_metric="repro_fleet_submitted_total",
              bad_metrics=("repro_fleet_shed_total",))
    windows = (BurnWindow("page", long_s=20.0, short_s=4.0, factor=14.4),)
    eng = BurnRateEngine(rec, [slo], windows=windows)
    # long: 50 shed / 2000 submitted = 0.025 err -> burn 25; short: 0
    assert eng.burn_rate(slo, 20.0) == pytest.approx(25.0)
    assert eng.burn_rate(slo, 4.0) == pytest.approx(0.0)
    st = eng.evaluate()[0]
    assert st.burns["page"] == (pytest.approx(25.0), pytest.approx(0.0))
    assert st.firing == [] and st.ok      # both windows must breach
    # rewind the question to t=10, mid-burn: both windows hot -> fires
    st10 = eng.evaluate(now=10.0)[0]
    assert st10.burns["page"][0] == pytest.approx(50.0)
    assert st10.burns["page"][1] == pytest.approx(50.0)
    assert st10.firing == ["page"]


def test_burn_rate_latency_and_gauge_kinds():
    clock = FakeClock()
    reg = MetricsRegistry()
    h = reg.histogram("repro_serve_request_latency_seconds", "",
                      buckets=(0.05, 0.25, 1.0))
    acc = reg.gauge("repro_canary_window_accuracy", "")
    rec = TimeSeriesRecorder(reg, clock=clock)
    rec.sample(clock.t)
    for v in [0.01] * 90 + [0.5] * 10:       # 10% of requests over 250ms
        h.observe(v)
    acc.set(0.8)
    rec.sample(clock.advance(1.0))
    lat = SLO(name="lat", kind="latency", objective=0.99,
              latency_metric="repro_serve_request_latency_seconds",
              bound_s=0.25)
    gauge = SLO(name="acc", kind="gauge", objective=0.9,
                gauge_metric="repro_canary_window_accuracy")
    eng = BurnRateEngine(rec, [lat, gauge])
    # latency: err 0.10 / budget 0.01 -> burn 10
    assert eng.burn_rate(lat, 10.0) == pytest.approx(10.0)
    # gauge: err (1-0.8)=0.2 / budget 0.1 -> burn 2
    assert eng.burn_rate(gauge, 10.0) == pytest.approx(2.0)
    # unknown metrics answer None (insufficient data), never 0
    ghost = SLO(name="g", kind="ratio", objective=0.5,
                total_metric="nope_total", bad_metrics=("also_nope",))
    assert eng.burn_rate(ghost, 10.0) is None


def test_scaled_windows_and_slo_validation():
    w = scaled_windows(1 / 60)
    assert [x.severity for x in w] == ["page", "ticket"]
    assert w[0].long_s == pytest.approx(60.0)
    assert w[0].short_s == pytest.approx(5.0)
    assert w[0].factor == DEFAULT_BURN_WINDOWS[0].factor   # unchanged
    assert w[1].long_s == pytest.approx(3 * 86400 / 60)
    with pytest.raises(ValueError):
        scaled_windows(0.0)
    with pytest.raises(ValueError):
        SLO(name="x", kind="nope", objective=0.9)
    with pytest.raises(ValueError):
        SLO(name="x", kind="ratio", objective=1.5,
            total_metric="t", bad_metrics=("b",))
    with pytest.raises(ValueError):
        SLO(name="x", kind="latency", objective=0.9,
            latency_metric="m", bound_s=0.0)


def test_parse_slo_spec():
    slos = parse_slo_spec("default")
    assert [s.name for s in slos] == ["availability", "latency"]
    slos = parse_slo_spec("availability=0.99, p99_ms=50@0.95, accuracy=0.9")
    assert slos[0].objective == 0.99
    assert slos[1].kind == "latency"
    assert slos[1].bound_s == pytest.approx(0.050)
    assert slos[1].objective == 0.95
    assert slos[2].kind == "gauge" and slos[2].objective == 0.9
    for bad in ("", "p99_ms", "frobnicate=1"):
        with pytest.raises(ValueError):
            parse_slo_spec(bad)


# ---------------------------------------------------------------------------
# EWMA anomaly detection
# ---------------------------------------------------------------------------

def test_ewma_warmup_shift_freeze_resolve():
    det = EwmaDetector(alpha=0.2, threshold=4.0, min_samples=8)
    rng = np.random.default_rng(0)
    base = 0.5 + 0.01 * rng.standard_normal(20)
    flags = [det.update(x)[0] for x in base]
    assert not any(flags)                     # warmup + in-band: quiet
    mean_before = det.mean
    # sustained level shift: every shifted sample keeps flagging because
    # the baseline freezes instead of absorbing the new level
    shifted = [det.update(0.15)[0] for _ in range(10)]
    assert all(shifted)
    assert det.mean == pytest.approx(mean_before)   # frozen
    ok, z = det.update(0.5)                   # back in band -> resolves
    assert not ok and abs(z) < 4.0


def test_ewma_direction_down_only():
    mk = lambda: EwmaDetector(alpha=0.2, threshold=3.0, min_samples=4,
                              direction="down")
    warmup = (0.5, 0.51, 0.49, 0.5, 0.5, 0.51)
    det = mk()
    for x in warmup:
        assert det.update(x)[0] is False
    assert det.update(0.1)[0] is True         # drop: flagged
    det = mk()                                # fresh baseline
    for x in warmup:
        det.update(x)
    ok, z = det.update(5.0)                   # rise: ignored (and the
    assert ok is False and z > 3.0            # EWMA absorbs it)
    with pytest.raises(ValueError):
        EwmaDetector(direction="sideways")


# ---------------------------------------------------------------------------
# alert lifecycle, sinks, watchers
# ---------------------------------------------------------------------------

def test_alert_dedup_refire_resolve_and_gauge():
    reg = MetricsRegistry()
    clock = FakeClock(100.0)
    mgr = AlertManager(reg, clock=clock)
    transitions = []
    mgr.add_sink(lambda a, tr: transitions.append((a.name, dict(a.labels),
                                                   tr)))
    a1 = mgr.fire("burn", labels={"severity": "page"}, severity="page",
                  value=20.0)
    a2 = mgr.fire("burn", labels={"severity": "ticket"}, severity="ticket",
                  value=2.0)
    again = mgr.fire("burn", labels={"severity": "page"}, severity="page",
                     value=30.0)
    assert again is a1 and a1.n_refires == 1 and a1.value == 30.0
    assert len(mgr.firing()) == 2
    # the gauge is the count of firing instances under the name
    assert reg.value("repro_alerts_firing", alert="burn") == 2
    clock.advance(5.0)
    resolved = mgr.resolve("burn", labels={"severity": "page"})
    assert resolved is a1 and a1.state == "resolved"
    assert a1.t_resolved == pytest.approx(105.0)
    assert reg.value("repro_alerts_firing", alert="burn") == 1  # ticket
    assert mgr.resolve("burn", labels={"severity": "page"}) is None
    assert mgr.firing(severity="ticket") == [a2]
    # refires do not re-notify; transitions are fire,fire,resolve
    assert [t[2] for t in transitions] == ["fire", "fire", "resolve"]
    doc = json.loads(json.dumps(mgr.to_json()))
    assert len(doc["firing"]) == 1 and len(doc["alerts"]) == 2
    assert doc["n_history"] == 2


def test_sink_errors_swallowed(tmp_path):
    mgr = AlertManager(MetricsRegistry())
    mgr.add_sink(lambda a, tr: 1 / 0)
    log = tmp_path / "alerts.jsonl"
    mgr.add_sink(log_file_sink(str(log)))
    mgr.fire("a", t=1.0)
    mgr.resolve("a", t=2.0)
    assert mgr.sink_errors == 2               # broken sink never propagates
    lines = [json.loads(l) for l in log.read_text().splitlines()]
    assert [l["transition"] for l in lines] == ["fire", "resolve"]
    assert lines[1]["state"] == "resolved"


def test_series_watcher_drift_fire_and_resolve():
    clock = FakeClock()
    reg = MetricsRegistry()
    g = reg.gauge("repro_activity_effective_density", "", ("layer",))
    rec = TimeSeriesRecorder(reg, clock=clock)
    mgr = AlertManager(reg, clock=clock)
    watcher = SeriesWatcher(rec, mgr, watches=[
        WatchSpec("repro_activity_effective_density",
                  alert_name="sparsity_drift", severity="ticket",
                  detector=lambda: EwmaDetector(alpha=0.2, threshold=4.0,
                                                min_samples=6))])
    rng = np.random.default_rng(1)

    def feed(level, n):
        for _ in range(n):
            g.labels(layer="conv1").set(level + 0.005 * rng.random())
            rec.sample(clock.advance(1.0))
            watcher.step()

    feed(0.5, 12)
    assert mgr.firing() == []
    feed(0.15, 3)                             # injected density shift
    firing = mgr.firing()
    assert [a.name for a in firing] == ["sparsity_drift"]
    assert dict(firing[0].labels) == {"layer": "conv1"}
    feed(0.5, 3)                              # revert -> resolves
    assert mgr.firing() == []
    assert reg.value("repro_alerts_firing", alert="sparsity_drift") == 0
    # watcher consumed each point exactly once (cursor, not re-reads)
    assert watcher._detectors[
        ("repro_activity_effective_density",
         (("layer", "conv1"),))].n >= 12


def test_burn_rate_watcher_and_autoscaler_pressure():
    rec, clock = _ratio_fixture(5, ticks=10)
    slo = default_serve_slos()[0]
    eng = BurnRateEngine(
        rec, [slo],
        windows=(BurnWindow("page", long_s=8.0, short_s=2.0, factor=14.4),))
    reg = MetricsRegistry()
    mgr = AlertManager(reg, clock=clock)

    class Fleet:
        def __init__(self):
            self.t, self.ups = 0.0, 0

        def signals(self):
            self.t += 1.0
            return dict(t=self.t, p99_ms=1.0, queue_depth=0, n_replicas=1,
                        shed=0, expired=0, workers=1, busy_s=0.0)

        def scale_up(self):
            self.ups += 1
            return "r2"

        def scale_down(self):
            return None

    fleet = Fleet()
    scaler = Autoscaler(fleet, target_p99_ms=100.0, up_patience=1,
                        cooldown_ticks=0, clock=lambda: fleet.t)
    mgr.add_sink(autoscaler_sink(scaler))
    watcher = BurnRateWatcher(eng, mgr)
    watcher.step()
    assert [a.name for a in mgr.firing()] == ["slo_burn:availability"]
    assert scaler.alert_pressure() == ["slo_burn:availability"]
    # every signal healthy, yet the burn pressure forces the scale-up
    tick = scaler.step()
    assert tick.action == "scale-up" and "alert pressure" in tick.reason
    assert fleet.ups == 1
    # burn stops -> alert resolves -> pressure clears -> holds again
    for _ in range(10):                       # clean ticks wash the window
        rec.sample(clock.advance(1.0))
    watcher.step()
    assert mgr.firing() == [] and scaler.alert_pressure() == []
    assert scaler.step().action == "hold"


def test_canary_shadow_sink_gating():
    class Monitor:
        def __init__(self):
            self.decision = "pending"
            self.steps = 0

        def step(self):
            self.steps += 1

    mon = Monitor()
    mgr = AlertManager(MetricsRegistry())
    mgr.add_sink(canary_shadow_sink(mon))
    mgr.fire("canary_accuracy_drift")         # not a sparsity-drift name
    assert mon.steps == 0
    mgr.fire("sparsity_drift", labels={"layer": "conv1"})
    assert mon.steps == 1
    mgr.resolve("sparsity_drift", labels={"layer": "conv1"})
    assert mon.steps == 1                     # resolves never trigger
    mon.decision = "promote"
    mgr.fire("events_per_frame_drift")
    assert mon.steps == 1                     # decided monitors left alone


# ---------------------------------------------------------------------------
# Perfetto export + validator
# ---------------------------------------------------------------------------

def _fake_dump(n=3, overlap=True):
    """A dump with n completed requests on one engine, overlapping."""
    log = TraceLog(capacity=16)
    for i in range(n):
        tr = log.begin()
        base = 100.0 + (0.0 if overlap else 10.0) * i
        tr.add("submit", t=base, engine="e0")
        tr.add("jit-step-start", t=base + 1.0 + i, backend="stream")
        tr.add("jit-step-end", t=base + 2.0 + i)
        tr.add("complete", t=base + 3.0 + i, pred=i)
        tr.finish()
    return log.dump()


def test_perfetto_export_lanes_and_validity():
    doc = to_perfetto(_fake_dump(3, overlap=True),
                      layer_ms={"conv1": 1.5, "conv2": 0.5})
    assert validate_perfetto(doc) == []
    evs = doc["traceEvents"]
    reqs = [e for e in evs if e["ph"] == "B" and e.get("cat") == "request"]
    assert len(reqs) == 3
    # overlapping requests on one engine must not share a tid (B/E stack)
    assert len({e["tid"] for e in reqs}) == 3
    # earliest event normalized to ts 0 on a common axis
    assert min(e["ts"] for e in evs if "ts" in e) == 0.0
    # the jit gap is named as a span, carrying its attrs
    jit = [e for e in evs if e.get("name") == "jit-step"]
    assert len(jit) == 3 and jit[0]["args"]["backend"] == "stream"
    # per-layer X events on their own track
    xs = [e for e in evs if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["conv1", "conv2"]
    assert xs[0]["dur"] == pytest.approx(1500.0)   # 1.5ms in us
    names = [e["args"]["name"] for e in evs
             if e["ph"] == "M" and e["name"] == "process_name"]
    assert "e0" in names and "layers" in names
    # non-overlapping requests reuse lane 1
    doc2 = to_perfetto(_fake_dump(3, overlap=False))
    reqs2 = [e for e in doc2["traceEvents"]
             if e["ph"] == "B" and e.get("cat") == "request"]
    assert {e["tid"] for e in reqs2} == {1}
    assert json.loads(json.dumps(doc)) == doc      # JSON-clean


def test_validate_perfetto_catches_broken_docs():
    assert validate_perfetto({}) == ["traceEvents missing or not a list"]
    bad = {"traceEvents": [
        {"pid": 1, "tid": 1, "ts": 0.0},                        # no ph
        {"ph": "B", "name": "a", "ts": 0.0},                    # no pid/tid
        {"ph": "E", "pid": 1, "tid": 1, "ts": 5.0},             # stray E
        {"ph": "B", "name": "b", "pid": 1, "tid": 1, "ts": 4.0},  # ts back
        {"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 9.0,
         "dur": -1.0},                                          # bad dur
        {"ph": "Q", "pid": 1, "tid": 1, "ts": 9.0},             # bad ph
    ]}
    problems = validate_perfetto(bad)
    assert len(problems) == 7                  # incl. the unclosed B
    assert any("missing ph" in p for p in problems)
    assert any("E without matching B" in p for p in problems)
    assert any("ts" in p and "previous" in p for p in problems)
    assert any("bad dur" in p for p in problems)
    assert any("unsupported ph" in p for p in problems)
    assert any("unclosed B" in p for p in problems)


def test_trace_dump_limit_keeps_newest():
    log = TraceLog(capacity=16)
    for i in range(5):
        tr = log.begin()
        tr.add("submit", t=float(i))
        tr.add("complete", t=float(i) + 0.5)
        tr.finish()
    assert [t["events"][0]["name"] for t in log.dump(limit=2)["traces"]]
    dump = log.dump(limit=2)
    assert len(dump["traces"]) == 2
    assert [t["t0"] for t in dump["traces"]] == [3.0, 4.0]
    assert dump["n_completed"] == 5            # headline counters intact
    assert log.dump(limit=0)["traces"] == []
    with pytest.raises(ValueError):
        log.dump(limit=-1)


def test_enable_tracing_per_pass_isolation():
    """Regression: each bench pass gets a fresh ring at its own capacity —
    a later ``enable_tracing`` must not inherit the previous pass's
    counters or traces (the obs_bench per-attempt isolation)."""
    log1 = enable_tracing(sample_every=1, capacity=8)
    for _ in range(8):
        tr = log1.begin()
        tr.add("submit")
        tr.add("complete")
        tr.finish()
    assert log1.n_completed == 8
    log2 = enable_tracing(sample_every=1, capacity=4)
    assert log2 is get_tracer() and log2 is not log1
    assert log2.n_seen == 0 and log2.n_completed == 0
    assert log2.capacity == 4 and log2.dump()["traces"] == []
    # the old pass's artifact is still intact for whoever held it
    assert log1.n_completed == 8 and len(log1.dump()["traces"]) == 8


# ---------------------------------------------------------------------------
# HTTP surface: health checks, readiness probes, query params, HEAD
# ---------------------------------------------------------------------------

def _get(url, method="GET"):
    req = urllib.request.Request(url, method=method)
    with urllib.request.urlopen(req, timeout=10) as resp:
        return resp.status, resp.read()


def test_healthz_checks_and_readyz_probes():
    with MetricsServer(port=0) as srv:
        # stock state: no checks/probes -> healthy and ready
        code, body, _ = srv._route("/healthz", {})
        assert code == 200 and json.loads(body)["status"] == "ok"
        code, body, _ = srv._route("/readyz", {})
        assert code == 200 and json.loads(body)["ready"] is True

        ready = {"ok": False}
        srv.add_ready_probe("engine", lambda: ready["ok"])
        code, body, _ = srv._route("/readyz", {})
        assert code == 503 and json.loads(body)["waiting_on"] == ["engine"]
        ready["ok"] = True
        code, _, _ = srv._route("/readyz", {})
        assert code == 200

        mgr = AlertManager(MetricsRegistry())
        set_default_alert_manager(mgr)
        srv.add_health_check("alerts", alert_health_check())
        srv.add_health_check("boom", lambda: 1 / 0)   # broken check
        code, body, _ = srv._route("/healthz", {})
        failed = json.loads(body)["failed"]
        assert code == 503 and [f["check"] for f in failed] == ["boom"]
        mgr.fire("slo_burn:latency", severity="page")
        code, body, _ = srv._route("/healthz", {})
        failed = json.loads(body)["failed"]
        assert {f["check"] for f in failed} == {"alerts", "boom"}
        assert "slo_burn:latency" in failed[0]["reason"]
        # ticket-severity alerts do not degrade liveness
        mgr.resolve("slo_burn:latency")
        mgr.fire("sparsity_drift", severity="ticket")
        code, body, _ = srv._route("/healthz", {})
        assert [f["check"] for f in json.loads(body)["failed"]] == ["boom"]


def test_http_endpoints_limit_head_and_analysis_routes():
    reg = MetricsRegistry()
    set_default_registry(reg)
    reg.counter("smoke_total", "").inc(2)
    with MetricsServer(port=0) as srv:
        # /timeseries and /alerts 404 until the defaults are installed
        for path in ("/timeseries", "/alerts"):
            with pytest.raises(urllib.error.HTTPError) as e:
                _get(srv.url(path))
            assert e.value.code == 404
        clock = FakeClock()
        rec = TimeSeriesRecorder(reg, clock=clock)
        rec.sample(clock.advance(1.0))
        set_default_recorder(rec)
        mgr = AlertManager(reg, clock=clock)
        mgr.fire("x", severity="ticket")
        set_default_alert_manager(mgr)
        status, body = _get(srv.url("/timeseries"))
        assert status == 200
        assert json.loads(body)["n_sweeps"] == 1
        status, body = _get(srv.url("/alerts"))
        assert json.loads(body)["firing"][0]["name"] == "x"

        enable_tracing(sample_every=1)
        for i in range(5):
            tr = get_tracer().begin()
            tr.add("submit", t=float(i), engine="e0")
            tr.add("complete", t=float(i) + 0.1)
            tr.finish()
        status, body = _get(srv.url("/trace?limit=2"))
        assert len(json.loads(body)["traces"]) == 2
        code, body, _ = srv._route("/trace", {"limit": ["bogus"]})
        assert code == 400
        status, body = _get(srv.url("/trace/perfetto?limit=3"))
        doc = json.loads(body)
        assert validate_perfetto(doc) == []
        reqs = [e for e in doc["traceEvents"]
                if e["ph"] == "B" and e.get("cat") == "request"]
        assert len(reqs) == 3
        # HEAD: headers only, no body, on every route
        status, body = _get(srv.url("/metrics"), method="HEAD")
        assert status == 200 and body == b""
        status, body = _get(srv.url("/healthz"), method="HEAD")
        assert status == 200 and body == b""


# ---------------------------------------------------------------------------
# engine readiness: the one real-engine test
# ---------------------------------------------------------------------------

def test_engine_ready_and_closed_probes():
    cfg = SNNConfig(conv_specs=((3, 2, 4),), pool=2, fc_specs=((32, 5),),
                    input_width=16, timesteps=2, n_classes=5)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    masks = make_mask_pytree(params, 0.5)
    eng = AsyncAMCServeEngine(params, cfg, masks=masks, backend="dense",
                              buckets=[2], max_delay_ms=5)
    probe = engine_ready_probe(eng)
    health = engine_health_check(eng)
    try:
        # warmup jit-compiles in __init__, so the engine is born ready
        assert eng.is_ready() and probe()
        assert not eng.closed and health() is None
    finally:
        eng.close()
    assert eng.closed and not eng.is_ready() and not probe()
    assert "closed" in health()
