"""Batched streaming AMC inference engine.

Mirrors the accelerator's deployment mode: a continuous stream of I/Q
frames is sigma-delta encoded and classified through the unified
``SNNProgram`` layer graph.  The execution backend is selectable
(``goap`` by default — the paper's sparsity-aware dataflow; ``dense`` /
``pallas`` / ``stream`` plug in unchanged).  Requests are gathered into
fixed-size batches (padding the tail) — the static-batch discipline is the
software analogue of the paper's fixed iteration schedule: the jitted
program never re-specializes, so the pipeline stays warm.

The engine reports the cost-model counters (accumulations, fetched bits)
for every processed batch, which is what the power model consumes, and
records which backend served each batch.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cost_model import bits_fetched, fc_wm_counts, goap_conv_counts
from repro.core.saocds import pad_same
from repro.core.sparse_format import weight_mask_from_dense
from repro.data.pipeline import sigma_delta_encode_np
from repro.models.graph import compile_snn
from repro.models.snn import SNNConfig, sparsify_params

__all__ = ["AMCServeEngine", "ServeStats"]


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    batches: int = 0
    accumulations: int = 0
    fetched_bits: int = 0
    wall_s: float = 0.0
    backend: str = ""
    batch_backends: List[str] = dataclasses.field(default_factory=list)

    def throughput_samples_per_s(self, frame_len: int = 128) -> float:
        if self.wall_s == 0:
            return 0.0
        return self.requests * frame_len / self.wall_s


class AMCServeEngine:
    def __init__(
        self,
        params,
        cfg: SNNConfig,
        masks=None,
        batch_size: int = 32,
        count_activity: bool = False,
        backend: str = "goap",
    ):
        self.cfg = cfg
        self.batch_size = batch_size
        self.count_activity = count_activity
        self.backend = backend
        self.program = compile_snn(cfg)
        # COO form only feeds the _count() activity hooks
        self.sparse = sparsify_params(params, masks) if count_activity else None
        self.stats = ServeStats(backend=backend)
        bound = self.program.bind(params, backend, masks=masks)
        self._fwd = jax.jit(bound.batch)

    def classify(self, iq: np.ndarray) -> np.ndarray:
        """iq: (N, 2, L) -> predicted class ids (N,). Batches internally."""
        n = iq.shape[0]
        preds = np.empty((n,), dtype=np.int32)
        t0 = time.perf_counter()
        for s in range(0, n, self.batch_size):
            chunk = iq[s : s + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            if pad:
                chunk = np.concatenate([chunk, np.zeros((pad,) + chunk.shape[1:], chunk.dtype)])
            frames = sigma_delta_encode_np(chunk, self.cfg.timesteps)
            logits = np.asarray(self._fwd(jnp.asarray(frames)))
            preds[s : s + self.batch_size - pad] = logits[: self.batch_size - pad].argmax(-1)
            self.stats.batches += 1
            self.stats.batch_backends.append(self.backend)
            if self.count_activity:
                self._count(frames[: self.batch_size - pad])
        self.stats.requests += n
        self.stats.wall_s += time.perf_counter() - t0
        return preds

    def _count(self, frames: np.ndarray) -> None:
        """Exact event counts through the conv stack (cost-model hooks)."""
        for b in range(frames.shape[0]):
            x = frames[b]  # (T, 2, L)
            for layer in self.sparse["conv"]:
                coo = layer["coo"]
                padded = np.asarray(pad_same(jnp.asarray(x), coo.kw))
                c = goap_conv_counts(padded, coo)
                self.stats.accumulations += c.accumulations
                self.stats.fetched_bits += bits_fetched(c)
                # advance the stream (cheap dense emulation for counting)
                from repro.core.saocds import max_pool_spikes, saocds_conv_layer
                from repro.core.lif import init_lif_params

                out, _ = saocds_conv_layer(jnp.asarray(padded), coo, layer["lif"])
                x = np.asarray(max_pool_spikes(out, self.cfg.pool))
            flat = x.reshape(x.shape[0], -1)
            for layer in self.sparse["fc"]:
                wm = weight_mask_from_dense(np.asarray(layer["w"]))
                c = fc_wm_counts(flat, wm)
                self.stats.accumulations += c.accumulations
                self.stats.fetched_bits += bits_fetched(c)
                break  # counting the dominant FC is enough for the model
