"""Property tests for the integer conversion pair (quantize / dequantize).

The fixed-point tier rests on ``quantize_to_int`` / ``dequantize`` (and
their NumPy twins in ``repro.fixed.quantize``) behaving like a textbook
uniform symmetric quantizer: round-trip error bounded by step/2 inside the
representable range, hard saturation at the code extremes outside it, and
odd symmetry up to the asymmetric two's-complement edge.  Runs under the
``tests/_hyp.py`` shim: with hypothesis installed these are property
tests, without it they skip cleanly.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from _hyp import given, st

from repro.train.lsq import dequantize, quantize_to_int

BITS = st.sampled_from([8, 16])
STEPS = st.floats(1e-6, 1.0, allow_nan=False, allow_infinity=False)
SEEDS = st.integers(0, 2**31 - 1)


def _qrange(bits):
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


@given(SEEDS, STEPS, BITS)
def test_roundtrip_error_within_half_step(seed, step, bits):
    """dequant(quant(w)) is within step/2 of w for in-range w."""
    qmin, qmax = _qrange(bits)
    rng = np.random.default_rng(seed)
    # stay strictly inside the representable range so no clipping occurs
    w = jnp.asarray((rng.uniform(qmin + 1, qmax - 1, size=64)
                     * step).astype(np.float32))
    codes = quantize_to_int(w, jnp.float32(step), bits=bits)
    w2 = np.asarray(dequantize(codes, jnp.float32(step)))
    # step/2 quantization error + float32 rounding of the products
    tol = step / 2 + np.abs(np.asarray(w)).max() * 1e-6 + 1e-7
    assert float(np.max(np.abs(w2 - np.asarray(w)))) <= tol


@given(STEPS, BITS)
def test_saturation_at_code_extremes(step, bits):
    """Out-of-range magnitudes clamp to qmin/qmax, never wrap."""
    qmin, qmax = _qrange(bits)
    big = jnp.asarray([10.0 * qmax * step, -10.0 * qmax * step,
                       np.float32(qmax + 5) * step,
                       np.float32(qmin - 5) * step], jnp.float32)
    codes = np.asarray(quantize_to_int(big, jnp.float32(step), bits=bits))
    assert codes[0] == qmax and codes[2] == qmax
    assert codes[1] == qmin and codes[3] == qmin
    assert codes.min() >= qmin and codes.max() <= qmax


@given(SEEDS, STEPS, BITS)
def test_sign_symmetry(seed, step, bits):
    """quant(-w) == -quant(w) away from the asymmetric qmin edge.

    Two's-complement ranges are asymmetric (|qmin| = qmax + 1), so the
    identity only holds where |w/step| stays at or below qmax — which the
    conversion pipeline guarantees by construction (max-abs calibration
    and LSQ both derive the step from |w|).
    """
    _, qmax = _qrange(bits)
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.uniform(-(qmax - 1), qmax - 1, size=64)
                     * step).astype(np.float32))
    pos = np.asarray(quantize_to_int(w, jnp.float32(step), bits=bits))
    neg = np.asarray(quantize_to_int(-w, jnp.float32(step), bits=bits))
    assert np.array_equal(neg, -pos)


@given(SEEDS, BITS)
def test_code_dtype_and_zero_step_floor(seed, bits):
    """Codes land in the deployment dtype; floored steps stay finite."""
    from repro.train.lsq import STEP_FLOOR

    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=32).astype(np.float32) * 0.1)
    codes = quantize_to_int(w, jnp.float32(1e-3), bits=bits)
    assert codes.dtype == jnp.int16
    qmin, qmax = _qrange(bits)
    assert int(codes.min()) >= qmin and int(codes.max()) <= qmax
    # the all-zero-layer path: a floored step keeps everything finite
    z = quantize_to_int(jnp.zeros(8), jnp.float32(STEP_FLOOR), bits=bits)
    assert not np.any(np.asarray(z))


@given(SEEDS, BITS)
def test_numpy_twin_matches_jax_conversion(seed, bits):
    """repro.fixed's NumPy conversion mirrors the train-side jnp pair.

    The golden interpreter derives its codes through
    ``repro.fixed.quantize_codes`` (pure NumPy) while the backend reuses
    the plan compiler's fake-quant artifact; both must agree with the
    train-side ``quantize_to_int`` on the same (w, step) — this is the
    root of the bit-exactness guarantee.
    """
    from repro.fixed import calibrate_step, quantize_codes

    rng = np.random.default_rng(seed)
    w = rng.normal(size=(16, 4)).astype(np.float32) * 0.3
    step = calibrate_step(w, bits=bits)
    ours = quantize_codes(w, step, bits=bits)
    theirs = np.asarray(quantize_to_int(jnp.asarray(w), jnp.float32(step),
                                        bits=bits))
    assert np.array_equal(ours.astype(np.int32), theirs.astype(np.int32))


def test_shim_importable_without_hypothesis():
    """The module collects in minimal envs (shim contract)."""
    from _hyp import HAVE_HYPOTHESIS  # noqa: F401
