"""Paper Table I: SW vs GOAP fetch/accumulation counts (Fig. 3 example).

Exact reproduction: the (1,3,2,4) kernel / (1,6,2) IFM example at 50%
temporal + 50% spatial sparsity must give SW (24, 96, 48) vs GOAP
(48, 12, 24) and fetched-bit totals 1560 vs 240 (= 15.4%).  A sweep over
random sparsities shows how the advantage scales (paper §III-C.2).
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import bits_fetched, goap_conv_counts, sw_conv_counts
from repro.core.sparse_format import coo_from_dense

NAME = "table1_goap_vs_sw"


def fig3_example():
    kw, ic, oc, wi = 3, 2, 4, 6
    k = np.zeros((kw, ic, oc), dtype=np.float32)
    for o in range(oc):
        k[1, 0, o], k[0, 1, o], k[2, 1, o] = 1.0, 2.0, 3.0
    ifm = np.zeros((ic, wi), dtype=np.float32)
    ifm[0, [1, 3, 5]] = 1
    ifm[1, [0, 2, 4]] = 1
    return k, ifm


def run() -> dict:
    k, ifm = fig3_example()
    sw = sw_conv_counts(ifm, k.shape)
    gp = goap_conv_counts(ifm, coo_from_dense(k))
    exact = {
        "SW": {**sw.asdict(), "fetched_bits": bits_fetched(sw)},
        "GOAP": {**gp.asdict(), "fetched_bits": bits_fetched(gp)},
        "paper_SW": {"input_fetches": 24, "weight_fetches": 96,
                     "accumulations": 48, "fetched_bits": 1560},
        "paper_GOAP": {"input_fetches": 48, "weight_fetches": 12,
                       "accumulations": 24, "fetched_bits": 240},
    }
    exact["match"] = (exact["SW"] == {**exact["paper_SW"]}
                      and exact["GOAP"] == {**exact["paper_GOAP"]})

    # sweep: bit-traffic ratio GOAP/SW vs sparsity (larger kernel)
    rng = np.random.default_rng(0)
    sweep = []
    for wd in (1.0, 0.75, 0.5, 0.25, 0.1):
        for sd in (0.5,):
            kw, ic, oc, wi = 11, 16, 32, 64
            kk = ((rng.random((kw, ic, oc)) < wd)
                  * rng.normal(size=(kw, ic, oc))).astype(np.float32)
            f = (rng.random((ic, wi)) < sd).astype(np.float32)
            s = sw_conv_counts(f, kk.shape)
            g = goap_conv_counts(f, coo_from_dense(kk))
            sweep.append({
                "w_density": wd, "ifm_density": sd,
                "bits_ratio": bits_fetched(g) / bits_fetched(s),
                "accum_ratio": g.accumulations / max(1, s.accumulations),
            })
    return {"exact": exact, "sweep": sweep}


def format_table(res: dict) -> str:
    e = res["exact"]
    lines = [
        "Table I — SW vs GOAP on the Fig. 3 example (paper values in [])",
        f"{'':14s}{'#in-fetch':>10s}{'#w-fetch':>10s}{'#accum':>8s}{'bits':>7s}",
    ]
    for m in ("SW", "GOAP"):
        c, p = e[m], e[f"paper_{m}"]
        lines.append(
            f"  {m:12s}{c['input_fetches']:>6d}[{p['input_fetches']:>3d}]"
            f"{c['weight_fetches']:>6d}[{p['weight_fetches']:>3d}]"
            f"{c['accumulations']:>4d}[{p['accumulations']:>3d}]"
            f"{c['fetched_bits']:>5d}[{p['fetched_bits']:>5d}]")
    lines.append(f"  exact match: {e['match']}")
    lines.append("  sweep (11x16x32 kernel, 50% IFM): w-density -> GOAP/SW bits")
    for r in res["sweep"]:
        lines.append(f"    {r['w_density']:.2f} -> bits {r['bits_ratio']:.3f}  "
                     f"accum {r['accum_ratio']:.3f}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
