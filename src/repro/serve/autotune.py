"""Warmup-race backend autotuner for the serving tier.

The layer-graph API makes every execution dataflow interchangeable
(``dense`` / ``goap`` / ``pallas`` / ``stream`` produce identical logits),
but their *cost* is wildly platform-dependent: the COO gather dataflow that
wins on the paper's accelerator loses to the im2col matmul oracle on a
wide-SIMD CPU, and the Pallas block-sparse kernel only pays off on a real
TPU (CPU interpret mode executes the kernel body in Python).

So the engine does what the hardware cannot: at bind time it **races** the
candidate backends on the exact batch shape it is about to serve — compile,
warm up, time a few repetitions — and pins the winner for the lifetime of
the binding.  A candidate that raises (missing TPU, unsupported layout,
bind-under-trace error) is recorded and excluded; if every candidate fails
the tuner falls back to ``goap``, the paper's reference dataflow, which
binds from plain numpy artifacts on any host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AutotuneReport", "default_candidates", "autotune_backend"]

# Interpret-mode Pallas is orders of magnitude off the pace and only slows
# the race down; only let it compete where a real TPU will run it.
_CPU_CANDIDATES = ("dense", "goap")
_TPU_CANDIDATES = ("dense", "goap", "pallas")


def default_candidates() -> Tuple[str, ...]:
    """Backends worth racing on this host."""
    return _TPU_CANDIDATES if jax.default_backend() == "tpu" else _CPU_CANDIDATES


@dataclasses.dataclass(frozen=True)
class AutotuneReport:
    """Outcome of one warmup race (kept on the engine for introspection)."""

    choice: str
    timings_ms: Dict[str, float]      # successful candidates -> mean wall ms
    errors: Dict[str, str]            # failed candidates -> error summary
    batch_shape: Tuple[int, ...]
    fell_back: bool = False           # True when every candidate raised

    def summary(self) -> dict:
        return {
            "choice": self.choice,
            "timings_ms": dict(self.timings_ms),
            "errors": dict(self.errors),
            "batch_shape": list(self.batch_shape),
            "fell_back": self.fell_back,
        }


def autotune_backend(
    program,
    params,
    batch_shape: Sequence[int],
    *,
    masks=None,
    candidates: Optional[Sequence[str]] = None,
    reps: int = 2,
    budget_s: float = 5.0,
    fallback: str = "goap",
    make_fn: Optional[Callable] = None,
) -> AutotuneReport:
    """Race ``candidates`` on ``batch_shape`` and pin the fastest.

    ``make_fn(bound)`` builds the callable to time from a
    :class:`~repro.models.graph.BoundProgram` — the engine passes its full
    fused step (encode + forward + shard_map) so the race measures what
    will actually serve; default is the jitted ``bound.batch``.

    Candidates are always scored on post-warmup (steady-state) runs so a
    slow-to-compile but fast-to-run backend is never penalized for its
    compile time; a candidate whose warmup already exceeded ``budget_s``
    gets a single timed rep instead of ``reps`` (bounds how long a
    genuinely slow candidate can stall engine start-up).
    """
    candidates = tuple(candidates) if candidates is not None else default_candidates()
    timings: Dict[str, float] = {}
    errors: Dict[str, str] = {}
    probe = jnp.zeros(tuple(batch_shape), jnp.float32)
    for name in candidates:
        try:
            bound = program.bind(params, name, masks=masks)
            fn = jax.jit(bound.batch) if make_fn is None else make_fn(bound)
            t0 = time.perf_counter()
            jax.block_until_ready(fn(probe))       # compile + warm up
            warm = time.perf_counter() - t0
            n_reps = 1 if warm > budget_s else max(1, reps)
            t0 = time.perf_counter()
            for _ in range(n_reps):
                jax.block_until_ready(fn(probe))
            timings[name] = (time.perf_counter() - t0) / n_reps * 1e3
        except Exception as e:  # noqa: BLE001 — any failure disqualifies
            errors[name] = f"{type(e).__name__}: {e}"
    if timings:
        choice, fell_back = min(timings, key=timings.get), False
    else:
        choice, fell_back = fallback, True
    return AutotuneReport(choice=choice, timings_ms=timings, errors=errors,
                          batch_shape=tuple(batch_shape), fell_back=fell_back)
