"""Scenario-robustness evaluation harness.

Sweeps **(channel scenario x SNR grid x backend)** through plan-compiled
batched forwards and reduces each cell to the quantities AMC papers report
per channel condition: accuracy, the per-modulation confusion matrix, and
per-class accuracies — serialized as one JSON-ready report with an
``accuracy surface`` (scenario x SNR matrix) for the primary backend.

Frames are generated *clean* (``generate_batch(..., apply_channel=False)``)
and impaired by :func:`repro.channel.apply_scenario` at each grid SNR, so
the scenario channel is the only impairment in the cell; the ``clean``
section evaluates the legacy dataset channel at the same SNRs as the
reference the paper's Fig. 8 grid corresponds to.  Every forward goes
through :func:`repro.plan.compile_plan`, one jitted step per backend —
identical shapes across cells, so each backend compiles exactly once.

Deterministic end to end: cell ``(scenario, snr)`` draws its frames from a
seed derived by a stable hash of the scenario name and the *float* SNR
(fractional SNR bins never collide), and the channel key derives from the
same hash.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro.channel import ChannelScenario, scenario_fn, suite_scenarios
from repro.data.pipeline import sigma_delta_encode_batch
from repro.data.radioml import MODULATIONS, generate_batch
from repro.models.graph import compile_snn
from repro.models.snn import SNNConfig
from repro.plan import compile_plan

__all__ = ["RobustnessConfig", "evaluate_robustness", "stable_cell_seed",
           "format_report"]

DEFAULT_SNR_GRID = (-10.0, 0.0, 10.0, 18.0)


def stable_cell_seed(tag: str, snr_db: float) -> int:
    """Stable 32-bit seed for one sweep cell.

    Hashes the *bytes of the float* (the shared
    :func:`repro.channel.stable_seed` primitive, not ``int(snr)``), so
    fractional SNR bins like 0.5 and 0.9 draw distinct frames — the defect
    the canary monitor's old ``int(snr) * 131`` derivation had.
    """
    from repro.channel import stable_seed

    return stable_seed(tag, snr_db)


@dataclasses.dataclass(frozen=True)
class RobustnessConfig:
    """One robustness sweep: which scenarios, SNRs, backends, and how much."""

    suite: str = "default"               # suite name or comma-joined names
    snr_grid: Tuple[float, ...] = DEFAULT_SNR_GRID
    frames_per_cell: int = 64
    backends: Tuple[str, ...] = ("goap",)
    seed: int = 0
    include_clean: bool = True           # legacy-channel reference section
    agreement_atol: float = 1e-5         # cross-backend logit tolerance


def _confusion(labels: np.ndarray, preds: np.ndarray, n_classes: int) -> np.ndarray:
    cm = np.zeros((n_classes, n_classes), dtype=np.int64)
    np.add.at(cm, (labels, preds), 1)
    return cm


def _cell_record(labels: np.ndarray, preds_by_backend: Dict[str, np.ndarray],
                 n_classes: int, primary: str) -> Dict[str, Any]:
    cm = _confusion(labels, preds_by_backend[primary], n_classes)
    row = cm.sum(axis=1)
    per_class = np.divide(np.diag(cm), row, out=np.zeros(n_classes),
                          where=row > 0)
    return {
        "n_frames": int(labels.shape[0]),
        "accuracy": {b: float((p == labels).mean())
                     for b, p in preds_by_backend.items()},
        "confusion": cm.tolist(),
        "per_class_accuracy": [round(float(v), 4) for v in per_class],
    }


def _snr_key(snr: float) -> str:
    return f"{float(snr):+.1f}"


def evaluate_robustness(
    params,
    model_cfg: SNNConfig,
    eval_cfg: Optional[RobustnessConfig] = None,
    *,
    masks=None,
    quant_fn=None,
    scenarios: Optional[Sequence[Union[str, ChannelScenario]]] = None,
) -> Dict[str, Any]:
    """Run the full (scenario x SNR x backend) sweep; returns the report.

    ``scenarios`` overrides the config suite (accepts names or
    :class:`ChannelScenario` instances).  The report is pure
    JSON-serializable builtins.
    """
    from repro.channel import get_scenario

    cfg = eval_cfg or RobustnessConfig()
    scen = (tuple(suite_scenarios(cfg.suite)) if scenarios is None else
            tuple(get_scenario(s) for s in scenarios))
    program = compile_snn(model_cfg)
    n_classes = model_cfg.n_classes
    # reduced configs classify a class subset — labels must stay in range
    classes = (tuple(range(n_classes))
               if n_classes < len(MODULATIONS) else None)
    primary = cfg.backends[0]

    # one fused encode+forward step per backend; every cell reuses it
    steps = {}
    for backend in cfg.backends:
        plan = compile_plan(program, params, masks=masks, quant_fn=quant_fn,
                            assignment=backend)
        if backend == "fixed":
            # the honest hardware path: integer Σ-Δ front end, integer
            # logits dequantized back onto the float backends' logit scale
            # (argmax-invariant) so cross-backend |dlogit| measures the
            # genuine float-vs-fixed divergence
            from repro.fixed import fixed_encode_batch, fixed_logit_scale

            scale = fixed_logit_scale(params, model_cfg, masks=masks,
                                      quant_fn=quant_fn)
            steps[backend] = jax.jit(
                lambda iq, p=plan, s=scale: p.bound.batch(
                    fixed_encode_batch(iq, model_cfg.timesteps)
                ).astype(jnp.float32) * s)
        else:
            steps[backend] = jax.jit(
                lambda iq, p=plan: p.bound.batch(
                    sigma_delta_encode_batch(iq, model_cfg.timesteps)))

    agreement = {"atol": cfg.agreement_atol, "max_abs_logit_diff": 0.0,
                 "worst_pair": None}
    wall_by_backend = {b: 0.0 for b in cfg.backends}

    def _cell(iq: np.ndarray, labels: np.ndarray) -> Dict[str, Any]:
        preds, logits_by = {}, {}
        x = jnp.asarray(iq, jnp.float32)
        for b in cfg.backends:
            t0 = time.perf_counter()
            logits = np.asarray(jax.block_until_ready(steps[b](x)))
            wall_by_backend[b] += time.perf_counter() - t0
            logits_by[b] = logits
            preds[b] = logits.argmax(-1)
        for b in cfg.backends[1:]:
            d = float(np.abs(logits_by[b] - logits_by[primary]).max())
            if d > agreement["max_abs_logit_diff"]:
                agreement["max_abs_logit_diff"] = d
                agreement["worst_pair"] = [primary, b]
        return _cell_record(labels, preds, n_classes, primary)

    report: Dict[str, Any] = {
        "config": {
            "suite": cfg.suite,
            "scenarios": [s.name for s in scen],
            "snr_grid": [float(s) for s in cfg.snr_grid],
            "frames_per_cell": cfg.frames_per_cell,
            "backends": list(cfg.backends),
            "seed": cfg.seed,
            "model": {"input_width": model_cfg.input_width,
                      "timesteps": model_cfg.timesteps,
                      "n_classes": n_classes},
        },
        "modulations": list(MODULATIONS[:n_classes]),
        "scenarios": {},
    }

    if cfg.include_clean:
        clean: Dict[str, Any] = {}
        for snr in cfg.snr_grid:
            seed = cfg.seed + stable_cell_seed("clean", snr)
            iq, labels, _ = generate_batch(seed, cfg.frames_per_cell,
                                           snr_db=snr, classes=classes,
                                           frame_len=model_cfg.input_width)
            clean[_snr_key(snr)] = _cell(iq, labels)
        report["clean"] = clean

    for sc in scen:
        sfn = scenario_fn(sc)
        per_snr: Dict[str, Any] = {}
        for snr in cfg.snr_grid:
            seed = cfg.seed + stable_cell_seed(sc.name, snr)
            iq, labels, snrs = generate_batch(
                seed, cfg.frames_per_cell, snr_db=snr, classes=classes,
                frame_len=model_cfg.input_width, apply_channel=False)
            key = jax.random.PRNGKey(seed % (2 ** 31 - 1))
            impaired = np.asarray(sfn(jnp.asarray(iq), jnp.asarray(snrs),
                                      key))
            per_snr[_snr_key(snr)] = _cell(impaired, labels)
        accs = [per_snr[_snr_key(s)]["accuracy"][primary]
                for s in cfg.snr_grid]
        report["scenarios"][sc.name] = {
            "per_snr": per_snr,
            "mean_accuracy": float(np.mean(accs)),
        }

    # the accuracy surface (primary backend): scenario rows x SNR columns
    report["surface"] = {
        "backend": primary,
        "snrs": [float(s) for s in cfg.snr_grid],
        "scenarios": [s.name for s in scen],
        "accuracy": [
            [report["scenarios"][s.name]["per_snr"][_snr_key(snr)]
             ["accuracy"][primary] for snr in cfg.snr_grid]
            for s in scen
        ],
    }
    if len(cfg.backends) > 1:
        agreement["agrees"] = bool(
            agreement["max_abs_logit_diff"] <= cfg.agreement_atol)
        report["agreement"] = agreement
    report["wall_s_by_backend"] = {b: round(w, 3)
                                   for b, w in wall_by_backend.items()}
    return report


def format_report(report: Dict[str, Any]) -> str:
    """Human-readable accuracy surface (what the CLI prints)."""
    surf = report["surface"]
    snrs, names = surf["snrs"], surf["scenarios"]
    w = max(len(n) for n in names + ["clean (legacy ch.)"]) + 2
    lines = [f"accuracy surface [{surf['backend']}] "
             f"({report['config']['frames_per_cell']} frames/cell)",
             " " * w + "".join(f"{s:>9.1f}dB" for s in snrs)]
    if "clean" in report:
        primary = surf["backend"]
        accs = [report["clean"][_snr_key(s)]["accuracy"][primary]
                for s in snrs]
        lines.append(f"{'clean (legacy ch.)':<{w}}"
                     + "".join(f"{a:>11.3f}" for a in accs))
    for name, row in zip(names, surf["accuracy"]):
        lines.append(f"{name:<{w}}" + "".join(f"{a:>11.3f}" for a in row))
    if "agreement" in report:
        ag = report["agreement"]
        lines.append(f"cross-backend max |dlogit| = "
                     f"{ag['max_abs_logit_diff']:.2e} "
                     f"({'OK' if ag['agrees'] else 'DISAGREES'} at atol "
                     f"{ag['atol']:g})")
    return "\n".join(lines)
