"""Serving tier: micro-batcher flush policy, tail padding, autotuner
fallback, percentile math, activity counting.

Tiny reduced config throughout so binds/compiles stay cheap; timing
assertions use generous margins (CI containers jitter).
"""
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import SNNConfig, compile_snn, init_snn, register_backend
from repro.distributed.sharding import serve_mesh
from repro.serve import (
    AMCServeEngine,
    AsyncAMCServeEngine,
    DeadlineExceeded,
    EngineClosed,
    MicroBatcher,
    QueueFull,
    ServeStats,
    autotune_backend,
)
from repro.serve.batcher import bucket_for, make_buckets
from repro.train.pruning import make_mask_pytree

CFG = SNNConfig(
    conv_specs=((3, 2, 4), (3, 4, 8)),
    pool=2,
    fc_specs=((32, 16), (16, 5)),
    input_width=16,
    timesteps=3,
    n_classes=5,
)
FRAME_SHAPE = (2, CFG.input_width)


@pytest.fixture(scope="module")
def setup():
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, 0.5)
    return compile_snn(CFG), params, masks


def _iq(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n,) + FRAME_SHAPE).astype(np.float32)


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    assert make_buckets(64) == (1, 2, 4, 8, 16, 32, 64)
    assert make_buckets(48, align=4) == (4, 8, 16, 32, 48)
    assert make_buckets(5, align=2) == (2, 4)  # cap rounded DOWN to align
    assert make_buckets(1, align=2) == (2,)    # ... but never below align
    assert bucket_for(3, (1, 2, 4, 8)) == 4
    assert bucket_for(100, (1, 2, 4, 8)) == 8


def test_batcher_flushes_on_size():
    mb = MicroBatcher(FRAME_SHAPE, max_batch=4, max_delay_ms=60_000)
    for i in range(4):
        mb.submit(_iq(1)[0])
    t0 = time.perf_counter()
    batch = mb.get_batch(timeout=1.0)
    # full bucket ships immediately — nowhere near the 60 s delay cap
    assert time.perf_counter() - t0 < 5.0
    assert batch is not None and batch.n_real == 4 and batch.bucket == 4
    assert batch.n_padded == 0
    mb.close()


def test_batcher_flushes_on_timeout_and_pads_to_bucket():
    mb = MicroBatcher(FRAME_SHAPE, max_batch=64, max_delay_ms=50)
    frames = _iq(3)
    for i in range(3):
        mb.submit(frames[i])
    t0 = time.perf_counter()
    batch = mb.get_batch(timeout=1.0)
    elapsed = time.perf_counter() - t0
    assert batch is not None and batch.n_real == 3
    assert elapsed >= 0.04  # waited for the delay budget before flushing
    assert batch.bucket == 4 and batch.n_padded == 1  # smallest covering bucket
    assert batch.frames.shape == (4,) + FRAME_SHAPE
    np.testing.assert_array_equal(batch.frames[:3], frames)
    np.testing.assert_array_equal(batch.frames[3], np.zeros(FRAME_SHAPE))
    mb.close()


def test_batcher_rejects_bad_shapes_and_close_wakes_consumers():
    mb = MicroBatcher(FRAME_SHAPE, max_batch=4, max_delay_ms=10)
    with pytest.raises(ValueError, match="expected frame of shape"):
        mb.submit(np.zeros((3, 7), np.float32))
    mb.close()
    assert mb.get_batch(timeout=1.0) is None  # sentinel wakes the consumer
    # the dedicated type (an EngineClosed IS-A RuntimeError) lets the
    # fleet router skip a retiring replica without masking real faults
    with pytest.raises(EngineClosed, match="closed"):
        mb.submit(np.zeros(FRAME_SHAPE, np.float32))


def test_drain_barrier_waits_for_priority_reordered_backlog():
    """Weighted dequeue hands realtime ahead of bulk; the barrier must
    still hold until the *lower-seq* bulk request is handed — a max-seq
    watermark would release it early and let hot_swap/scale_down close
    an engine over a still-queued request."""
    mb = MicroBatcher(FRAME_SHAPE, max_batch=1, max_delay_ms=1)
    frames = _iq(2)
    bulk = mb.submit(frames[0], priority="bulk")          # seq 0
    mb.submit(frames[1], priority="realtime")             # seq 1
    batch = mb.get_batch(timeout=1.0)                     # WRR: realtime first
    assert [r.priority for r in batch.requests] == ["realtime"]
    # seq 1 handed, seq 0 still queued: the barrier must NOT release
    assert not mb.drain_barrier(timeout=0.05)
    batch = mb.get_batch(timeout=1.0)
    assert [r.priority for r in batch.requests] == ["bulk"]
    assert mb.drain_barrier(timeout=1.0)
    bulk.cancel()
    mb.close()


def test_expired_requests_fail_even_while_consumer_keeps_blocking():
    """A round that pops only expired requests must fail their futures
    when the round ends — not hold them until get_batch returns (which,
    with no further traffic and timeout=None, is never)."""
    mb = MicroBatcher(FRAME_SHAPE, max_batch=4, max_delay_ms=1)
    fut = mb.submit(_iq(1)[0], deadline=mb.now() - 1.0)   # already expired
    consumer = threading.Thread(target=mb.get_batch,
                                kwargs={"timeout": None}, daemon=True)
    consumer.start()
    with pytest.raises(DeadlineExceeded):
        fut.result(timeout=5.0)       # resolves while get_batch still blocks
    assert consumer.is_alive()        # no live request ever arrived
    assert mb.n_expired == 1
    mb.close()
    consumer.join(timeout=5.0)
    assert not consumer.is_alive()


# ---------------------------------------------------------------------------
# tail padding: exactly N predictions, no padded-frame leakage into stats
# ---------------------------------------------------------------------------

def test_sync_engine_tail_padding(setup):
    _, params, masks = setup
    engine = AMCServeEngine(params, CFG, masks=masks, batch_size=4,
                            backend="dense")
    iq = _iq(11)
    preds = engine.classify(iq)
    st = engine.stats
    assert preds.shape == (11,)
    assert st.requests == 11 and st.batches == 3
    assert st.padded_frames == 1
    assert len(st.latencies_s) == 11  # one latency per real request only
    assert st.backend_batch_counts() == {"dense": 3}


def test_async_engine_tail_padding_matches_reference(setup):
    program, params, masks = setup
    iq = _iq(11)
    # reference: dense program over the exact same (padded-free) frames
    from repro.data.pipeline import sigma_delta_encode_np

    frames = jnp.asarray(sigma_delta_encode_np(iq, CFG.timesteps))
    ref = np.asarray(program.apply_batch(params, frames, "dense",
                                         masks=masks)).argmax(-1)
    with AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                             max_batch=8, max_delay_ms=5.0,
                             warmup=False) as engine:
        preds = engine.classify(iq)
        st = engine.stats
    assert preds.shape == (11,)
    np.testing.assert_array_equal(preds, ref)  # padding never leaks into preds
    assert st.requests == 11
    assert len(st.latencies_s) == 11  # padded tail rows get no latency entry
    assert len(st.queue_depths) == st.batches
    assert all(b == "dense" for b in st.batch_backends)
    # worker-maintained serving window: throughput is real even though no
    # caller ever passed through a timed classify() section
    assert st.wall_s > 0 and st.throughput_fps() > 0


def test_submit_future_path_reports_throughput(setup):
    _, params, masks = setup
    with AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                             max_batch=4, max_delay_ms=5.0,
                             warmup=False) as engine:
        futs = [engine.submit(f) for f in _iq(9, seed=11)]
        preds = [f.result(timeout=30.0) for f in futs]
        st = engine.stats
    assert len(preds) == 9 and all(isinstance(p, int) for p in preds)
    assert st.requests == 9
    assert st.wall_s > 0 and st.throughput_fps() > 0


def test_batcher_rejects_conflicting_max_batch_and_buckets():
    with pytest.raises(ValueError, match="conflicts with explicit buckets"):
        MicroBatcher(FRAME_SHAPE, max_batch=64, buckets=(2, 4))
    mb = MicroBatcher(FRAME_SHAPE, buckets=(2, 4))  # buckets authoritative
    assert mb.max_batch == 4
    mb.close()


def test_close_never_leaves_a_future_pending(setup):
    _, params, masks = setup
    engine = AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                                 max_batch=2, max_delay_ms=1.0, warmup=False)
    futures = [engine.submit(f) for f in _iq(16, seed=9)]
    engine.close()  # immediately: some batches served, the rest drained
    served = drained = 0
    for fut in futures:
        assert fut.done() or True  # must resolve promptly either way
        try:
            pred = fut.result(timeout=10.0)
            assert isinstance(pred, int)
            served += 1
        except RuntimeError as e:
            assert "closed" in str(e)
            drained += 1
    assert served + drained == 16  # nobody hangs
    with pytest.raises(RuntimeError, match="closed"):
        engine.submit(_iq(1)[0])


def test_async_engine_counts_activity_like_sync(setup):
    _, params, masks = setup
    iq = _iq(6, seed=3)
    sync = AMCServeEngine(params, CFG, masks=masks, batch_size=8,
                          count_activity=True, backend="dense")
    sync.classify(iq)
    with AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                             max_batch=8, max_delay_ms=5.0, warmup=False,
                             count_activity=True) as engine:
        engine.classify(iq)
        st = engine.stats
    # identical activity despite different batching/padding: padded tail
    # rows are stripped before the counting hooks run
    assert st.accumulations == sync.stats.accumulations
    assert st.fetched_bits == sync.stats.fetched_bits
    assert st.accumulations > 0 and st.fetched_bits > 0


def test_sync_engine_count_path_unit(setup):
    """The counting path (old ``_count``) alone, on a 1-frame batch."""
    _, params, masks = setup
    engine = AMCServeEngine(params, CFG, masks=masks, batch_size=2,
                            count_activity=True, backend="goap")
    engine.classify(_iq(1, seed=7))
    st = engine.stats
    assert st.requests == 1 and st.batches == 1
    assert st.accumulations > 0
    assert st.fetched_bits > st.accumulations  # >=1 bit fetched per accum


def test_cancelled_future_does_not_poison_its_batch(setup):
    _, params, masks = setup
    with AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                             max_batch=64, max_delay_ms=200.0,
                             warmup=False) as engine:
        # both requests land in the same (timeout-flushed) micro-batch
        fut_a = engine.submit(_iq(1, seed=21)[0])
        fut_b = engine.submit(_iq(1, seed=22)[0])
        cancelled = fut_a.cancel()
        pred_b = fut_b.result(timeout=30.0)  # must still resolve normally
    assert isinstance(pred_b, int)
    if cancelled:  # cancel() raced the worker; when it won, a is cancelled
        assert fut_a.cancelled()
    else:
        assert isinstance(fut_a.result(timeout=30.0), int)


def test_traceable_encoder_matches_numpy_encoder():
    from repro.data.pipeline import (
        sigma_delta_encode_batch,
        sigma_delta_encode_np,
    )

    iq = _iq(5, seed=13)
    for osr in (1, 3, 8):
        np.testing.assert_array_equal(
            np.asarray(sigma_delta_encode_batch(jnp.asarray(iq), osr)),
            sigma_delta_encode_np(iq, osr))


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def test_autotuner_picks_a_winner(setup):
    program, params, masks = setup
    report = autotune_backend(program, params, (4, CFG.timesteps, 2, CFG.input_width),
                              masks=masks, candidates=("dense", "goap"),
                              reps=1)
    assert report.choice in ("dense", "goap")
    assert set(report.timings_ms) == {"dense", "goap"}
    assert not report.errors and not report.fell_back


def test_autotuner_falls_back_to_goap_when_backend_raises(setup):
    program, params, masks = setup
    from repro.models import graph

    def _boom(spec, layer_params, *, cfg, mask=None, quant_fn=None):
        raise RuntimeError("no such accelerator")

    snapshot = dict(graph._REGISTRY)
    try:
        register_backend("boom", "conv_lif", _boom)
        register_backend("boom", "fc_lif", _boom)
        report = autotune_backend(program, params, (4, CFG.timesteps, 2, CFG.input_width),
                                  masks=masks, candidates=("boom",))
        assert report.choice == "goap" and report.fell_back
        assert "boom" in report.errors
        assert "RuntimeError" in report.errors["boom"]
        # a raising candidate is excluded, not fatal, when others survive
        report = autotune_backend(program, params, (4, CFG.timesteps, 2, CFG.input_width),
                                  masks=masks, candidates=("boom", "dense"),
                                  reps=1)
        assert report.choice == "dense" and not report.fell_back
        assert "boom" in report.errors
    finally:
        graph._REGISTRY.clear()
        graph._REGISTRY.update(snapshot)


def test_async_engine_auto_backend(setup):
    _, params, masks = setup
    with AsyncAMCServeEngine(params, CFG, masks=masks, backend="auto",
                             candidates=("dense", "goap"), max_batch=4,
                             max_delay_ms=5.0, warmup=False) as engine:
        assert engine.autotune is not None
        assert engine.backend == engine.autotune.choice
        preds = engine.classify(_iq(5))
    assert preds.shape == (5,)


# ---------------------------------------------------------------------------
# sharded path (1-device mesh: same code path as a pod, minus the fan-out)
# ---------------------------------------------------------------------------

def test_async_engine_shard_map_path(setup):
    program, params, masks = setup
    iq = _iq(6, seed=5)
    from repro.data.pipeline import sigma_delta_encode_np

    frames = jnp.asarray(sigma_delta_encode_np(iq, CFG.timesteps))
    ref = np.asarray(program.apply_batch(params, frames, "dense",
                                         masks=masks)).argmax(-1)
    mesh = serve_mesh(1)
    with AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                             mesh=mesh, max_batch=4, max_delay_ms=5.0,
                             warmup=False) as engine:
        assert all(b % 1 == 0 for b in engine.batcher.buckets)
        preds = engine.classify(iq)
    np.testing.assert_array_equal(preds, ref)


# ---------------------------------------------------------------------------
# ServeStats percentile math vs numpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 5, 100, 997])
def test_percentiles_match_numpy(n):
    rng = np.random.default_rng(n)
    lat = rng.exponential(scale=0.01, size=n).tolist()
    st = ServeStats(latencies_s=list(lat))
    for q in (50.0, 95.0, 99.0, 0.0, 100.0, 37.3):
        np.testing.assert_allclose(
            st.latency_percentile(q), np.percentile(lat, q), rtol=1e-12)
    np.testing.assert_allclose(st.p50_ms, np.percentile(lat, 50) * 1e3)
    np.testing.assert_allclose(st.p95_ms, np.percentile(lat, 95) * 1e3)
    np.testing.assert_allclose(st.p99_ms, np.percentile(lat, 99) * 1e3)


def test_percentiles_empty_stats():
    st = ServeStats()
    assert st.p50_ms == 0.0 and st.p99_ms == 0.0
    assert st.throughput_fps() == 0.0
    assert st.mean_queue_depth() == 0.0


def test_stats_histories_are_bounded_but_totals_exact():
    st = ServeStats()
    cap = ServeStats.MAX_SAMPLES
    st.record_latencies([0.001] * (cap + 100))
    assert len(st.latencies_s) == cap
    for i in range(cap + 50):
        st.record_batch("dense", queue_depth=i)
    st.record_batch("goap", queue_depth=0)
    assert len(st.queue_depths) <= cap and len(st.batch_backends) <= cap
    # exact totals survive the history trimming
    assert st.backend_batch_counts() == {"dense": cap + 50, "goap": 1}
    assert st.batches == cap + 51


def test_stats_summary_roundtrips_to_json():
    import json

    st = ServeStats(requests=3, batches=1, backend="dense",
                    batch_backends=["dense"], latencies_s=[0.1, 0.2, 0.3],
                    queue_depths=[2], wall_s=0.5)
    d = json.loads(json.dumps(st.summary()))
    assert d["requests"] == 3
    assert d["backend_batch_counts"] == {"dense": 1}
    assert d["throughput_fps"] == pytest.approx(6.0)


# ---------------------------------------------------------------------------
# ServeStats edge cases: empty/singleton histories, zero elapsed time
# ---------------------------------------------------------------------------

def test_stats_empty_histories_stay_finite():
    import json

    st = ServeStats()
    assert st.latency_percentile(99.0) == 0.0
    assert st.p50_ms == st.p95_ms == st.p99_ms == 0.0
    assert st.mean_queue_depth() == 0.0
    assert st.throughput_fps() == 0.0
    assert st.throughput_samples_per_s() == 0.0
    d = json.loads(json.dumps(st.summary()))
    for key, val in d.items():
        if isinstance(val, (int, float)):
            assert np.isfinite(val), f"{key} not finite on empty stats"


def test_stats_singleton_latency_percentiles():
    st = ServeStats()
    st.record_latencies([0.004])
    # one sample: every percentile is that sample, no interpolation NaNs
    for q in (0.0, 50.0, 99.0, 100.0):
        assert st.latency_percentile(q) == pytest.approx(0.004)
    assert st.p50_ms == st.p99_ms == pytest.approx(4.0)


def test_stats_zero_elapsed_throughput_is_zero():
    # requests recorded but no wall time yet (first batch still in
    # flight): throughput must report 0.0, never divide by zero
    st = ServeStats(requests=10, wall_s=0.0)
    assert st.throughput_fps() == 0.0
    assert st.throughput_samples_per_s() == 0.0


# ---------------------------------------------------------------------------
# classify() abandonment: timeouts must not leak futures into the batcher
# ---------------------------------------------------------------------------

def test_classify_timeout_cancels_queued_futures(setup):
    _, params, masks = setup
    # max_delay far beyond the classify timeout and a 64-wide bucket:
    # the 4 submitted frames just sit queued, so the timeout must fire
    # with every future still pending
    eng = AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                              max_delay_ms=60_000.0, warmup=False)
    captured = []
    orig_submit = eng.submit

    def recording_submit(iq, **kw):
        fut = orig_submit(iq, **kw)
        captured.append(fut)
        return fut

    eng.submit = recording_submit
    try:
        # on 3.10 concurrent.futures.TimeoutError is not yet the builtin
        import concurrent.futures

        with pytest.raises((TimeoutError, concurrent.futures.TimeoutError)):
            eng.classify(_iq(4), timeout=0.2)
        # regression: classify used to return leaving its requests queued
        # forever; now every outstanding future is cancelled, and the
        # dequeue path drops cancelled requests without a batch slot
        assert len(captured) == 4
        assert all(f.done() for f in captured)
        assert all(f.cancelled() for f in captured)
    finally:
        eng.close()
    assert eng.stats.requests == 0


# ---------------------------------------------------------------------------
# micro-batcher concurrency stress (slow: excluded from default tier-1)
# ---------------------------------------------------------------------------

def _stress_round(seed: int) -> None:
    """Producers, consumers, and a chaos thread hammer one batcher.

    The invariant under test: every submitted future resolves exactly
    once (result, error, cancel — any is fine; zero or double is a bug),
    no matter how submits race expiry, cancellation, drain barriers, and
    close. Done-callbacks fire once per future by contract, so counting
    them counts resolutions.
    """
    rng = np.random.default_rng(seed)
    mb = MicroBatcher(FRAME_SHAPE, max_batch=4,
                      max_delay_ms=float(rng.choice([0.2, 1.0, 5.0])),
                      max_queue=32,
                      pace_ms=float(rng.choice([0.0, 0.5])))
    errors, resolved, futures = [], [], []
    lock = threading.Lock()
    frame = _iq(1)[0]

    def producer(t):
        prng = np.random.default_rng(seed * 100 + t)
        for _ in range(30):
            try:
                fut = mb.submit(
                    frame,
                    priority="bulk" if prng.random() < 0.4 else "realtime",
                    deadline=(mb.now() + 1e-4 if prng.random() < 0.2
                              else None))
            except QueueFull:
                continue
            except RuntimeError:
                return          # racing close(): valid terminal state
            fut.add_done_callback(lambda f: resolved.append(1))
            with lock:
                futures.append(fut)
            if prng.random() < 0.1:
                fut.cancel()
            if prng.random() < 0.3:
                time.sleep(prng.random() * 1e-3)

    def consumer():
        try:
            while True:
                batch = mb.get_batch(timeout=0.02)
                if batch is None:
                    if mb.closed:
                        return
                    continue
                for req in batch.requests:
                    try:
                        req.future.set_result(0)
                    except Exception:   # lost a cancel race: fine
                        pass
        except Exception as exc:  # noqa: BLE001 — fail the test, not the thread
            errors.append(exc)

    def chaos():
        for _ in range(10):
            mb.qsize()
            mb.qsizes()
            mb.drain_barrier(timeout=0.005)

    threads = ([threading.Thread(target=producer, args=(t,))
                for t in range(3)]
               + [threading.Thread(target=consumer) for _ in range(2)]
               + [threading.Thread(target=chaos)])
    for th in threads[:3] + threads[5:]:
        th.start()
    for th in threads[3:5]:
        th.start()
    for th in threads[:3] + threads[5:]:
        th.join(timeout=30.0)
    mb.drain_barrier(timeout=5.0)
    mb.close()
    for th in threads[3:5]:
        th.join(timeout=30.0)
    # anything still queued at close is failed, exactly as the engine does
    err = RuntimeError("closed")
    for req in mb.drain():
        if not req.future.done():
            try:
                req.future.set_exception(err)
            except Exception:
                pass
    assert not errors, errors
    deadline = time.perf_counter() + 5.0
    while len(resolved) < len(futures) and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert all(f.done() for f in futures), "unresolved futures leaked"
    assert len(resolved) == len(futures), (
        f"{len(futures)} futures but {len(resolved)} resolutions")


@pytest.mark.slow
def test_batcher_concurrency_stress_50_seeds():
    for seed in range(50):
        _stress_round(seed)
