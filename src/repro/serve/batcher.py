"""Dynamic micro-batching request queue for the serving tier.

The accelerator sustains 23.5 MS/s because its pipeline never sees a
control bubble: every frame enters a fixed iteration schedule.  The
software analogue is a micro-batcher that gathers individual requests into
**fixed-shape** batches: batch sizes are drawn from a static bucket ladder
(powers of two up to ``max_batch``) and the tail of a partially-filled
bucket is zero-padded, so the jitted program only ever sees ``len(buckets)``
distinct shapes and never re-specializes under load.

Flush policy (the standard dynamic-batching trade-off):

* **size flush** — the batch reaches ``max_batch`` requests: ship now,
  throughput-optimal;
* **timeout flush** — ``max_delay`` elapsed since the batch started
  forming: ship what we have (padded up to the smallest covering bucket),
  bounding added tail latency to ``max_delay`` under light traffic;
* **pace gate** (``pace_ms > 0``) — consecutive flushes are at least
  ``pace_ms`` apart, bounding batch-launch rate (the fleet tier uses this
  as the per-replica service-rate cap; the batch keeps filling while the
  gate holds, so pacing *improves* batching efficiency under load).

The queue is **priority- and deadline-aware** (the fleet tier's request
model):

* requests carry a priority class (``realtime`` > ``bulk``); dequeue is
  smooth-weighted round-robin across the non-empty classes, so under a
  saturated queue realtime requests observe strictly lower queueing delay
  while bulk traffic still drains (no starvation);
* requests may carry an absolute deadline; an expired request **fails
  fast** at dequeue time with :class:`DeadlineExceeded` instead of
  occupying a micro-batch slot (likewise a request whose future was
  cancelled is dropped without a slot);
* ``max_queue`` bounds the backlog: ``submit`` raises :class:`QueueFull`
  once the bound is hit — the admission-control primitive the fleet
  router's load shedding builds on (shed at the door, never queue
  unboundedly).

``MicroBatcher`` is transport-only — it knows nothing about models or
backends; the engine's worker loops consume :class:`MicroBatch` objects
and resolve each request's :class:`ServeFuture`.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import heapq
import itertools
import threading
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.trace import tadd, tfinish

__all__ = [
    "ServeFuture",
    "Request",
    "MicroBatch",
    "DeadlineExceeded",
    "QueueFull",
    "EngineClosed",
    "PRIORITIES",
    "DEFAULT_PRIORITY_WEIGHTS",
    "make_buckets",
    "bucket_for",
    "MicroBatcher",
]

#: Priority classes, highest first.  ``realtime`` models the paper's
#: streaming deployment (a frame is worthless once its decision window
#: passes); ``bulk`` models offline re-scoring / shadow traffic.
PRIORITIES: Tuple[str, ...] = ("realtime", "bulk")

#: Default dequeue weights: under a saturated queue realtime receives
#: ~8/9 of the batch slots, bulk the rest (weighted, not strict, so bulk
#: can never starve).
DEFAULT_PRIORITY_WEIGHTS: Dict[str, float] = {"realtime": 8.0, "bulk": 1.0}


class ServeFuture(concurrent.futures.Future):
    """Future for one serve request (stdlib ``Future`` semantics).

    Resolved by the engine's worker loop — ``result(timeout=...)`` blocks
    until the micro-batch containing this request has been served, or
    raises the worker's exception / a shutdown ``RuntimeError`` / a
    :class:`DeadlineExceeded` if the request expired while queued.

    ``trace`` carries the request's :class:`~repro.obs.trace.RequestTrace`
    (None when tracing is off / unsampled) so callers holding only the
    future can read the span timeline after resolution.
    """

    trace = None


class DeadlineExceeded(RuntimeError):
    """The request's deadline passed while it was still queued."""


class QueueFull(RuntimeError):
    """Admission rejected: the batcher's ``max_queue`` bound is hit."""


class EngineClosed(RuntimeError):
    """Submit refused: the batcher (and the engine over it) has closed.

    A dedicated type so callers that route around a retiring replica (the
    fleet router) can distinguish "this engine is shutting down — try the
    next one" from a genuine engine fault, which must propagate.
    """


@dataclasses.dataclass
class Request:
    """One enqueued classification request (a single I/Q frame)."""

    seq: int
    iq: np.ndarray            # (IC, L) float32
    t_enqueue: float
    future: ServeFuture
    deadline: Optional[float] = None   # absolute, on the batcher's clock
    priority: str = "realtime"
    trace: Optional[object] = None     # RequestTrace (None when untraced)


@dataclasses.dataclass
class MicroBatch:
    """A flushed batch: real requests plus zero-padded tail rows."""

    requests: List[Request]
    bucket: int               # fixed batch shape this batch was padded to
    frames: np.ndarray        # (bucket, IC, L) — rows >= n_real are padding
    queue_depth: int          # backlog remaining in the queue at flush time

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def n_padded(self) -> int:
        return self.bucket - len(self.requests)


def make_buckets(max_batch: int, align: int = 1) -> Tuple[int, ...]:
    """Power-of-two bucket ladder up to ``max_batch``, ``align``-aligned.

    ``align`` is the device count of the serving mesh: every bucket must be
    divisible by it so the batch axis shards evenly.  A ``max_batch`` that
    is not itself aligned is rounded **down** (never above the caller's
    sizing cap), but never below ``align``.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    top = max(align, (max_batch // align) * align)
    sizes = []
    b = align
    while b < top:
        sizes.append(b)
        b *= 2
    sizes.append(top)
    return tuple(sorted(set(sizes)))


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket covering ``n`` requests (caller caps n at max)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class MicroBatcher:
    """Bounded-delay dynamic micro-batcher over priority-class queues."""

    def __init__(
        self,
        frame_shape: Tuple[int, int],
        max_batch: Optional[int] = None,
        max_delay_ms: float = 5.0,
        buckets: Optional[Sequence[int]] = None,
        align: int = 1,
        max_queue: Optional[int] = None,
        priority_weights: Optional[Dict[str, float]] = None,
        pace_ms: float = 0.0,
        clock=time.perf_counter,
        obs_counters: Optional[Dict[str, object]] = None,
    ):
        self.frame_shape = tuple(frame_shape)
        if buckets:
            self.buckets = tuple(sorted(buckets))
            if max_batch is not None and max_batch != self.buckets[-1]:
                raise ValueError(
                    f"max_batch={max_batch} conflicts with explicit buckets "
                    f"{self.buckets} (their top is the max batch — pass one "
                    "or the other, or make them agree)")
        else:
            self.buckets = make_buckets(64 if max_batch is None else max_batch,
                                        align)
        if any(b % align for b in self.buckets):
            raise ValueError(
                f"buckets {self.buckets} must all be multiples of align={align}")
        self.max_batch = self.buckets[-1]
        self.max_delay_s = max_delay_ms / 1e3
        self.max_queue = max_queue
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.pace_s = pace_ms / 1e3
        weights = dict(priority_weights or DEFAULT_PRIORITY_WEIGHTS)
        unknown = set(weights) - set(PRIORITIES)
        if unknown:
            raise ValueError(f"unknown priority classes {sorted(unknown)}; "
                             f"valid: {PRIORITIES}")
        if any(w <= 0 for w in weights.values()):
            raise ValueError(f"priority weights must be > 0, got {weights}")
        for p in PRIORITIES:  # every class dequeues even if not weighted
            weights.setdefault(p, 1.0)
        self._weights = weights
        self._clock = clock
        # one FIFO per priority class; dequeue interleaves them by smooth
        # weighted round-robin (credit scheme, deterministic — no RNG)
        self._pending: Dict[str, collections.deque] = {
            p: collections.deque() for p in PRIORITIES}
        self._credit: Dict[str, float] = {p: 0.0 for p in PRIORITIES}
        self._seq = itertools.count()
        self._last_seq = -1    # highest seq ever submitted
        # exact un-handed tracking for drain_barrier.  A high-water-mark
        # seq is NOT enough: weighted round-robin dequeues realtime ahead
        # of bulk, so a high realtime seq can be handed while lower-seq
        # bulk requests are still queued.  Min-heap of un-handed seqs with
        # lazy deletion (seqs handed out of order park in _handed_out_of_
        # order until they surface at the heap top); both structures are
        # bounded by the live backlog.
        self._unhanded: List[int] = []
        self._handed_out_of_order: set = set()
        self._handed = threading.Condition()
        self._closed = False
        # one lock/condition covers queue state, admission, the close flag
        # and the pace gate: a submit either lands before close (and is
        # served or drained) or raises — no request can slip into the
        # queue after drain() has emptied it
        self._cond = threading.Condition()
        self._next_flush = 0.0  # pace gate: earliest next flush time
        # counters (exact totals, exported by the engine's stats)
        self.n_expired = 0     # requests failed fast on a passed deadline
        self.n_rejected = 0    # submits refused by the max_queue bound
        self.n_cancelled = 0   # cancelled futures dropped at dequeue
        # optional registry mirrors ({"expired"/"rejected"/"cancelled":
        # inc()-able}) — the engine wires its labeled metric children here
        self._obs = dict(obs_counters or {})

    def _obs_inc(self, key: str) -> None:
        c = self._obs.get(key)
        if c is not None:
            c.inc()

    # -- producer side ------------------------------------------------------

    def now(self) -> float:
        """The batcher's clock (deadlines are absolute on this clock)."""
        return self._clock()

    def submit(self, iq: np.ndarray, *, deadline: Optional[float] = None,
               priority: str = "realtime", trace=None) -> ServeFuture:
        """Enqueue one (IC, L) frame; returns a future for its prediction.

        ``deadline`` is absolute (``batcher.now() + budget_s``); ``None``
        never expires.  Raises :class:`QueueFull` when the ``max_queue``
        admission bound is hit — the caller (router) sheds instead of
        queueing unboundedly.  ``trace`` is the request's optional
        :class:`~repro.obs.trace.RequestTrace`; the batcher records the
        queue-transit events on it (the *caller* records the terminal on
        an admission refusal — a router may retry another replica).
        """
        iq = np.asarray(iq, dtype=np.float32)
        if iq.shape != self.frame_shape:
            raise ValueError(
                f"expected frame of shape {self.frame_shape}, got {iq.shape}")
        if priority not in self._pending:
            raise ValueError(f"unknown priority {priority!r}; "
                             f"valid: {PRIORITIES}")
        with self._cond:
            if self._closed:
                raise EngineClosed("MicroBatcher is closed")
            if (self.max_queue is not None
                    and self._depth_locked() >= self.max_queue):
                self.n_rejected += 1
                self._obs_inc("rejected")
                raise QueueFull(
                    f"admission rejected: {self.max_queue} requests queued")
            fut = ServeFuture()
            fut.trace = trace
            seq = next(self._seq)
            self._last_seq = seq
            with self._handed:
                heapq.heappush(self._unhanded, seq)
            tadd(trace, "enqueue", queue_depth=self._depth_locked(),
                 priority=priority)
            self._pending[priority].append(
                Request(seq=seq, iq=iq, t_enqueue=self._clock(), future=fut,
                        deadline=deadline, priority=priority, trace=trace))
            self._cond.notify()
        return fut

    def _depth_locked(self) -> int:
        return sum(len(d) for d in self._pending.values())

    def qsize(self) -> int:
        with self._cond:
            return self._depth_locked()

    def qsizes(self) -> Dict[str, int]:
        """Per-priority-class backlog snapshot."""
        with self._cond:
            return {p: len(d) for p, d in self._pending.items()}

    def drain_barrier(self, timeout: Optional[float] = None) -> bool:
        """Block until every request enqueued *before this call* has been
        handed to a consumer batch (or failed fast); False on timeout.

        This is the hot-swap drain point: after flipping the primary
        version, waiting on the barrier guarantees the pre-flip backlog
        has been batched (on the old or new plan — either way it will be
        served, never dropped).  Requests submitted after the call do not
        extend the wait.

        The wait is on *every* seq <= the snapshot, not a high-water
        mark: priority dequeue hands requests out of seq order, so the
        barrier holds until the smallest un-handed seq moves past the
        target.
        """
        with self._cond:
            target = self._last_seq
        deadline = None if timeout is None else self._clock() + timeout
        with self._handed:
            while self._unhanded and self._unhanded[0] <= target:
                remaining = None
                if deadline is not None:
                    remaining = deadline - self._clock()
                    if remaining <= 0:
                        return False
                self._handed.wait(timeout=remaining)
        return True

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def close(self) -> None:
        """Wake all worker loops; pending get_batch calls return None."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def drain(self) -> List[Request]:
        """Remove and return every still-queued request (after close).

        The engine resolves their futures with an error so no caller is
        left blocking on a request that will never be served.
        """
        with self._cond:
            if not self._closed:
                raise RuntimeError("drain() is only valid after close()")
            pending: List[Request] = []
            for d in self._pending.values():
                pending.extend(d)
                d.clear()
            if pending:
                # drained requests count as handled (their futures are
                # failed by the engine), so a pending drain_barrier wakes
                # instead of waiting on requests that will never batch
                self._mark_handed_all(r.seq for r in pending)
            return pending

    # -- consumer side ------------------------------------------------------

    def _pop_locked(self, expired: List[Request]) -> Optional[Request]:
        """Pop the next live request by weighted priority; None if empty.

        Expired requests are moved to ``expired`` (the caller fails their
        futures *outside* the lock — future callbacks must never run under
        it); cancelled futures are dropped on the spot.  Both count as
        handed so drain barriers never wait on them.
        """
        now = self._clock()
        while True:
            avail = [p for p in PRIORITIES if self._pending[p]]
            if not avail:
                return None
            if len(avail) == 1:
                pick = avail[0]
            else:
                # smooth weighted round-robin (the nginx scheme): credit
                # every non-empty class, pick the richest, debit it by the
                # total — exactly proportional over any window, no bursts
                total = 0.0
                for p in avail:
                    self._credit[p] += self._weights[p]
                    total += self._weights[p]
                pick = max(avail, key=lambda p: (self._credit[p],
                                                 -PRIORITIES.index(p)))
                self._credit[pick] -= total
            r = self._pending[pick].popleft()
            if r.future.cancelled():
                self.n_cancelled += 1
                self._obs_inc("cancelled")
                tadd(r.trace, "cancelled", at="dequeue")
                tfinish(r.trace)
                self._mark_handed(r.seq)
                continue
            if r.deadline is not None and now > r.deadline:
                self.n_expired += 1
                self._obs_inc("expired")
                tadd(r.trace, "expired", at="dequeue")
                tfinish(r.trace)
                self._mark_handed(r.seq)
                expired.append(r)
                continue
            tadd(r.trace, "dequeue")
            return r

    #: sentinel: a gathering round ended with no live request — fail its
    #: expired futures now and start another round
    _RETRY = object()

    def get_batch(self, timeout: Optional[float] = None) -> Optional[MicroBatch]:
        """Block for the next batch; None on timeout or close.

        Waits for a first live request, then keeps draining the queues
        until the batch is full (**size flush**) or ``max_delay`` has
        elapsed since the batch started forming (**timeout flush**).  With
        a pace gate the batch keeps filling until the gate opens, and
        flushes are serialized at least ``pace_ms`` apart.

        Expired requests are failed (outside the lock) at the end of
        *every* gathering round, never held until this call returns — a
        consumer blocking with ``timeout=None`` on an idle queue cannot
        leave ``DeadlineExceeded`` futures unresolved past their round.
        """
        wait_deadline = None if timeout is None else self._clock() + timeout
        while True:
            expired: List[Request] = []
            with self._cond:
                out = self._gather_round_locked(wait_deadline, expired)
            if expired:
                err = DeadlineExceeded(
                    "request deadline expired while queued")
                for r in expired:
                    _fail_quietly(r.future, err)
            if out is self._RETRY:
                continue
            if out is None:
                return None
            reqs, depth = out
            bucket = bucket_for(len(reqs), self.buckets)
            frames = np.zeros((bucket,) + self.frame_shape,
                              dtype=np.float32)
            # trace timestamps stay on perf_counter even under a fake
            # batcher clock — spans must be comparable across events
            t_form = time.perf_counter()
            for i, r in enumerate(reqs):
                frames[i] = r.iq
                tadd(r.trace, "batch-form", t=t_form, bucket=bucket,
                     n_real=len(reqs), n_padded=bucket - len(reqs))
            return MicroBatch(requests=reqs, bucket=bucket, frames=frames,
                              queue_depth=depth)

    def _gather_round_locked(self, wait_deadline: Optional[float],
                             expired: List[Request]):
        """One gathering round under ``_cond``: a ``(reqs, depth)`` batch,
        None (timeout / close), or ``_RETRY`` (round produced only
        expired/cancelled requests — the caller fails ``expired`` outside
        the lock and calls again)."""
        # -- phase 1: first live request (or timeout / close) ---------------
        while True:
            if self._closed:
                return None
            first = self._pop_locked(expired)
            if first is not None:
                break
            if expired:
                # nothing live to batch yet but this round already popped
                # expired requests: hand them back for prompt failure
                # instead of holding them while blocked on the condition
                return self._RETRY
            remaining = None
            if wait_deadline is not None:
                remaining = wait_deadline - self._clock()
                if remaining <= 0:
                    return None
            self._cond.wait(timeout=remaining)
        # -- phase 2: gather until full / max_delay / pace -------------------
        reqs = [first]
        form_deadline = self._clock() + self.max_delay_s
        gather_deadline = max(form_deadline, self._next_flush)
        while not self._closed:
            now = self._clock()
            full = len(reqs) >= self.max_batch
            if now >= gather_deadline and not full:
                break
            if full and now >= self._next_flush:
                break
            if not full:
                nxt = self._pop_locked(expired)
                if nxt is not None:
                    reqs.append(nxt)
                    continue
            # full-but-paced waits for the gate; partial waits for more
            # requests (a submit notifies) or the forming deadline
            until = self._next_flush if full else gather_deadline
            self._cond.wait(timeout=max(0.0, until - now))
        # -- phase 3: pace gate — serialize flushes ---------------------------
        if self.pace_s > 0 and not self._closed:
            while True:
                now = self._clock()
                if now >= self._next_flush or self._closed:
                    break
                self._cond.wait(timeout=self._next_flush - now)
        # flush-time recheck: forming/pacing can outlast a deadline, and a
        # gathered request may have expired or been cancelled since it was
        # popped — it must not ride into the jitted step in a batch slot
        self._mark_handed_all(r.seq for r in reqs)
        now = self._clock()
        live = []
        for r in reqs:
            if r.future.cancelled():
                self.n_cancelled += 1
                self._obs_inc("cancelled")
                tadd(r.trace, "cancelled", at="flush")
                tfinish(r.trace)
            elif r.deadline is not None and now > r.deadline:
                self.n_expired += 1
                self._obs_inc("expired")
                tadd(r.trace, "expired", at="flush")
                tfinish(r.trace)
                expired.append(r)
            else:
                live.append(r)
        if not live:
            return self._RETRY
        if self.pace_s > 0:
            # the pace slot is consumed only by a real flush —
            # all-expired rounds launch no compute
            self._next_flush = self._clock() + self.pace_s
        return live, self._depth_locked()

    def _mark_handed(self, seq: int) -> None:
        self._mark_handed_all((seq,))

    def _mark_handed_all(self, seqs: Iterable[int]) -> None:
        with self._handed:
            self._handed_out_of_order.update(seqs)
            heap = self._unhanded
            while heap and heap[0] in self._handed_out_of_order:
                self._handed_out_of_order.discard(heapq.heappop(heap))
            self._handed.notify_all()


def _fail_quietly(fut, err: BaseException) -> None:
    """set_exception tolerant of cancelled / already-resolved futures."""
    if fut.done():
        return
    try:
        fut.set_exception(err)
    except Exception:  # noqa: BLE001 — lost a cancel race; nothing to do
        pass
