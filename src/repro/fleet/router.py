"""Front-end fleet router: N replica groups behind one admission door.

The single-process :class:`~repro.serve.AsyncAMCServeEngine` tops out at
one host's devices; the fleet tier is the shape that takes "millions of
users": a :class:`FleetRouter` owns N **replica groups** (each a full
async engine — queue, micro-batcher, worker loops, sharded over the serve
mesh when devices allow) and fronts them with the production serving
primitives the single engine lacks:

* **join-shortest-queue dispatch** — each request goes to the replica
  with the smallest backlog (deterministic index tie-break, no RNG);
* **admission control / load shedding** — a replica whose ``max_queue``
  bound is hit rejects; when *every* replica rejects the request is shed
  with :class:`ShedError` at the door (bounded latency above saturation,
  never an unbounded queue).  An optional ``shed_p99_ms`` threshold sheds
  ``bulk``-class traffic early whenever the fleet's recent p99 breaches
  it, protecting realtime headroom;
* **per-request deadlines and priority classes** — propagated to the
  deadline/priority-aware micro-batcher in every replica (expired
  requests fail fast without occupying a batch slot; realtime dequeues
  ahead of bulk by weighted round-robin);
* **elastic capacity** — ``scale_up()`` builds a replica through the
  engine factory and **replays the deploy lineage** (bound versions,
  primary flip, traffic router) so a replica added mid-canary serves
  exactly what its siblings serve; ``scale_down()`` fences a replica off
  from new traffic, drains its backlog, then closes it — zero dropped
  requests.  The :class:`~repro.fleet.autoscaler.Autoscaler` drives both
  against p99/utilization targets.

The router is **engine-like**: it exposes ``cfg`` / ``versions`` /
``bind_version`` / ``swap_to`` / ``set_router`` / ``version_stats`` /
``batcher`` (a fleet-wide facade), so the whole :mod:`repro.deploy`
toolchain — ``hot_swap``, canary routing, ``CanaryMonitor`` — works on a
fleet exactly as on one engine, with every operation fanned out to all
replicas.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.obs.metrics import default_registry
from repro.obs.trace import begin_trace, tadd, tfinish
from repro.serve.batcher import PRIORITIES, EngineClosed, QueueFull
from repro.serve.engine import ServeStats

__all__ = ["ShedError", "Replica", "FleetRouter", "engine_factory",
           "merge_stats"]


class ShedError(RuntimeError):
    """Request refused at the fleet door (admission control).

    ``reason`` is ``"queue"`` (every replica's backlog bound hit) or
    ``"p99"`` (bulk traffic shed while the fleet p99 breaches the
    configured threshold).
    """

    def __init__(self, message: str, reason: str):
        super().__init__(message)
        self.reason = reason


@dataclasses.dataclass
class Replica:
    """One replica group: a name, its engine, and its birth order.

    ``fenced``/``gate`` implement the retirement fence: ``submit`` checks
    the flag and enqueues while holding ``gate``, and ``scale_down`` sets
    the flag under the same gate before draining — so once the fence is
    up, no request (not even one whose replica-list snapshot predates the
    retirement) can slip into the replica's queue behind the drain
    barrier.
    """

    name: str
    engine: Any
    index: int
    fenced: bool = False
    gate: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)


def engine_factory(params, cfg, masks=None, **engine_kwargs):
    """Build a ``name -> AsyncAMCServeEngine`` factory over fixed weights.

    The standard way to hand a :class:`FleetRouter` its replica recipe —
    every replica binds the same weights/config with the same serving
    knobs (``max_batch``, ``max_queue``, ``pace_ms``, ``backend`` ...).
    """
    from repro.serve.engine import AsyncAMCServeEngine

    def make(name: str):
        kw = dict(engine_kwargs)
        # the replica name becomes the {engine=...} label on every serve
        # metric, keeping fleet-wide aggregates separable per replica
        kw.setdefault("name", name)
        return AsyncAMCServeEngine(params, cfg, masks=masks, **kw)

    return make


def _fair_recent(parts: List[List[float]], cap: int) -> List[float]:
    """Concatenate sample windows, trimming *each part's* oldest samples.

    When the combined history exceeds ``cap``, every part contributes its
    most recent ``cap // n_parts`` samples.  Sequential concatenate-then-
    trim would instead keep whichever replicas happened to be appended
    last — with full windows, the merged percentiles would be computed
    over the *final replica only*, silently dropping every other
    replica's tail latencies (the bug pinned by
    ``test_merge_stats_fair_window`` in ``tests/test_fleet.py``).
    """
    total = sum(len(x) for x in parts)
    if total > cap and len(parts) > 1:
        share = max(1, cap // len(parts))
        parts = [x[-share:] for x in parts]
    out: List[float] = []
    for x in parts:
        out.extend(x)
    return out[-cap:]


def merge_stats(parts: List[ServeStats], backend: str = "") -> ServeStats:
    """Aggregate per-replica :class:`ServeStats` into one fleet view.

    Counters add exactly; latency / queue-depth histories concatenate,
    and when the combined history exceeds the class window every replica
    contributes an equal share of its most recent samples (so merged
    percentiles represent the whole fleet, not the last-merged replica);
    ``wall_s`` takes the widest serving window so fleet throughput is
    conservative, never inflated by summing overlapping windows.
    """
    merged = ServeStats(backend=backend)
    for p in parts:
        if not merged.backend:
            merged.backend = p.backend
        merged.requests += p.requests
        merged.batches += p.batches
        merged.accumulations += p.accumulations
        merged.fetched_bits += p.fetched_bits
        merged.padded_frames += p.padded_frames
        merged.wall_s = max(merged.wall_s, p.wall_s)
        for b, n in p.backend_batch_counts().items():
            merged.backend_batch_totals[b] = (
                merged.backend_batch_totals.get(b, 0) + n)
    merged.latencies_s = _fair_recent(
        [list(p.latencies_s) for p in parts], ServeStats.MAX_SAMPLES)
    merged.queue_depths = [int(d) for d in _fair_recent(
        [list(p.queue_depths) for p in parts], ServeStats.MAX_SAMPLES)]
    return merged


class _FleetBatcher:
    """Fleet-wide facade over the replicas' batchers.

    Exposes exactly the surface :func:`repro.deploy.swap.hot_swap` (and
    anything else written against ``engine.batcher``) needs: total
    backlog and a drain barrier spanning every replica.
    """

    def __init__(self, fleet: "FleetRouter"):
        self._fleet = fleet

    def qsize(self) -> int:
        return sum(r.engine.batcher.qsize()
                   for r in self._fleet._snapshot())

    def drain_barrier(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.perf_counter() + timeout
        ok = True
        for rep in self._fleet._snapshot():
            remaining = None
            if deadline is not None:
                remaining = max(0.0, deadline - time.perf_counter())
            ok = rep.engine.batcher.drain_barrier(timeout=remaining) and ok
        return ok

    @property
    def n_expired(self) -> int:
        return sum(r.engine.batcher.n_expired for r in self._fleet._snapshot())

    @property
    def n_rejected(self) -> int:
        return sum(r.engine.batcher.n_rejected
                   for r in self._fleet._snapshot())


class FleetRouter:
    """Admission-controlled router over elastic replica groups.

    ``factory`` is a ``name -> AsyncAMCServeEngine`` callable (see
    :func:`engine_factory`).  ``replicas`` engines are built eagerly;
    ``scale_up``/``scale_down`` move the count within
    ``[min_replicas, max_replicas]``.
    """

    def __init__(
        self,
        factory: Callable[[str], Any],
        *,
        replicas: int = 1,
        min_replicas: int = 1,
        max_replicas: int = 8,
        default_priority: str = "realtime",
        default_deadline_ms: Optional[float] = None,
        shed_p99_ms: Optional[float] = None,
        p99_window: int = 256,
        clock=time.perf_counter,
    ):
        if not 1 <= min_replicas <= max_replicas:
            raise ValueError(
                f"need 1 <= min_replicas <= max_replicas, got "
                f"{min_replicas}..{max_replicas}")
        if not min_replicas <= replicas <= max_replicas:
            raise ValueError(
                f"replicas={replicas} outside [{min_replicas}, "
                f"{max_replicas}]")
        if default_priority not in PRIORITIES:
            raise ValueError(f"unknown priority {default_priority!r}")
        self._factory = factory
        self.min_replicas = min_replicas
        self.max_replicas = max_replicas
        self.default_priority = default_priority
        self.default_deadline_ms = default_deadline_ms
        self.shed_p99_ms = shed_p99_ms
        self.p99_window = p99_window
        self._clock = clock
        # _lock guards the replica list and counters (short critical
        # sections on the submit path); _scale_lock serializes the slow
        # lifecycle operations (replica builds, fleet-wide binds/flips)
        # without ever blocking admission
        self._lock = threading.Lock()
        self._scale_lock = threading.Lock()
        self._replicas: List[Replica] = []
        self._retired: List[Replica] = []
        self._next_index = 0
        # deploy lineage, replayed onto every scale-up replica so late
        # joiners serve the same versions/routing as their siblings
        self._bound: "OrderedDict[str, dict]" = OrderedDict()
        self._primary: Optional[str] = None
        self._shared_router: Optional[Callable[[], str]] = None
        # door-level counters (per shed reason and priority class)
        self.n_shed = 0
        self.shed_by_reason: Dict[str, int] = {"queue": 0, "p99": 0}
        self.shed_by_priority: Dict[str, int] = {p: 0 for p in PRIORITIES}
        self.n_submitted = 0
        # registry mirrors of the door-level counters
        reg = default_registry()
        self._m_submitted = reg.counter(
            "repro_fleet_submitted_total",
            "Requests admitted through the fleet door")
        self._m_shed = reg.counter(
            "repro_fleet_shed_total",
            "Requests refused by fleet admission control",
            ("reason", "priority"))
        self._m_replicas = reg.gauge(
            "repro_fleet_replicas", "Live replica count")
        self.batcher = _FleetBatcher(self)
        for _ in range(replicas):
            rep = self._build_replica()
            with self._lock:
                self._replicas.append(rep)
        self._primary = self._replicas[0].engine.active_version
        self._m_replicas.set(self.n_replicas)

    # -- replica lifecycle --------------------------------------------------

    def _snapshot(self) -> List[Replica]:
        with self._lock:
            return list(self._replicas)

    def _build_replica(self) -> Replica:
        with self._lock:
            index = self._next_index
            self._next_index += 1
            bound = [(label, dict(spec)) for label, spec in self._bound.items()]
            primary = self._primary
            router = self._shared_router
        name = f"replica-{index}"
        engine = self._factory(name)
        # replay the deploy lineage: a replica born mid-canary must serve
        # the same version table, primary, and traffic split as the rest
        for label, spec in bound:
            engine.bind_version(label, **spec)
        if primary is not None and primary != engine.active_version:
            engine.swap_to(primary)
        if router is not None:
            engine.set_router(router)
        return Replica(name=name, engine=engine, index=index)

    @property
    def n_replicas(self) -> int:
        with self._lock:
            return len(self._replicas)

    def replica_names(self) -> List[str]:
        return [r.name for r in self._snapshot()]

    def scale_up(self) -> Optional[str]:
        """Add one replica (replaying the deploy lineage); None at max.

        The engine build/compile runs outside the admission lock — the
        fleet keeps serving while the new replica warms up, and it only
        joins the routing set once fully bound.
        """
        with self._scale_lock:
            if self.n_replicas >= self.max_replicas:
                return None
            rep = self._build_replica()
            with self._lock:
                self._replicas.append(rep)
            self._m_replicas.set(self.n_replicas)
            return rep.name

    def scale_down(self, drain_timeout: float = 30.0) -> Optional[str]:
        """Retire the youngest replica; None at min.

        The replica is fenced off from new traffic first, its backlog is
        drained (every queued request still gets served), and only then
        is its engine closed — scale-down never drops a request.
        """
        with self._scale_lock:
            with self._lock:
                if len(self._replicas) <= self.min_replicas:
                    return None
                rep = self._replicas.pop()  # youngest: cheapest to retire
            # fence: a concurrent submit that snapshotted the replica list
            # before the pop could still enqueue here.  Submits check
            # ``fenced`` and enqueue under ``rep.gate``, so acquiring the
            # gate to raise the flag (a) waits out any submit that already
            # passed the check — its request lands before the barrier's
            # seq snapshot — and (b) guarantees later submits skip this
            # replica.  Only then is the drain target captured.
            with rep.gate:
                rep.fenced = True
            rep.engine.batcher.drain_barrier(timeout=drain_timeout)
            rep.engine.close()
            with self._lock:
                self._retired.append(rep)
            self._m_replicas.set(self.n_replicas)
            return rep.name

    # -- admission / dispatch -----------------------------------------------

    def _shed(self, reason: str, priority: str, detail: str,
              trace=None) -> "ShedError":
        with self._lock:
            self.n_shed += 1
            self.shed_by_reason[reason] = (
                self.shed_by_reason.get(reason, 0) + 1)
            self.shed_by_priority[priority] = (
                self.shed_by_priority.get(priority, 0) + 1)
        self._m_shed.labels(reason=reason, priority=priority).inc()
        tadd(trace, "shed", reason=reason, priority=priority)
        tfinish(trace)
        return ShedError(detail, reason=reason)

    def submit(self, iq: np.ndarray, *, priority: Optional[str] = None,
               deadline_ms: Optional[float] = None):
        """Admit one frame into the least-loaded replica; a future.

        Raises :class:`ShedError` when admission control refuses the
        request (every replica queue full, or bulk traffic during a p99
        breach) — fail fast at the door, never queue unboundedly.
        """
        priority = self.default_priority if priority is None else priority
        if priority not in PRIORITIES:
            raise ValueError(f"unknown priority {priority!r}")
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        # the fleet door originates the request trace: replica attempts,
        # shed decisions and the queue transit all land on one timeline
        trace = begin_trace()
        tadd(trace, "submit", priority=priority)
        if (self.shed_p99_ms is not None and priority == "bulk"
                and self.recent_p99_ms() > self.shed_p99_ms):
            raise self._shed(
                "p99", priority,
                f"bulk traffic shed: fleet p99 above {self.shed_p99_ms}ms",
                trace=trace)
        reps = self._snapshot()
        if not reps:
            raise RuntimeError("fleet has no replicas")
        # join-shortest-queue, deterministic index tie-break; on a full
        # replica fall through to the next-shortest before shedding
        order = sorted(reps, key=lambda r: (r.engine.batcher.qsize(),
                                            r.index))
        for rep in order:
            # check-and-enqueue under the replica's retirement gate: once
            # scale_down raises the fence, no submit — even one holding a
            # pre-retirement list snapshot — can land a request behind the
            # drain barrier.  EngineClosed (a fleet close racing this
            # snapshot) likewise skips to the next candidate; any other
            # error is a real engine fault and propagates.
            with rep.gate:
                if rep.fenced:
                    continue
                # optimistic: recorded before the enqueue so the timeline
                # stays ordered; a refusal appends replica-full after it
                tadd(trace, "admit", replica=rep.name)
                try:
                    fut = rep.engine.submit(iq, deadline_ms=deadline_ms,
                                            priority=priority, trace=trace)
                except (QueueFull, EngineClosed) as e:
                    tadd(trace, "replica-full", replica=rep.name,
                         reason=type(e).__name__)
                    continue
            with self._lock:
                self.n_submitted += 1
            self._m_submitted.inc()
            return fut
        raise self._shed("queue", priority,
                         "all replica queues at their admission bound",
                         trace=trace)

    def classify(self, iq: np.ndarray, timeout: float = 300.0, *,
                 priority: Optional[str] = None,
                 deadline_ms: Optional[float] = None) -> np.ndarray:
        """Blocking convenience wrapper: (N, 2, L) -> class ids (N,).

        Mirrors the engine's: on timeout/failure the outstanding futures
        are cancelled (never leaked into replica queues) before the error
        propagates.
        """
        futures = [self.submit(iq[i], priority=priority,
                               deadline_ms=deadline_ms)
                   for i in range(iq.shape[0])]
        out = np.empty((len(futures),), dtype=np.int32)
        try:
            for i, f in enumerate(futures):
                out[i] = f.result(timeout=timeout)
        except BaseException:
            for f in futures:
                f.cancel()
            raise
        return out

    # -- control-plane signals ----------------------------------------------

    def recent_p99_ms(self, window: Optional[int] = None) -> float:
        """p99 (ms) over the most recent served latencies, fleet-wide."""
        window = self.p99_window if window is None else window
        lat: List[float] = []
        for rep in self._snapshot():
            lat.extend(rep.engine.recent_latencies(window))
        if not lat:
            return 0.0
        return float(np.percentile(lat, 99.0)) * 1e3

    def queue_depth(self) -> int:
        return self.batcher.qsize()

    def signals(self) -> Dict[str, Any]:
        """One control-plane sample: what the autoscaler (and bench) read.

        Cumulative counters (``busy_s``, ``shed``, ``expired``,
        ``requests``) are meant to be differenced between ticks; ``p99_ms``
        and ``queue_depth`` are instantaneous.
        """
        reps = self._snapshot()
        with self._lock:
            shed = self.n_shed
            shed_by_reason = dict(self.shed_by_reason)
        return {
            "t": self._clock(),
            "n_replicas": len(reps),
            "queue_depth": sum(r.engine.batcher.qsize() for r in reps),
            "p99_ms": self.recent_p99_ms(),
            "requests": sum(r.engine.stats.requests for r in reps),
            "busy_s": sum(r.engine.busy_s for r in reps),
            "workers": sum(r.engine.n_workers for r in reps),
            "shed": shed,
            "shed_by_reason": shed_by_reason,
            "expired": sum(r.engine.batcher.n_expired for r in reps),
            "rejected": sum(r.engine.batcher.n_rejected for r in reps),
        }

    def export_stats(self) -> Dict[str, Any]:
        """Fleet digest + per-replica breakdown (JSON-ready)."""
        reps = self._snapshot()
        with self._lock:
            retired = list(self._retired)
        return {
            "n_replicas": len(reps),
            "replicas": {r.name: r.engine.export_stats() for r in reps},
            "retired": [r.name for r in retired],
            "fleet": self.stats.summary(),
            "n_submitted": self.n_submitted,
            "n_shed": self.n_shed,
            "shed_by_reason": dict(self.shed_by_reason),
            "shed_by_priority": dict(self.shed_by_priority),
            "n_expired": self.batcher.n_expired,
        }

    @property
    def stats(self) -> ServeStats:
        """Merged fleet-wide ServeStats (live + retired replicas)."""
        with self._lock:
            engines = [r.engine for r in self._replicas + self._retired]
        return merge_stats([e.stats for e in engines])

    # -- engine-like deploy surface (hot_swap / canary / monitor) -----------

    @property
    def cfg(self):
        return self._snapshot()[0].engine.cfg

    @property
    def active_version(self) -> str:
        with self._lock:
            primary = self._primary
        return primary if primary is not None else \
            self._snapshot()[0].engine.active_version

    def versions(self) -> Dict[str, Any]:
        return self._snapshot()[0].engine.versions()

    def get_version(self, label: str):
        return self._snapshot()[0].engine.get_version(label)

    def bind_version(self, label: str, params, masks=None, **kwargs):
        """Bind a version on *every* replica; recorded for scale-up replay."""
        spec = dict(params=params, masks=masks, **kwargs)
        with self._scale_lock:
            ver = None
            for rep in self._snapshot():
                ver = rep.engine.bind_version(label, **spec)
            with self._lock:
                self._bound[label] = spec
            return ver

    def swap_to(self, label: str) -> str:
        """Flip the primary on every replica; returns the old label."""
        with self._scale_lock:
            old = self.active_version
            for rep in self._snapshot():
                rep.engine.swap_to(label)
            with self._lock:
                self._primary = label
            return old

    def remove_version(self, label: str) -> None:
        with self._scale_lock:
            for rep in self._snapshot():
                rep.engine.remove_version(label)
            with self._lock:
                self._bound.pop(label, None)

    def set_router(self, router: Optional[Callable[[], str]]) -> None:
        """Install one *shared* traffic router across all replicas.

        Sharing a single (thread-safe) router keeps the canary split
        globally proportional — each replica's worker draws from the same
        smooth-weighted-round-robin sequence.
        """
        with self._scale_lock:
            with self._lock:
                self._shared_router = router
            for rep in self._snapshot():
                rep.engine.set_router(router)

    def version_stats(self) -> Dict[str, ServeStats]:
        """Per-label stats merged across replicas (monitor-compatible)."""
        with self._lock:
            engines = [r.engine for r in self._replicas + self._retired]
        by_label: Dict[str, List[ServeStats]] = {}
        for eng in engines:
            for label, st in eng.version_stats().items():
                by_label.setdefault(label, []).append(st)
        return {label: merge_stats(parts)
                for label, parts in by_label.items()}

    # -- readiness / shutdown -----------------------------------------------

    def is_ready(self) -> bool:
        """True once every live replica has served its first jit step."""
        reps = self._snapshot()
        return bool(reps) and all(rep.engine.is_ready() for rep in reps)

    @property
    def closed(self) -> bool:
        with self._lock:
            return not self._replicas and bool(self._retired)

    def close(self) -> None:
        """Close every replica; every queued future resolves (or fails)."""
        with self._scale_lock:
            with self._lock:
                reps = list(self._replicas)
                self._replicas = []
                self._retired.extend(reps)
            for rep in reps:       # fence first: a submit racing shutdown
                with rep.gate:     # sheds at the door instead of landing
                    rep.fenced = True  # a request the close will fail
            for rep in reps:
                rep.engine.close()
            self._m_replicas.set(0)

    def __enter__(self) -> "FleetRouter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
