"""Serving tier: micro-batched streaming AMC inference engines."""

from .autotune import (
    AutotuneReport,
    PerLayerAutotuneReport,
    autotune_backend,
    autotune_per_layer,
    default_candidates,
)
from .batcher import MicroBatch, MicroBatcher, Request, ServeFuture
from .engine import AMCServeEngine, AsyncAMCServeEngine, BoundVersion, ServeStats

__all__ = [
    "AMCServeEngine",
    "AsyncAMCServeEngine",
    "BoundVersion",
    "ServeStats",
    "MicroBatcher",
    "MicroBatch",
    "Request",
    "ServeFuture",
    "AutotuneReport",
    "PerLayerAutotuneReport",
    "autotune_backend",
    "autotune_per_layer",
    "default_candidates",
]
