"""Parameter / activation / state sharding rules (DP x TP x SP x EP).

One rule table covers every assigned architecture.  Rules are keyed on the
*path* of each leaf in the parameter pytree (the layer code gives leaves
stable names: ``wq``, ``wd``, ``moe/wg`` ...) and are **divisibility
checked**: a dim is only sharded when the mesh axis divides it, otherwise
that dim falls back to replication.  Stacked-layer leading axes (the
``(L, ...)`` from the scanned stacks) are auto-detected by rule arity and
left unsharded.

Scheme (Megatron-style TP over the ``model`` axis, DP over ``pod x data``):

=================  =======================================  ==============
leaf               shape                                    spec (last dims)
=================  =======================================  ==============
emb.tok            (V, d)                                   (None, model)
emb.unemb          (d, V)                                   (None, model) | (model, None)
attn wq/wk/wv      (d, H*hd)                                (None, model)  [col]
attn wo            (H*hd, d)                                (model, None)  [row]
mlp wg/wu          (d, ff)                                  (None, model)
mlp wd             (ff, d)                                  (model, None)
moe wg/wu          (E, d, f)                                (model, None, None) EP | (None, None, model) TP
moe wd             (E, f, d)                                (model, None, None) EP | (None, model, None) TP
rglru w_x/w_gate   (d, w)                                   (None, model)
rglru w_r/w_i/out  (w, *)                                   (model, None)
ssm (mamba2)       fused in-proj has unaligned segment      replicated (see
                   boundaries under tiling                  DESIGN.md perf log)
norms/bias/scalar  (d,)                                     replicated
=================  =======================================  ==============

Expert-parallel vs expert-TP is decided per config: ``E % model == 0`` ->
EP (llama4-scout, 16e); otherwise TP inside experts (qwen2-moe, 60e).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "dp_axes",
    "partition_params",
    "named_tree",
    "train_batch_spec",
    "act_pspec",
    "logits_pspec",
    "decode_state_specs",
    "spec_report",
    "serve_mesh",
    "serve_batch_pspec",
    "shard_serve_fn",
]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel mesh axes: pod composes with data when present."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _check(spec_dims: Sequence, shape: Sequence[int], mesh: Mesh):
    """Replicate any dim whose size the assigned axis does not divide."""
    out = []
    for ax, dim in zip(spec_dims, shape):
        out.append(ax if (ax is not None and dim % _axis_size(mesh, ax) == 0) else None)
    return tuple(out)


# rule: regex over the '/'-joined leaf path -> spec builder for LAST dims
# (leading stack dims auto-padded with None).  First match wins.
_COL = ("col",)   # (None, model): shard output features
_ROW = ("row",)   # (model, None): shard input features (partial-sum out)


def _rules(model: str):
    return [
        # --- embeddings (vocab-parallel; vocab padded to 128-multiples) ---
        (r"(^|/)tok$",          (model, None)),
        (r"(^|/)unemb$",        "unemb"),
        (r"(^|/)pos$",          (None, model)),
        # --- attention (sharded only when shards hold WHOLE heads; a
        # fractured head layout makes XLA partial-compute attention
        # scores and all-reduce them: 5.4 GB x 1024 on whisper prefill.
        # Misaligned archs run sequence-parallel attention instead.) ---
        (r"(^|/)(wq|wk|wv)$",   "attn_col"),
        (r"(^|/)(bq|bk|bv)$",   "attn_bias"),
        (r"(^|/)wo$",           "attn_row"),
        (r"(^|/)(q_norm|k_norm)$", None),
        # --- MoE (must precede generic mlp rules) ---
        (r"moe/(wg|wu)$",       "moe_up"),
        (r"moe/wd$",            "moe_down"),
        (r"(^|/)router$",       None),
        # --- dense MLP (swiglu + whisper mlp) ---
        (r"(^|/)(wg|wu|wi|w1)$", (None, model)),
        (r"(^|/)(wd|w2)$",      (model, None)),
        (r"(^|/)b1$",           (model,)),
        (r"(^|/)dec_pos$",      (None, model)),
        # --- RG-LRU ---
        (r"rglru/(w_x|w_gate)$", (None, model)),
        (r"rglru/conv$",        (None, model)),
        (r"rglru/(w_r|w_i)$",   (model, None, None)),  # block-diag (nb, wb, wb)
        (r"rglru/w_out$",       (model, None)),
        (r"rglru/lam$",         (model,)),
        # --- Mamba-2: fused in-proj segments are not tile-aligned; keep
        # replicated at baseline (perf log tracks the sharded variant) ---
        (r"ssm/",               None),
        # whisper conv-frontend stub / layernorm scale+bias / defaults
        (r".*",                 None),
    ]


def _spec_for(path: str, shape: Tuple[int, ...], mesh: Mesh, model: str,
              n_experts: int, head_dim: int = 0) -> P:
    def _heads_align(dim: int) -> bool:
        # With kv-chunked online-softmax attention (no sharded-dim
        # slicing) a fractured head layout is handled by one reshard, so
        # plain divisibility suffices; whole-head alignment is preferred
        # but not required.  (Replicating misaligned projections instead
        # costs 16x their param/grad/moment memory: +3.5 GB/device on
        # llama4-scout train.)
        n = _axis_size(mesh, model)
        return dim % n == 0

    for pat, rule in _rules(model):
        if re.search(pat, path):
            if rule is None:
                return P()
            if rule == "attn_col":     # (d, H*hd)
                dims = (None, model) if _heads_align(shape[-1]) else (None, None)
            elif rule == "attn_bias":  # (H*hd,)
                dims = (model,) if _heads_align(shape[-1]) else (None,)
            elif rule == "attn_row":   # (H*hd, d)
                dims = (model, None) if _heads_align(shape[-2]) else (None, None)
            elif rule == "unemb":
                # (d, V): prefer vocab-sharded logits; fall back to row
                if shape[-1] % _axis_size(mesh, model) == 0:
                    dims = (None, model)
                else:
                    dims = (model, None)
            elif rule == "moe_up":       # (E, d, f)
                if n_experts and n_experts % _axis_size(mesh, model) == 0:
                    dims = (model, None, None)
                else:
                    dims = (None, None, model)
            elif rule == "moe_down":     # (E, f, d)
                if n_experts and n_experts % _axis_size(mesh, model) == 0:
                    dims = (model, None, None)
                else:
                    dims = (None, model, None)
            else:
                dims = rule
            dims = dims[-len(shape):] if len(dims) > len(shape) else dims
            pad = (None,) * (len(shape) - len(dims))
            return P(*_check(pad + tuple(dims), shape, mesh))
    return P()


def partition_params(shape_tree: Any, mesh: Mesh, *, model_axis: str = "model",
                     n_experts: int = 0, head_dim: int = 0,
                     fsdp_axis: Optional[str] = "data") -> Any:
    """PartitionSpec tree for a parameter (or grad/opt-moment) shape tree.

    ``shape_tree`` leaves need only ``.shape`` (ShapeDtypeStruct or array).

    ``fsdp_axis``: ZeRO-3 / fully-sharded data parallelism — after the TP
    rules assign the ``model`` axis, the largest still-unsharded non-stack
    dim of every >=2-D weight is sharded over the data axis.  Weights are
    all-gathered per layer inside the scan loop (XLA overlaps the gather
    with the previous layer's compute), and gradients reduce-scatter back;
    optimizer moments inherit the same spec, so parameter + moment memory
    drops by the data-axis size.  This is what lets the 100B llama4-scout
    train cell fit 16 GB HBM (75 GB/device with TP-only).  The ``pod``
    axis stays pure DP: params replicate across pods, matching the
    fast-ICI-intra / slow-DCN-inter hierarchy.  Disabled (None) for
    pipeline or inference setups that want weights resident.
    """
    fsdp_n = mesh.shape.get(fsdp_axis, 1) if fsdp_axis else 1

    def visit(path, leaf):
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            elif hasattr(p, "name"):
                keys.append(str(p.name))
        path = "/".join(keys)
        spec = _spec_for(path, tuple(leaf.shape), mesh, model_axis,
                         n_experts, head_dim)
        # FSDP must not fracture attention heads either: for q/k/v (head
        # dim last) and o (head dim second-to-last) only head-aligned
        # sharding is allowed on the head dim (whisper's 20x64 heads were
        # re-fractured over `data` by FSDP after the TP rule declined)
        blocked = set()
        leaf_name = keys[-1] if keys else ""
        if head_dim and leaf_name in ("wq", "wk", "wv", "wo", "bq", "bk", "bv"):
            h_i = len(leaf.shape) - (2 if leaf_name == "wo" else 1)
            if (leaf.shape[h_i] % fsdp_n or
                    (leaf.shape[h_i] // fsdp_n) % head_dim):
                blocked.add(h_i)
        if fsdp_axis and fsdp_n > 1 and len(leaf.shape) >= 2:
            dims = list(spec) + [None] * (len(leaf.shape) - len(spec))
            # never shard dim 0 of rank>=3 leaves (the layer-scan axis);
            # choose the largest unsharded dim divisible by the fsdp axis
            lo = 1 if len(leaf.shape) >= 3 else 0
            cands = [
                (leaf.shape[i], i)
                for i in range(len(leaf.shape) - 1, lo - 1, -1)
                if dims[i] is None and leaf.shape[i] % fsdp_n == 0
                and leaf.shape[i] >= 2 * fsdp_n and i not in blocked
            ]
            if cands:
                _, i = max(cands)
                dims[i] = fsdp_axis
                spec = P(*dims)
        return spec

    return jax.tree_util.tree_map_with_path(visit, shape_tree)


def named_tree(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# activations / batches / decode state
# ---------------------------------------------------------------------------

def train_batch_spec(mesh: Mesh, batch: int, *, rank: int = 2) -> P:
    """(B, S, ...) input batch: B over DP axes when divisible."""
    dp = dp_axes(mesh)
    if batch % _axis_size(mesh, dp) != 0:
        dp = None
    return P(dp, *(None,) * (rank - 1))


def act_pspec(mesh: Mesh, batch: int, seq: int, *, seq_shard: bool = True) -> P:
    """Residual stream (B, S, d): B over DP, S over model (sequence
    parallelism — the activation-memory lever that lets 48L x 4k x 256
    training shapes fit HBM; see DESIGN.md §6)."""
    dp = dp_axes(mesh)
    if batch % _axis_size(mesh, dp) != 0:
        dp = None
    s_ax = "model" if (seq_shard and seq % _axis_size(mesh, "model") == 0) else None
    return P(dp, s_ax, None)


def logits_pspec(mesh: Mesh, batch: int, seq: int, vocab: int) -> P:
    """Logits (B, S, V): vocab-shard when divisible, else sequence-shard
    (keeps the fp32 softmax buffer partitioned either way)."""
    dp = dp_axes(mesh)
    if batch % _axis_size(mesh, dp) != 0:
        dp = None
    if vocab % _axis_size(mesh, "model") == 0:
        return P(dp, None, "model")
    s_ax = "model" if seq % _axis_size(mesh, "model") == 0 else None
    return P(dp, s_ax, None)


def decode_state_specs(state_tree: Any, mesh: Mesh, batch: int) -> Any:
    """Decode-state sharding: KV caches (L, B, CTX, nkv, hd) shard B over
    DP and CTX over model (ring-buffer writes stay local — verified no
    all-gather in the partitioned HLO).  SSM / LRU / conv states shard B
    over DP and the widest trailing dim over model when divisible."""
    dp = dp_axes(mesh)
    if batch % _axis_size(mesh, dp) != 0:
        dp = None
    model_n = _axis_size(mesh, "model")

    def visit(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        name = keys[-1] if keys else ""
        shape = tuple(leaf.shape)
        if name == "len" or len(shape) == 0:
            return P()
        if name in ("k", "v", "ck", "cv") and len(shape) >= 4:
            # (..., B, CTX, nkv, hd)
            ctx_ax = "model" if shape[-3] % model_n == 0 else None
            lead = (None,) * (len(shape) - 4)
            return P(*lead, dp if shape[-4] % max(1, _axis_size(mesh, dp)) == 0 and dp else None,
                     ctx_ax, None, None)
        if name in ("k_scale", "v_scale") and len(shape) >= 3:
            # (..., B, CTX, nkv): shard CTX with the int8 cache it scales
            ctx_ax = "model" if shape[-2] % model_n == 0 else None
            lead = (None,) * (len(shape) - 3)
            return P(*lead, dp if shape[-3] % max(1, _axis_size(mesh, dp)) == 0 and dp else None,
                     ctx_ax, None)
        # generic state: (L, B, ...) — shard B over dp, last dim over model
        dims = [None] * len(shape)
        if len(shape) >= 2:
            dims[1] = dp if dp and shape[1] % _axis_size(mesh, dp) == 0 else None
        if shape[-1] % model_n == 0 and len(shape) >= 3:
            dims[-1] = "model"
        return P(*dims)

    return jax.tree_util.tree_map_with_path(visit, state_tree)


def serve_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D ``("data",)`` mesh over local devices for the serving tier.

    Serving is pure data parallelism: the SNN is tiny (fits any single
    device many times over) so the only axis worth sharding is the request
    batch.  A 1-device mesh is valid and keeps the shard_map code path
    identical from laptop to pod.
    """
    from repro.compat import AxisType, make_mesh

    n = n_devices if n_devices is not None else jax.local_device_count()
    return make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))


def serve_batch_pspec(mesh: Mesh) -> P:
    """Leading-axis batch spec for serve batches on a ``serve_mesh``."""
    return P("data" if "data" in mesh.axis_names else None)


def shard_serve_fn(fn, mesh: Mesh):
    """shard_map-wrap a batched ``(B, ...) -> (B, ...)`` fn over ``data``.

    The per-shard body is embarrassingly parallel (no collectives): each
    device runs the bound program on its slice of the request batch.  The
    micro-batcher guarantees every bucket size is a multiple of the data
    axis, so the split is always even.  Callers still jit the result.
    """
    from repro.compat import shard_map

    spec = serve_batch_pspec(mesh)
    return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                     check_vma=False)


def spec_report(spec_tree: Any, shape_tree: Any) -> str:
    """Human-readable param-spec table (used by dryrun --verbose)."""
    lines = []

    def visit(path, spec, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        lines.append(f"  {'/'.join(keys):60s} {str(tuple(leaf.shape)):28s} {spec}")

    jax.tree_util.tree_map_with_path(
        lambda p, s, l: visit(p, s, l), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
    return "\n".join(lines)
