"""Streaming-emulator equivalence + LIF dynamics invariants."""
import numpy as np
import jax
import jax.numpy as jnp
from _hyp import given, settings, st

from repro.core.lif import init_lif_params, lif_step, lif_unroll, spike
from repro.core.saocds import (
    max_pool_spikes,
    saocds_conv_layer,
    schedule_interpreter,
    sw_conv_layer,
    wm_fc_layer,
)
from repro.core.sparse_format import build_schedule, coo_from_dense, weight_mask_from_dense


def _layer_case(seed, kw, ic, oc, wi, t, w_density):
    rng = np.random.default_rng(seed)
    k = ((rng.random((kw, ic, oc)) < w_density) * rng.normal(size=(kw, ic, oc))).astype(
        np.float32
    )
    frames = (rng.random((t, ic, wi)) < 0.5).astype(np.float32)
    return k, frames


stream_cases = st.tuples(
    st.integers(0, 2**31 - 1),
    st.integers(1, 4),                      # kw
    st.integers(1, 5),                      # ic
    st.integers(1, 7),                      # oc
    st.integers(5, 16),                     # wi
    st.integers(1, 4),                      # timesteps
    st.sampled_from([0.02, 0.1, 0.5, 1.0]),  # includes extreme sparsity
)


@settings(max_examples=15)
@given(stream_cases)
def test_schedule_interpreter_equals_fast_path(case):
    """The faithful Algorithm-2 emulator and the vectorized path agree
    bitwise-closely for every sparsity pattern, including ones that force
    empty and extra iterations."""
    seed, kw, ic, oc, wi, t, wd = case
    if wi < kw:
        wi = kw + 1
    k, frames = _layer_case(seed, kw, ic, oc, wi, t, wd)
    coo = coo_from_dense(k)
    sched = build_schedule(coo)
    lif = init_lif_params((oc, 1), alpha=0.8, theta=0.9, v_th=0.5)
    oi = wi - kw + 1
    out_i, vf_i, counts = schedule_interpreter(jnp.asarray(frames), sched, lif, oi, oc)
    out_f, vf_f = saocds_conv_layer(jnp.asarray(frames), coo, lif)
    np.testing.assert_allclose(np.asarray(out_i), np.asarray(out_f), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vf_i), np.asarray(vf_f), rtol=1e-5, atol=1e-5)
    assert counts["reps_per_timestep"] == sched.reps


def test_saocds_equals_sw_baseline():
    """GOAP streaming and the dense SW baseline compute identical layers."""
    k, frames = _layer_case(3, 3, 4, 6, 14, 5, 0.4)
    coo = coo_from_dense(k)
    lif = init_lif_params((6, 1))
    out_g, vf_g = saocds_conv_layer(jnp.asarray(frames), coo, lif)
    out_s, vf_s = sw_conv_layer(jnp.asarray(frames), jnp.asarray(k), lif)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_s), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(vf_g), np.asarray(vf_s), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# LIF invariants
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.99), st.floats(0.1, 2.0))
def test_lif_spike_implies_potential_drop(seed, alpha, theta):
    rng = np.random.default_rng(seed)
    p = init_lif_params((8,), alpha=alpha, theta=theta, v_th=0.5)
    v = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    v_next, s = lif_step(v, c, p)
    v_acc = p.alpha * v + c
    # where a spike fired, potential dropped by exactly theta
    np.testing.assert_allclose(
        np.asarray(v_next), np.asarray(v_acc - p.theta * s), rtol=1e-6
    )
    # spikes only where v_acc exceeded threshold
    assert bool(jnp.all((s == 1) == (v_acc > p.v_th)))


def test_lif_bounded_potential_under_bounded_input():
    """With soft reset and decay, the membrane potential stays bounded for
    bounded input current."""
    p = init_lif_params((4,), alpha=0.9, theta=1.0, v_th=1.0)
    currents = jnp.ones((200, 4)) * 0.7
    spikes, v_fin = lif_unroll(currents, p)
    assert bool(jnp.all(jnp.abs(v_fin) < 20.0))
    assert spikes.mean() > 0  # it does fire


def test_surrogate_gradient_nonzero():
    """The Heaviside has a usable surrogate derivative near threshold."""
    g = jax.grad(lambda u: spike(u).sum())(jnp.asarray([-0.1, 0.0, 0.1]))
    assert bool(jnp.all(g > 0))
    # far from threshold the surrogate vanishes (fast sigmoid)
    g_far = jax.grad(lambda u: spike(u).sum())(jnp.asarray([100.0]))
    assert float(g_far[0]) < 1e-3


def test_max_pool_spikes_is_logical_or():
    s = jnp.asarray([[1.0, 0.0, 0.0, 0.0], [0.0, 0.0, 1.0, 1.0]])
    out = max_pool_spikes(s, 2)
    np.testing.assert_array_equal(np.asarray(out), [[1.0, 0.0], [0.0, 1.0]])


def test_wm_fc_layer_matches_manual():
    rng = np.random.default_rng(0)
    w = ((rng.random((10, 3)) < 0.5) * rng.normal(size=(10, 3))).astype(np.float32)
    wm = weight_mask_from_dense(w)
    frames = (rng.random((4, 10)) < 0.5).astype(np.float32)
    lif = init_lif_params((3,))
    out, vf = wm_fc_layer(jnp.asarray(frames), wm, lif)
    # manual scan
    v = np.zeros(3, dtype=np.float32)
    alpha = float(np.asarray(lif.alpha)[0])
    for t in range(4):
        v = alpha * v + frames[t] @ w
        s = (v > 1.0).astype(np.float32)
        v -= s
        np.testing.assert_allclose(np.asarray(out[t]), s, rtol=1e-6)
