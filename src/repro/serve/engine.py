"""Streaming AMC inference engines (sync baseline + async serving tier).

Mirrors the accelerator's deployment mode: a continuous stream of I/Q
frames is sigma-delta encoded and classified through the unified
``SNNProgram`` layer graph.  Two engines share one stats/counting core:

* :class:`AMCServeEngine` — the original synchronous per-chunk loop
  (fixed-size batches, numpy encode on the host).  Kept as the serving
  baseline and for callers that want a blocking, single-threaded path.
* :class:`AsyncAMCServeEngine` — the production-style tier: a request
  queue feeds a dynamic micro-batcher (size/timeout flush, tail padded to
  fixed bucket shapes so the jitted program never re-specializes — the
  software form of the paper's fixed iteration schedule); worker loops fan
  batches across devices via ``shard_map`` over a 1-D data mesh; the
  Σ-Δ encoder is traced into the compiled step; and a warmup-race
  autotuner picks the fastest backend for the serving batch shape at bind
  time (``backend="auto"``).

Both engines bind through :func:`repro.plan.compile_plan`, so COO kernels
and schedules come from the content-addressed plan cache — an engine
restart on unchanged weights rebuilds nothing (the software form of the
paper's offline precomputation).  The async tier additionally supports
``backend="per-layer"``: a layer-by-layer backend race whose winning
heterogeneous assignment is served through the fused single-scan
streaming executor.

Both engines report the cost-model counters (accumulations, fetched bits)
that the power model consumes, which backend served each batch, and —
new in the async tier era — per-request latency percentiles, sampled
queue depths, and padded-frame counts.

The async engine serves from a **version table** (label ->
:class:`BoundVersion`, each with its own compiled step and
:class:`ServeStats`): :meth:`~AsyncAMCServeEngine.bind_version` compiles
a new model off the hot path, :meth:`~AsyncAMCServeEngine.swap_to` flips
the primary atomically between micro-batches, and
:meth:`~AsyncAMCServeEngine.set_router` splits traffic across versions —
the hooks :mod:`repro.deploy` (registry / hot-swap / canary monitor)
drives.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import Counter
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.cost_model import bits_fetched, fc_wm_counts, goap_conv_counts
from repro.core.saocds import max_pool_spikes, pad_same, saocds_conv_layer
from repro.core.sparse_format import weight_mask_from_dense
from repro.data.pipeline import sigma_delta_encode_batch, sigma_delta_encode_np
from repro.models.graph import compile_snn
from repro.models.snn import SNNConfig, sparsify_params
from repro.plan import compile_plan
from repro.serve.autotune import (
    AutotuneReport,
    PerLayerAutotuneReport,
    autotune_backend,
    autotune_per_layer,
)
from repro.obs.activity import ActivityObserver
from repro.obs.metrics import default_registry
from repro.obs.trace import begin_trace, tadd, tfinish
from repro.serve.batcher import EngineClosed, MicroBatcher, QueueFull

__all__ = ["AMCServeEngine", "AsyncAMCServeEngine", "ServeStats",
           "BoundVersion"]


@dataclasses.dataclass
class ServeStats:
    # Sample histories are bounded: a long-lived tier must not leak memory,
    # so percentiles/means are over the most recent MAX_SAMPLES entries.
    MAX_SAMPLES = 65536

    requests: int = 0
    batches: int = 0
    accumulations: int = 0
    fetched_bits: int = 0
    wall_s: float = 0.0
    backend: str = ""
    batch_backends: List[str] = dataclasses.field(default_factory=list)
    backend_batch_totals: Dict[str, int] = dataclasses.field(default_factory=dict)
    latencies_s: List[float] = dataclasses.field(default_factory=list)
    queue_depths: List[int] = dataclasses.field(default_factory=list)
    padded_frames: int = 0

    def record_batch(self, backend: str, queue_depth: Optional[int] = None,
                     padded: int = 0) -> None:
        """Account one served batch (exact totals + bounded history)."""
        self.batches += 1
        self.padded_frames += padded
        self.backend_batch_totals[backend] = (
            self.backend_batch_totals.get(backend, 0) + 1)
        self.batch_backends.append(backend)
        if len(self.batch_backends) > self.MAX_SAMPLES:
            del self.batch_backends[: -self.MAX_SAMPLES]
        if queue_depth is not None:
            self.queue_depths.append(queue_depth)
            if len(self.queue_depths) > self.MAX_SAMPLES:
                del self.queue_depths[: -self.MAX_SAMPLES]

    def record_latencies(self, values) -> None:
        """Append per-request latencies, keeping the window bounded."""
        self.latencies_s.extend(values)
        if len(self.latencies_s) > self.MAX_SAMPLES:
            del self.latencies_s[: -self.MAX_SAMPLES]

    def throughput_samples_per_s(self, frame_len: int = 128) -> float:
        if self.wall_s == 0:
            return 0.0
        return self.requests * frame_len / self.wall_s

    def throughput_fps(self) -> float:
        """Requests (frames) classified per wall second."""
        return self.requests / self.wall_s if self.wall_s else 0.0

    # -- latency percentiles ------------------------------------------------

    def latency_percentile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.percentile(self.latencies_s, q))

    @property
    def p50_ms(self) -> float:
        return self.latency_percentile(50.0) * 1e3

    @property
    def p95_ms(self) -> float:
        return self.latency_percentile(95.0) * 1e3

    @property
    def p99_ms(self) -> float:
        return self.latency_percentile(99.0) * 1e3

    def backend_batch_counts(self) -> Dict[str, int]:
        """Exact per-backend batch totals (survive the history trimming)."""
        if self.backend_batch_totals:
            return dict(self.backend_batch_totals)
        return dict(Counter(self.batch_backends))  # directly-built stats

    def mean_queue_depth(self) -> float:
        return float(np.mean(self.queue_depths)) if self.queue_depths else 0.0

    def summary(self) -> dict:
        """JSON-ready digest (what BENCH_serve.json records)."""
        return {
            "requests": self.requests,
            "batches": self.batches,
            "backend": self.backend,
            "backend_batch_counts": self.backend_batch_counts(),
            "throughput_fps": self.throughput_fps(),
            "throughput_samples_per_s": self.throughput_samples_per_s(),
            "p50_ms": self.p50_ms,
            "p95_ms": self.p95_ms,
            "p99_ms": self.p99_ms,
            "mean_queue_depth": self.mean_queue_depth(),
            "padded_frames": self.padded_frames,
            "accumulations": self.accumulations,
            "fetched_bits": self.fetched_bits,
            "wall_s": self.wall_s,
        }


def _fail_future(fut, err: BaseException) -> None:
    """set_exception tolerant of callers that cancelled or already-done."""
    if fut.done():
        return
    try:
        fut.set_exception(err)
    except Exception:  # noqa: BLE001 — lost a cancel race; nothing to do
        pass


def _quant_fn_for(lsq_scales, quant_bits: int, backend=None):
    """Fresh per-bind quant closure for a backend assignment.

    Fixed assignments always get a :class:`repro.fixed.FixedQuantFn`
    (which calibrates per layer when no LSQ state exists) so the integer
    datapath has a step size to fold; float assignments keep the classic
    behavior — trained fake-quant with LSQ state, None without.
    """
    from repro.fixed import serving_quant_fn

    return serving_quant_fn(lsq_scales, quant_bits, assignment=backend)


def _uses_fixed(backend) -> bool:
    from repro.fixed import assignment_uses_fixed

    return assignment_uses_fixed(backend)


def count_batch_activity(stats: ServeStats, sparse, frames: np.ndarray,
                         cfg: SNNConfig) -> None:
    """Exact event counts through the conv stack (cost-model hooks).

    ``frames``: (B, T, IC, L) encoded spikes, **real rows only** — padded
    tail rows must be stripped by the caller so padding never leaks into
    the activity stats.
    """
    # the WM layout depends only on the fixed weights — build it once per
    # batch, not once per frame (counting the dominant FC is enough)
    wm = weight_mask_from_dense(np.asarray(sparse["fc"][0]["w"]))
    for b in range(frames.shape[0]):
        x = frames[b]  # (T, IC, L)
        for layer in sparse["conv"]:
            coo = layer["coo"]
            padded = np.asarray(pad_same(jnp.asarray(x), coo.kw))
            c = goap_conv_counts(padded, coo)
            stats.accumulations += c.accumulations
            stats.fetched_bits += bits_fetched(c)
            # advance the stream (cheap dense emulation for counting)
            out, _ = saocds_conv_layer(jnp.asarray(padded), coo, layer["lif"])
            x = np.asarray(max_pool_spikes(out, cfg.pool))
        c = fc_wm_counts(x.reshape(x.shape[0], -1), wm)
        stats.accumulations += c.accumulations
        stats.fetched_bits += bits_fetched(c)


class AMCServeEngine:
    """Synchronous per-chunk serving loop (the pre-tier baseline)."""

    def __init__(
        self,
        params,
        cfg: SNNConfig,
        masks=None,
        batch_size: int = 32,
        count_activity: bool = False,
        backend: str = "goap",
        lsq_scales=None,
        quant_bits: int = 16,
    ):
        self.cfg = cfg
        self.batch_size = batch_size
        self.count_activity = count_activity
        self.backend = backend
        self.program = compile_snn(cfg)
        # COO form only feeds the activity-counting hooks
        self.sparse = sparsify_params(params, masks) if count_activity else None
        self.stats = ServeStats(backend=backend)
        # precompiled plan: COO/schedule artifacts come from the content-
        # addressed cache, so engine restarts on unchanged weights rebuild
        # nothing (the software form of the paper's offline precomputation)
        self.plan = compile_plan(self.program, params, masks=masks,
                                 quant_fn=_quant_fn_for(lsq_scales,
                                                        quant_bits,
                                                        backend),
                                 assignment=backend)
        self._fwd = jax.jit(self.plan.preferred_batch())

    def _encode(self, chunk: np.ndarray) -> np.ndarray:
        """Host-side Σ-Δ encode; the fixed backend gets the integer path."""
        if _uses_fixed(self.backend):
            from repro.fixed.golden import golden_encode_frames

            return np.moveaxis(
                golden_encode_frames(chunk, self.cfg.timesteps), 0, 1)
        return sigma_delta_encode_np(chunk, self.cfg.timesteps)

    def classify(self, iq: np.ndarray) -> np.ndarray:
        """iq: (N, 2, L) -> predicted class ids (N,). Batches internally."""
        n = iq.shape[0]
        preds = np.empty((n,), dtype=np.int32)
        t0 = time.perf_counter()
        for s in range(0, n, self.batch_size):
            chunk = iq[s : s + self.batch_size]
            pad = self.batch_size - chunk.shape[0]
            if pad:
                chunk = np.concatenate([chunk, np.zeros((pad,) + chunk.shape[1:], chunk.dtype)])
            frames = self._encode(chunk)
            logits = np.asarray(self._fwd(jnp.asarray(frames)))
            n_real = self.batch_size - pad
            preds[s : s + n_real] = logits[:n_real].argmax(-1)
            self.stats.record_batch(self.backend, padded=pad)
            # latency is arrival (classify() start) -> chunk completion,
            # matching the async tier's enqueue->completion semantics so
            # the two engines' percentiles are directly comparable
            self.stats.record_latencies(
                [time.perf_counter() - t0] * n_real)
            if self.count_activity:
                self._count(frames[:n_real])
        self.stats.requests += n
        self.stats.wall_s += time.perf_counter() - t0
        return preds

    def _count(self, frames: np.ndarray) -> None:
        count_batch_activity(self.stats, self.sparse, frames, self.cfg)


@dataclasses.dataclass
class BoundVersion:
    """One bound model version in the async engine's serving table.

    The engine serves from a label -> ``BoundVersion`` table: the primary
    label takes all traffic unless a router (canary / A/B split) is
    installed.  Each version carries its own compiled step, plan, and
    :class:`ServeStats`, so a canary's latency and accuracy are observable
    independently of the production baseline.
    """

    label: str
    backend: str
    step: Any = dataclasses.field(repr=False)
    plan: Any = dataclasses.field(repr=False)
    sparse: Any = dataclasses.field(repr=False)
    stats: ServeStats = dataclasses.field(default_factory=ServeStats)
    # start of *this version's* serving window (earliest enqueue among the
    # requests it served) — a late-bound canary's wall_s/throughput must
    # not be diluted by traffic that predates its bind
    t_first: float = float("inf")
    # live-counter mode: the version's step returns (logits, per-conv
    # accumulation counts) and this ActivityObserver records them; None
    # means the step returns bare logits
    activity: Any = dataclasses.field(default=None, repr=False)


class AsyncAMCServeEngine:
    """Async sharded serving tier: queue -> micro-batcher -> worker loops.

    Usage::

        engine = AsyncAMCServeEngine(params, cfg, masks=masks,
                                     backend="auto", max_batch=64)
        fut = engine.submit(iq_frame)        # (2, L) -> future
        pred = fut.result()                  # class id
        preds = engine.classify(iq_frames)   # (N, 2, L) convenience wrapper
        engine.close()

    ``backend="auto"`` races the platform's candidate backends on the
    largest bucket shape and pins the winner (``engine.autotune`` keeps the
    full report).  ``backend="per-layer"`` races them **layer by layer**
    (plan cost priors order each race; ``engine.perlayer`` keeps the
    report) and serves the winning heterogeneous assignment through the
    fused single-scan streaming executor (``engine.plan``).  With more
    than one local device (or an explicit ``mesh``) every batch is fanned
    across the mesh's ``data`` axis via ``shard_map``; bucket sizes are
    forced to multiples of the device count so the split is always even.
    """

    def __init__(
        self,
        params,
        cfg: SNNConfig,
        masks=None,
        *,
        backend: str = "auto",
        max_batch: Optional[int] = None,   # default 64 (or buckets[-1])
        max_delay_ms: float = 5.0,
        buckets: Optional[Sequence[int]] = None,
        workers: int = 1,
        max_queue: Optional[int] = None,
        pace_ms: float = 0.0,
        priority_weights=None,
        mesh=None,
        count_activity: bool = False,
        warmup: bool = True,
        candidates: Optional[Sequence[str]] = None,
        autotune_reps: int = 2,
        version_label: str = "default",
        lsq_scales=None,
        quant_bits: int = 16,
        name: Optional[str] = None,
        activity_gauges: bool = True,
    ):
        self.cfg = cfg
        self.count_activity = count_activity
        self.quant_bits = quant_bits
        self.program = compile_snn(cfg)
        self.sparse = sparsify_params(params, masks) if count_activity else None
        # observability identity: the {engine=...} label on every serve
        # metric (the fleet factory passes the replica name, so fleet-wide
        # aggregates stay separable per replica)
        self.name = name if name is not None else "engine"
        self.activity_gauges = activity_gauges

        if mesh is None and jax.local_device_count() > 1:
            from repro.distributed.sharding import serve_mesh

            mesh = serve_mesh()
        self.mesh = mesh
        align = int(mesh.shape["data"]) if mesh is not None else 1

        # registry instrumentation: all families are idempotent creates on
        # the process-wide registry, children pre-resolved off the hot path
        reg = default_registry()
        eng = self.name
        self._m_requests = reg.counter(
            "repro_serve_requests_total", "Requests served (real frames)",
            ("engine",)).labels(engine=eng)
        self._m_batches = reg.counter(
            "repro_serve_batches_total", "Micro-batches served",
            ("engine", "backend"))
        self._m_padded = reg.counter(
            "repro_serve_padded_frames_total",
            "Zero-padded tail rows shipped in fixed-shape buckets",
            ("engine",)).labels(engine=eng)
        self._m_latency = reg.histogram(
            "repro_serve_request_latency_seconds",
            "Per-request enqueue-to-completion latency",
            ("engine",)).labels(engine=eng)
        self._m_qdepth = reg.gauge(
            "repro_serve_queue_depth",
            "Queue backlog observed at the last batch flush",
            ("engine",)).labels(engine=eng)
        obs_counters = {
            "expired": reg.counter(
                "repro_serve_expired_total",
                "Requests failed fast on a passed deadline",
                ("engine",)).labels(engine=eng),
            "rejected": reg.counter(
                "repro_serve_rejected_total",
                "Submits refused by the max_queue admission bound",
                ("engine",)).labels(engine=eng),
            "cancelled": reg.counter(
                "repro_serve_cancelled_total",
                "Cancelled futures dropped without a batch slot",
                ("engine",)).labels(engine=eng),
        }

        ic0 = cfg.conv_specs[0][1]
        self.batcher = MicroBatcher(
            frame_shape=(ic0, cfg.input_width), max_batch=max_batch,
            max_delay_ms=max_delay_ms, buckets=buckets, align=align,
            max_queue=max_queue, pace_ms=pace_ms,
            priority_weights=priority_weights, obs_counters=obs_counters)

        self.autotune: Optional[AutotuneReport] = None
        self.perlayer: Optional[PerLayerAutotuneReport] = None
        self.plan = None
        self.assignment: Optional[Dict[str, str]] = None
        raced_steps: Dict[str, object] = {}
        if backend == "per-layer":
            # race the candidates layer by layer (plan cost priors order the
            # race) and serve the winning heterogeneous assignment through
            # the fused single-scan streaming executor
            self.perlayer = autotune_per_layer(
                self.program, params, self.batcher.max_batch, masks=masks,
                candidates=candidates, reps=autotune_reps)
            self.assignment = dict(self.perlayer.assignment)
            self.plan = compile_plan(self.program, params, masks=masks,
                                     quant_fn=_quant_fn_for(lsq_scales,
                                                            quant_bits,
                                                            self.assignment),
                                     assignment=self.assignment)
        elif backend == "auto":
            probe_shape = (self.batcher.max_batch, ic0, cfg.input_width)
            if candidates is None and lsq_scales is not None:
                # quantized serving: the integer `fixed` backend competes
                from repro.serve.autotune import default_candidates

                candidates = default_candidates(quantized=True)

            def make_fn(bound):  # memoize so the winner's compile is reused
                fn = self._wrap_bound(bound)
                raced_steps[bound.backend] = fn
                return fn

            # with LSQ state the race binds carry the fake-quant (or, for
            # the fixed candidate, integer) weights so timings measure the
            # quantized serving step that would actually run
            self.autotune = autotune_backend(
                self.program, params, probe_shape, masks=masks,
                quant_fn=_quant_fn_for(lsq_scales, quant_bits),
                candidates=candidates, reps=autotune_reps, make_fn=make_fn)
            backend = self.autotune.choice
        self.backend = backend
        self.stats = ServeStats(backend=backend)
        # live activity gauges need a counter-returning step: single-host
        # only (the shard_map wrapper carries bare logits) and only for
        # assignments whose conv layers count in-graph
        counters_wanted = activity_gauges and mesh is None
        if self.plan is not None:           # per-layer: fused streaming step
            self._step = self._wrap_batch_fn(
                self.plan.batch, int_encode=_uses_fixed(self.assignment))
        elif (backend in raced_steps and lsq_scales is None
              and not (counters_wanted
                       and backend in ("stream", "pallas_fused"))):
            # reuse the race winner's compile (without LSQ state the race
            # bind is the serving bind; with it the winner is only a
            # backend choice — the serving step is rebuilt through the
            # cached plan below so restarts stay near-free)
            self._step = raced_steps[backend]
        else:                               # cached plan bind
            self.plan = compile_plan(self.program, params, masks=masks,
                                     quant_fn=_quant_fn_for(lsq_scales,
                                                            quant_bits,
                                                            backend),
                                     assignment=backend)
            self._step = self._wrap_batch_fn(self.plan.preferred_batch(),
                                             int_encode=_uses_fixed(backend))
        self._activity: Optional[ActivityObserver] = None
        if (counters_wanted and self.plan is not None
                and self.plan.supports_live_counters):
            self._step = self._wrap_batch_fn(
                self.plan.batch_counters,
                int_encode=_uses_fixed(self.assignment or backend))
            self._activity = ActivityObserver(self.plan, engine=self.name)

        # readiness: armed by the first successful jitted step (warmup
        # counts), what /readyz keys on — distinct from liveness
        self._ready = threading.Event()
        if warmup:  # pre-compile every bucket shape so serving never stalls
            for b in self.batcher.buckets:
                jax.block_until_ready(
                    self._step(jnp.zeros((b, ic0, cfg.input_width), jnp.float32)))
            self._ready.set()

        # serving table: label -> BoundVersion.  The primary takes all
        # traffic unless a router is installed (deploy.router); hot-swap
        # (deploy.swap) binds a new version off-thread then flips _primary
        # between micro-batches.
        self._versions: Dict[str, BoundVersion] = {
            version_label: BoundVersion(
                label=version_label, backend=self.backend, step=self._step,
                plan=self.plan, sparse=self.sparse,
                stats=ServeStats(backend=self.backend),
                activity=self._activity),
        }
        self._primary = version_label
        self._router: Optional[Callable[[], str]] = None

        self._lock = threading.Lock()
        self._t_first_enqueue = float("inf")  # start of the serving window
        self._t_started = time.perf_counter()
        self._busy_s = 0.0  # cumulative worker time spent serving batches
        self._stop = threading.Event()
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"amc-serve-worker-{i}")
            for i in range(max(1, workers))
        ]
        for t in self._threads:
            t.start()

    # -- compiled step ------------------------------------------------------

    def _wrap_batch_fn(self, batch_fn, int_encode: bool = False):
        """Fuse Σ-Δ encode + forward (+ shard_map) under one jit.

        ``batch_fn``: (B, T, IC, L) spike frames -> (B, n_classes) logits —
        a bound program's layer-by-layer ``batch`` or an ExecutionPlan's
        fused streaming ``batch``.  ``int_encode`` routes through the
        integer Q0.15 Σ-Δ front end (the fixed tier's encoder).
        """
        osr = self.cfg.timesteps
        if int_encode:
            from repro.fixed import fixed_encode_batch as encode
        else:
            encode = sigma_delta_encode_batch

        def step(iq):  # (B, IC, L) raw I/Q -> (B, n_classes) logits
            return batch_fn(encode(iq, osr))

        if self.mesh is not None:
            from repro.distributed.sharding import shard_serve_fn

            step = shard_serve_fn(step, self.mesh)
        return jax.jit(step)

    def _wrap_bound(self, bound):
        return self._wrap_batch_fn(bound.batch,
                                   int_encode=_uses_fixed(bound.backend))

    # -- worker loop --------------------------------------------------------

    def _route(self) -> BoundVersion:
        """Pick the version serving the next batch (router, else primary).

        A router naming a label that was removed mid-flight falls back to
        the primary — routing can degrade, never crash the worker loop.
        The table read happens under the engine lock so it can never
        interleave with a swap_to/remove_version pair: the invariant that
        the primary is always in the table holds while the lock is held.
        """
        label: Optional[str] = None
        router = self._router
        if router is not None:
            try:
                label = router()
            except Exception:  # noqa: BLE001 — a broken router must not
                label = None   # take the serving loop down with it
        with self._lock:
            ver = self._versions.get(label) if label is not None else None
            return ver if ver is not None else self._versions[self._primary]

    def _worker(self) -> None:
        while not self._stop.is_set():
            batch = self.batcher.get_batch(timeout=0.1)
            if batch is None:
                continue
            t_busy0 = time.perf_counter()
            try:
                # the version is pinned *per batch*: a hot-swap flipping
                # the primary mid-service never retargets an in-flight
                # batch, so its futures complete on the plan that started
                # them.  Routing runs inside the covered block: if it ever
                # raises, the batch's futures fail instead of stranding.
                ver = self._route()
                t_step0 = time.perf_counter()
                out = ver.step(jnp.asarray(batch.frames))
                if ver.activity is not None:
                    logits_dev, accs = out
                    logits = np.asarray(logits_dev)
                else:
                    accs = None
                    logits = np.asarray(out)
                t_step1 = time.perf_counter()
                self._ready.set()  # first successful jit step: /readyz 200
                preds = logits.argmax(-1).astype(np.int32)
                n_real = batch.n_real
                if accs is not None:
                    ver.activity.observe(
                        {k: np.asarray(v) for k, v in accs.items()}, n_real)
                # activity counting is an expensive diagnostics mode; it
                # runs outside the lock (workers stay parallel) but before
                # the futures resolve, so a caller that reads ``stats``
                # right after its results always sees them counted
                counted: Optional[ServeStats] = None
                if self.count_activity and ver.sparse is not None:
                    counted = ServeStats()
                    frames = sigma_delta_encode_np(
                        batch.frames[:n_real], self.cfg.timesteps)
                    count_batch_activity(counted, ver.sparse, frames,
                                         self.cfg)
                # completion is stamped after counting: callers' futures
                # resolve after it, so latencies reflect what they waited
                t_done = time.perf_counter()
                with self._lock:
                    # serving window: first enqueue ever -> latest batch
                    # completion.  Correct for both the submit()/future
                    # path and (possibly concurrent) classify() callers.
                    # Each version additionally tracks its own window so a
                    # late-bound canary's throughput is not diluted.
                    batch_first = min(r.t_enqueue for r in batch.requests)
                    self._t_first_enqueue = min(self._t_first_enqueue,
                                                batch_first)
                    ver.t_first = min(ver.t_first, batch_first)
                    for st, t0 in ((self.stats, self._t_first_enqueue),
                                   (ver.stats, ver.t_first)):
                        st.requests += n_real
                        st.record_batch(ver.backend,
                                        queue_depth=batch.queue_depth,
                                        padded=batch.n_padded)
                        st.record_latencies(
                            t_done - r.t_enqueue for r in batch.requests)
                        # max(): a worker delayed by activity counting must
                        # not shrink a window another worker extended
                        st.wall_s = max(st.wall_s, t_done - t0)
                        if counted is not None:
                            st.accumulations += counted.accumulations
                            st.fetched_bits += counted.fetched_bits
                # registry mirrors (family-locked; outside the engine lock)
                self._m_requests.inc(n_real)
                self._m_batches.labels(engine=self.name,
                                       backend=ver.backend).inc()
                self._m_padded.inc(batch.n_padded)
                self._m_qdepth.set(batch.queue_depth)
                for r in batch.requests:
                    self._m_latency.observe(t_done - r.t_enqueue)
                    if r.trace is not None:
                        # the jitted step is batch-wide: every traced rider
                        # shares the same explicit start/end stamps
                        r.trace.add("jit-step-start", t=t_step0,
                                    version=ver.label, backend=ver.backend)
                        r.trace.add("jit-step-end", t=t_step1)
                for i, r in enumerate(batch.requests):
                    # transitions PENDING -> RUNNING (after which cancel()
                    # can no longer win the race); False = caller cancelled
                    # while queued — skip, don't poison the batch
                    if r.future.set_running_or_notify_cancel():
                        tadd(r.trace, "complete", pred=int(preds[i]))
                        tfinish(r.trace)
                        r.future.set_result(int(preds[i]))
                    else:
                        tadd(r.trace, "cancelled", at="resolve")
                        tfinish(r.trace)
            except Exception as e:  # noqa: BLE001 — propagate to callers;
                # the whole batch path is covered so a stats/counting error
                # can never strand a future or kill the worker loop
                for r in batch.requests:
                    tadd(r.trace, "error", detail=str(e))
                    tfinish(r.trace)
                    _fail_future(r.future, e)
            finally:
                with self._lock:
                    self._busy_s += time.perf_counter() - t_busy0

    # -- model lifecycle (deploy subsystem hooks) ---------------------------

    @property
    def active_version(self) -> str:
        """Label of the primary (default-traffic) version."""
        return self._primary

    def versions(self) -> Dict[str, BoundVersion]:
        """Snapshot of the serving table (label -> BoundVersion)."""
        with self._lock:
            return dict(self._versions)

    def get_version(self, label: str) -> BoundVersion:
        return self._versions[label]

    def version_stats(self) -> Dict[str, ServeStats]:
        with self._lock:
            return {k: v.stats for k, v in self._versions.items()}

    def bind_version(self, label: str, params, masks=None, *,
                     backend: Optional[str] = None,
                     lsq_scales=None, quant_bits: Optional[int] = None,
                     warmup: bool = True) -> BoundVersion:
        """Compile and register a new model version under ``label``.

        Safe to call from any thread while serving: the compile (plan bind
        + per-bucket warmup) runs in the *caller's* thread against the
        content-addressed plan cache, and only the final table insert
        takes the engine lock — workers keep draining batches on the
        current versions throughout.  The new version takes no traffic
        until :meth:`swap_to` or a router targets it.

        ``backend=None`` inherits the engine's serving backend (including
        a ``per-layer`` heterogeneous assignment); ``backend="auto"``
        re-races the candidates for the new weights.
        """
        if backend is None:
            backend = self.backend
        bits = quant_bits if quant_bits is not None else self.quant_bits
        qfn = _quant_fn_for(lsq_scales, bits, backend)
        plan = None
        if backend == "per-layer":
            if not self.assignment:
                # silently serving a uniform fallback while reporting
                # "per-layer" would misstate what runs; the heterogeneous
                # race only exists on engines constructed with it
                raise ValueError(
                    "backend='per-layer' requires an engine constructed "
                    "with backend='per-layer' (no autotuned assignment to "
                    "inherit); pass an explicit backend instead")
            qfn = _quant_fn_for(lsq_scales, bits, self.assignment)
            plan = compile_plan(self.program, params, masks=masks,
                                quant_fn=qfn, assignment=self.assignment)
            step = self._wrap_batch_fn(
                plan.batch, int_encode=_uses_fixed(self.assignment))
        else:
            if backend == "auto":
                ic0 = self.cfg.conv_specs[0][1]
                probe = (self.batcher.max_batch, ic0, self.cfg.input_width)
                backend = autotune_backend(self.program, params, probe,
                                           masks=masks).choice
                qfn = _quant_fn_for(lsq_scales, bits, backend)
            plan = compile_plan(self.program, params, masks=masks,
                                quant_fn=qfn, assignment=backend)
            step = self._wrap_batch_fn(plan.preferred_batch(),
                                       int_encode=_uses_fixed(backend))
        sparse = sparsify_params(params, masks) if self.count_activity else None
        activity = None
        if (self.activity_gauges and self.mesh is None and plan is not None
                and plan.supports_live_counters):
            enc = self.assignment if backend == "per-layer" else backend
            step = self._wrap_batch_fn(plan.batch_counters,
                                       int_encode=_uses_fixed(enc))
            activity = ActivityObserver(plan, engine=self.name)
        if warmup:  # pre-compile every bucket so the flip never stalls
            ic0 = self.cfg.conv_specs[0][1]
            for b in self.batcher.buckets:
                jax.block_until_ready(
                    step(jnp.zeros((b, ic0, self.cfg.input_width),
                                   jnp.float32)))
        ver = BoundVersion(label=label, backend=backend, step=step,
                           plan=plan, sparse=sparse,
                           stats=ServeStats(backend=backend),
                           activity=activity)
        with self._lock:
            self._versions[label] = ver
        return ver

    def swap_to(self, label: str) -> str:
        """Atomically make ``label`` the primary version; returns the old.

        The flip is a table-pointer update between micro-batches:
        in-flight batches complete on the version that started them, and
        the next batch any worker picks up serves from the new primary —
        no request is dropped or blocked for more than one batch flush.
        """
        with self._lock:
            if label not in self._versions:
                raise KeyError(
                    f"no bound version {label!r} (bound: "
                    f"{sorted(self._versions)})")
            old, self._primary = self._primary, label
            ver = self._versions[label]
            self.backend = ver.backend
            self.plan = ver.plan
            self.stats.backend = ver.backend
        return old

    def remove_version(self, label: str) -> None:
        """Drop a non-primary version from the serving table."""
        with self._lock:
            if label == self._primary:
                raise ValueError(
                    f"cannot remove the primary version {label!r}; "
                    "swap_to another version first")
            self._versions.pop(label, None)

    def set_router(self, router: Optional[Callable[[], str]]) -> None:
        """Install (or clear, with None) the per-batch version router."""
        self._router = router

    # -- fleet-facing signals ----------------------------------------------

    @property
    def n_workers(self) -> int:
        return len(self._threads)

    @property
    def busy_s(self) -> float:
        """Cumulative worker seconds spent serving batches."""
        with self._lock:
            return self._busy_s

    def utilization(self) -> float:
        """Busy fraction of total worker capacity since construction.

        The autoscaler prefers *windowed* utilization (deltas of
        ``busy_s`` between control ticks); this cumulative form is the
        zero-state fallback and what ``export_stats`` reports.
        """
        elapsed = time.perf_counter() - self._t_started
        if elapsed <= 0:
            return 0.0
        return min(1.0, self.busy_s / (elapsed * self.n_workers))

    def recent_latencies(self, k: int = 256) -> List[float]:
        """Last ``k`` served-request latencies (seconds), oldest first."""
        with self._lock:
            return list(self.stats.latencies_s[-k:])

    def export_stats(self) -> dict:
        """Per-replica control-plane snapshot (what the fleet aggregates).

        Extends ``stats.summary()`` with the live queue/admission signals
        the router and autoscaler act on: current queue depth, expired /
        rejected / cancelled totals from the batcher, and worker
        utilization.
        """
        s = self.stats.summary()
        s.update({
            "queue_depth": self.batcher.qsize(),
            "queue_depths_by_priority": self.batcher.qsizes(),
            "n_expired": self.batcher.n_expired,
            "n_rejected": self.batcher.n_rejected,
            "n_cancelled": self.batcher.n_cancelled,
            "workers": self.n_workers,
            "busy_s": self.busy_s,
            "utilization": self.utilization(),
            "active_version": self.active_version,
        })
        return s

    # -- public API ---------------------------------------------------------

    def submit(self, iq: np.ndarray, *, deadline_ms: Optional[float] = None,
               priority: str = "realtime", trace=None):
        """Enqueue one (2, L) frame; returns a ``ServeFuture``.

        ``deadline_ms`` is a relative latency budget: a request still
        queued when it expires fails fast with ``DeadlineExceeded``
        instead of occupying a micro-batch slot.  ``priority`` picks the
        dequeue class (``realtime`` > ``bulk``, weighted).

        ``trace=None`` starts a fresh request trace when tracing is
        enabled; a caller that already owns one (the fleet router) passes
        it through and keeps responsibility for its failure terminals.
        """
        deadline = (None if deadline_ms is None
                    else self.batcher.now() + deadline_ms / 1e3)
        owned = False
        if trace is None:
            trace = begin_trace()
            owned = trace is not None
            tadd(trace, "submit", engine=self.name, priority=priority)
        try:
            return self.batcher.submit(iq, deadline=deadline,
                                       priority=priority, trace=trace)
        except (QueueFull, EngineClosed) as e:
            if owned:  # a router-owned trace may retry another replica
                tadd(trace, "reject", reason=type(e).__name__)
                tfinish(trace)
            raise

    def classify(self, iq: np.ndarray, timeout: float = 300.0, *,
                 deadline_ms: Optional[float] = None,
                 priority: str = "realtime") -> np.ndarray:
        """Blocking convenience wrapper: (N, 2, L) -> class ids (N,).

        ``stats.wall_s`` is maintained by the worker loop as the serving
        window (first enqueue -> latest completion), so it is consistent
        whether requests arrive through here or through ``submit()``.

        On timeout (or any per-request failure) the outstanding futures
        are cancelled before the error propagates — an abandoned classify
        call never leaks still-pending requests into the batcher (the
        dequeue path drops cancelled futures without giving them a batch
        slot).  Requests already inside an in-flight batch complete
        normally; their results are simply discarded.
        """
        futures = [self.submit(iq[i], deadline_ms=deadline_ms,
                               priority=priority)
                   for i in range(iq.shape[0])]
        out = np.empty((len(futures),), dtype=np.int32)
        try:
            for i, f in enumerate(futures):
                out[i] = f.result(timeout=timeout)
        except BaseException:
            for f in futures:
                f.cancel()  # no-op for done/running futures
            raise
        return out

    def is_ready(self) -> bool:
        """True once the first jitted step succeeded (and not closed)."""
        return self._ready.is_set() and not self._stop.is_set()

    @property
    def closed(self) -> bool:
        return self._stop.is_set()

    def close(self) -> None:
        """Stop the workers; no future is ever left unresolved.

        In-flight batches finish (workers join after their current batch);
        requests still queued are drained and their futures failed with a
        ``RuntimeError`` so blocked callers wake instead of hanging.
        """
        self._stop.set()
        self.batcher.close()
        for t in self._threads:
            t.join(timeout=5.0)
        err = RuntimeError("AsyncAMCServeEngine closed before serving "
                           "this request")
        for r in self.batcher.drain():
            tadd(r.trace, "cancelled", at="close")
            tfinish(r.trace)
            _fail_future(r.future, err)

    def __enter__(self) -> "AsyncAMCServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
