"""End-to-end observability: metrics registry, request tracing, activity
telemetry, and the HTTP exposition endpoint.

See README "Observability" for the metric naming scheme and examples.
"""
from repro.obs.activity import (
    SCHEDULE_KEYS,
    ActivityObserver,
    static_schedule_counts,
)
from repro.obs.http import MetricsServer
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS,
    MetricsRegistry,
    default_registry,
    set_default_registry,
)
from repro.obs.trace import (
    TERMINAL_EVENTS,
    RequestTrace,
    TraceEvent,
    TraceLog,
    begin_trace,
    disable_tracing,
    enable_tracing,
    get_tracer,
    tadd,
    tfinish,
)

__all__ = [
    "MetricsRegistry",
    "default_registry",
    "set_default_registry",
    "DEFAULT_LATENCY_BUCKETS",
    "TraceEvent",
    "RequestTrace",
    "TraceLog",
    "TERMINAL_EVENTS",
    "enable_tracing",
    "disable_tracing",
    "get_tracer",
    "begin_trace",
    "tadd",
    "tfinish",
    "ActivityObserver",
    "static_schedule_counts",
    "SCHEDULE_KEYS",
    "MetricsServer",
]
