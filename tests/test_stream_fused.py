"""Parity tests for the multi-layer fused Pallas streaming kernel.

The ``pallas_fused`` backend collapses the whole network into one
``pallas_call`` with every layer's LIF state in VMEM
(:mod:`repro.kernels.stream_fused`).  It must be *invisible* numerically:

* logits match the dense oracle at atol 1e-5 across seeded configs;
* the per-conv gated-accumulation counters match the ``stream``
  backend's Tables I/III counters **exactly** (integer equality — the
  counts·row_sums identity is exact in f32 for integer-valued totals);
* on the paper config the counters hit the same pinned literals as
  ``tests/test_stream_golden.py``;
* the batched kernel path equals per-sample runs, and the fused Σ-Δ
  encode path equals encode-then-forward.

Everything runs in interpret mode on CPU; the compiled-mode test is
skipped unless a real TPU is attached.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import compile_plan, compile_snn, init_snn
from repro.core.encoder import sigma_delta_encode
from repro.kernels.stream_fused import fused_stack_of, stream_fused_forward
from repro.models.snn import SNNConfig
from repro.train.pruning import make_mask_pytree

SMALL = SNNConfig(conv_specs=((3, 2, 4), (3, 4, 8)), pool=2,
                  fc_specs=((64, 16), (16, 5)), input_width=32,
                  timesteps=4, n_classes=5).validate()
DENSITY = 0.5
# 10 seeded (weights, mask density, input) configurations
SEED_GRID = [(seed, density) for seed in range(5)
             for density in (0.3, 0.6)]


def _setup(cfg, seed, density):
    program = compile_snn(cfg)
    params = init_snn(jax.random.PRNGKey(seed), cfg)
    masks = make_mask_pytree(params, density)
    rng = np.random.default_rng(seed)
    frames = jnp.asarray(
        (rng.random((cfg.timesteps, cfg.conv_specs[0][1],
                     cfg.input_width)) < 0.5).astype(np.float32))
    return program, params, masks, frames


def _fused_plan(program, params, masks):
    return compile_plan(program, params, masks=masks,
                        assignment="pallas_fused")


@pytest.mark.parametrize("seed,density", SEED_GRID)
def test_fused_matches_dense_oracle(seed, density):
    program, params, masks, frames = _setup(SMALL, seed, density)
    want = np.asarray(program.apply(params, frames, "dense", masks=masks))
    plan = _fused_plan(program, params, masks)
    assert fused_stack_of(plan) is not None, "plan did not fuse"
    got, _ = plan.run_streaming(frames)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


@pytest.mark.parametrize("seed", range(3))
def test_fused_counters_equal_stream_backend_exactly(seed):
    program, params, masks, frames = _setup(SMALL, seed, DENSITY)
    _, want = program.apply(params, frames, "stream", masks=masks,
                            return_counters=True)
    plan = _fused_plan(program, params, masks)
    _, got = plan.run_streaming(frames)
    assert set(got) == set(want)
    for name in want:
        for key in ("reps_per_timestep", "compute_iters", "extra_iters",
                    "empty_iters", "accumulations", "timesteps"):
            assert int(np.asarray(got[name][key])) == \
                int(np.asarray(want[name][key])), (
                    f"{name}.{key}: fused kernel counter diverged from "
                    f"the stream backend")


def test_fused_counters_match_golden_paper_config():
    """The in-kernel counters reproduce the pinned Tables I/III literals
    on the full paper config (same setup as tests/test_stream_golden.py)."""
    from test_stream_golden import GOLDEN_LAYERS, _setup as golden_setup

    program, params, masks, frames = golden_setup()
    plan = _fused_plan(program, params, masks)
    assert fused_stack_of(plan) is not None
    logits, counters = plan.run_streaming(frames)
    want = np.asarray(program.apply(params, frames, "dense", masks=masks))
    np.testing.assert_allclose(np.asarray(logits), want, atol=1e-5)
    assert set(counters) == set(GOLDEN_LAYERS)
    for name, golden in GOLDEN_LAYERS.items():
        for key, val in golden.items():
            assert int(np.asarray(counters[name][key])) == val, (
                f"{name}.{key}: fused kernel drifted off the golden "
                f"Tables I/III value")


def test_batched_kernel_equals_per_sample():
    program, params, masks, _ = _setup(SMALL, 0, DENSITY)
    plan = _fused_plan(program, params, masks)
    stack = fused_stack_of(plan)
    rng = np.random.default_rng(7)
    frames_b = jnp.asarray(
        (rng.random((3, SMALL.timesteps, SMALL.conv_specs[0][1],
                     SMALL.input_width)) < 0.5).astype(np.float32))
    logits_b, accs_b = stream_fused_forward(stack, frames_b)
    for i in range(frames_b.shape[0]):
        logits_1, accs_1 = stream_fused_forward(stack, frames_b[i:i + 1])
        np.testing.assert_array_equal(np.asarray(logits_b[i]),
                                      np.asarray(logits_1[0]))
        np.testing.assert_array_equal(np.asarray(accs_b[i]),
                                      np.asarray(accs_1[0]))
    # and the plan's batch entry point (what the engine jits) agrees with
    # the layer-by-layer bound program
    want = np.asarray(plan.bound.batch(frames_b))
    np.testing.assert_allclose(np.asarray(plan.batch(frames_b)), want,
                               atol=1e-5)


def test_fused_sigma_delta_encode_matches_encode_then_forward():
    """encode=True fuses the Σ-Δ modulator into the kernel: feeding the
    normalized analog frame must equal modulating first and streaming the
    resulting spike frames."""
    program, params, masks, _ = _setup(SMALL, 1, DENSITY)
    plan = _fused_plan(program, params, masks)
    stack = fused_stack_of(plan)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.random((2, SMALL.conv_specs[0][1],
                                SMALL.input_width)).astype(np.float32))
    frames = jnp.moveaxis(sigma_delta_encode(x, SMALL.timesteps), 0, 1)
    want_logits, want_accs = stream_fused_forward(stack, frames)
    got_logits, got_accs = stream_fused_forward(stack, x, encode=True)
    np.testing.assert_array_equal(np.asarray(got_logits),
                                  np.asarray(want_logits))
    np.testing.assert_array_equal(np.asarray(got_accs),
                                  np.asarray(want_accs))


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic kernel needs a real TPU")
def test_fused_compiled_matches_interpret():
    program, params, masks, frames = _setup(SMALL, 0, DENSITY)
    plan = _fused_plan(program, params, masks)
    stack = fused_stack_of(plan)
    li, ai = stream_fused_forward(stack, frames[None], interpret=True)
    lc, ac = stream_fused_forward(stack, frames[None], interpret=False)
    np.testing.assert_allclose(np.asarray(lc), np.asarray(li), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ac), np.asarray(ai))
