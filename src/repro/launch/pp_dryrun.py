import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=256")

"""Pipeline-parallel production dry-run: the paper's inter-layer streaming
at pod scale.

The SAOCDS accelerator streams activations layer-to-layer through
per-layer hardware stages (paper §III).  This driver maps the same
structure onto the production pod: llama3-8b's 32 layers become 8
pipeline stages of 4 layers on a (stage=8, data=2, model=16) = 256-chip
mesh — ``spmd_pipeline`` (shard_map + ppermute, fixed tick schedule with
explicit bubble slots) over stages, pjit TP/DP inside each stage.

Usage: PYTHONPATH=src python -m repro.launch.pp_dryrun [--arch llama3-8b]
Writes experiments/dryrun/pp/<arch>__prefill_pp.json.
"""
import argparse
import functools
import json
import pathlib
import sys
import time

__all__ = ["main"]


def run(arch: str = "llama3-8b", n_micro: int = 16, seq: int = 4096,
        batch: int = 32) -> dict:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.compat import AxisType, make_mesh

    from repro.configs.registry import get_config
    from repro.distributed.ctx import activation_constraints
    from repro.distributed.pipeline import spmd_pipeline
    from repro.distributed.sharding import partition_params
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import HW
    from repro.models.config import ArchConfig
    from repro.models.lm import _block_apply, _stack_layout, init_lm
    from repro.models.layers import mask_vocab_pad, rms_norm

    cfg = get_config(arch)
    assert cfg.family == "dense", "PP demo targets the dense decoder archs"
    n_stages = 8
    assert cfg.n_layers % n_stages == 0
    per_stage = cfg.n_layers // n_stages
    mesh = make_mesh((n_stages, 2, 16), ("stage", "data", "model"),
                     axis_types=(AxisType.Auto,) * 3)
    chips = len(mesh.devices.flat)
    mb = batch // n_micro

    # ---- parameter shapes: layer stack regrouped (stages, per_stage, ...)
    shapes = jax.eval_shape(
        functools.partial(init_lm, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    (kind, count), = _stack_layout(cfg)
    stack_sd = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct((n_stages, per_stage) + x.shape[1:],
                                       x.dtype),
        shapes["stacks"][0])
    # TP specs for the inner (per_stage, ...) tree, then prepend the stage axis
    inner_specs = partition_params(
        jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), stack_sd),
        mesh, head_dim=cfg.hd)
    stack_specs = jax.tree_util.tree_map(
        lambda s: P("stage", *tuple(s)), inner_specs,
        is_leaf=lambda x: isinstance(x, P))
    emb_sd = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), shapes["emb"])
    emb_specs = partition_params(emb_sd, mesh, head_dim=cfg.hd)
    norm_sd = jax.ShapeDtypeStruct(shapes["final_norm"].shape,
                                   shapes["final_norm"].dtype)

    tokens_sd = jax.ShapeDtypeStruct((n_micro, mb, seq), jnp.int32)

    def stage_fn(p_stage, x):
        def body(h, layer_p):
            out, _ = _block_apply(cfg, kind, layer_p, h, None, 0)
            return out, None

        x, _ = jax.lax.scan(body, x, p_stage)
        return x

    def pp_prefill(stacks, emb, final_norm, tokens):
        x = emb["tok"][tokens]                       # (n_micro, mb, S, d)
        x = x.reshape(n_micro * mb, seq, -1)
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(None, "model", None)))
        x = x.reshape(n_micro, mb, seq, -1)
        y = spmd_pipeline(stage_fn, stacks, x, mesh, stage_axis="stage",
                          collect="stack")
        y = rms_norm(y[:, :, -1:], final_norm, cfg.norm_eps)
        logits = mask_vocab_pad(y @ emb["unemb"], cfg)
        return logits                                 # (n_micro, mb, 1, V)

    jitted = jax.jit(
        pp_prefill,
        in_shardings=(
            jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                   stack_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                   emb_specs,
                                   is_leaf=lambda x: isinstance(x, P)),
            NamedSharding(mesh, P()),
            NamedSharding(mesh, P()),
        ),
        out_shardings=NamedSharding(mesh, P(None, None, None, "model")),
    )

    rec = {"arch": arch, "mesh": {"stage": n_stages, "data": 2, "model": 16},
           "n_micro": n_micro, "microbatch_rows": mb, "seq": seq,
           "ticks": n_micro + n_stages - 1,
           "bubble_fraction": (n_stages - 1) / (n_micro + n_stages - 1)}
    t0 = time.perf_counter()
    with mesh, activation_constraints(
            NamedSharding(mesh, P(None, None, "model", None))):
        lowered = jitted.lower(stack_sd, emb_sd, norm_sd, tokens_sd)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 1)
    ma = compiled.memory_analysis()
    print(ma)
    live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
            + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
    rec["memory"] = {"peak_live_bytes": live,
                     "fits_16g_hbm": bool(live < 16 * 1024**3)}
    a = analyze_hlo(compiled.as_text())
    rec["hlo"] = a.summary()
    peak, hbm, ici = HW["peak_flops_bf16"], HW["hbm_bw"], HW["ici_bw"]
    rec["terms_s"] = {
        "compute": a.dot_flops / peak,
        "memory": a.bytes_accessed / hbm,
        "collective": a.collective_bytes / ici,
    }
    rec["ok"] = True
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--n-micro", type=int, default=16)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)
    rec = run(args.arch, n_micro=args.n_micro)
    out = pathlib.Path(args.out) / "pp"
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{args.arch}__prefill_pp.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    print(json.dumps({k: rec[k] for k in
                      ("arch", "ok", "ticks", "bubble_fraction", "terms_s")},
                     default=str))
    return 0 if rec.get("ok") else 1


if __name__ == "__main__":
    sys.exit(main())
