"""Dynamic micro-batching request queue for the serving tier.

The accelerator sustains 23.5 MS/s because its pipeline never sees a
control bubble: every frame enters a fixed iteration schedule.  The
software analogue is a micro-batcher that gathers individual requests into
**fixed-shape** batches: batch sizes are drawn from a static bucket ladder
(powers of two up to ``max_batch``) and the tail of a partially-filled
bucket is zero-padded, so the jitted program only ever sees ``len(buckets)``
distinct shapes and never re-specializes under load.

Flush policy (the standard dynamic-batching trade-off):

* **size flush** — the batch reaches ``max_batch`` requests: ship now,
  throughput-optimal;
* **timeout flush** — ``max_delay`` elapsed since the batch started
  forming: ship what we have (padded up to the smallest covering bucket),
  bounding added tail latency to ``max_delay`` under light traffic.

``MicroBatcher`` is transport-only — it knows nothing about models or
backends; the engine's worker loops consume :class:`MicroBatch` objects
and resolve each request's :class:`ServeFuture`.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import itertools
import queue
import threading
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ServeFuture",
    "Request",
    "MicroBatch",
    "make_buckets",
    "bucket_for",
    "MicroBatcher",
]


class ServeFuture(concurrent.futures.Future):
    """Future for one serve request (stdlib ``Future`` semantics).

    Resolved by the engine's worker loop — ``result(timeout=...)`` blocks
    until the micro-batch containing this request has been served, or
    raises the worker's exception / a shutdown ``RuntimeError``.
    """


@dataclasses.dataclass
class Request:
    """One enqueued classification request (a single I/Q frame)."""

    seq: int
    iq: np.ndarray            # (IC, L) float32
    t_enqueue: float
    future: ServeFuture


@dataclasses.dataclass
class MicroBatch:
    """A flushed batch: real requests plus zero-padded tail rows."""

    requests: List[Request]
    bucket: int               # fixed batch shape this batch was padded to
    frames: np.ndarray        # (bucket, IC, L) — rows >= n_real are padding
    queue_depth: int          # backlog remaining in the queue at flush time

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def n_padded(self) -> int:
        return self.bucket - len(self.requests)


def make_buckets(max_batch: int, align: int = 1) -> Tuple[int, ...]:
    """Power-of-two bucket ladder up to ``max_batch``, ``align``-aligned.

    ``align`` is the device count of the serving mesh: every bucket must be
    divisible by it so the batch axis shards evenly.  A ``max_batch`` that
    is not itself aligned is rounded **down** (never above the caller's
    sizing cap), but never below ``align``.
    """
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    top = max(align, (max_batch // align) * align)
    sizes = []
    b = align
    while b < top:
        sizes.append(b)
        b *= 2
    sizes.append(top)
    return tuple(sorted(set(sizes)))


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket covering ``n`` requests (caller caps n at max)."""
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class MicroBatcher:
    """Bounded-delay dynamic micro-batcher over a thread-safe queue."""

    _CLOSE = object()  # sentinel waking (and re-waking) worker loops

    def __init__(
        self,
        frame_shape: Tuple[int, int],
        max_batch: Optional[int] = None,
        max_delay_ms: float = 5.0,
        buckets: Optional[Sequence[int]] = None,
        align: int = 1,
        clock=time.perf_counter,
    ):
        self.frame_shape = tuple(frame_shape)
        if buckets:
            self.buckets = tuple(sorted(buckets))
            if max_batch is not None and max_batch != self.buckets[-1]:
                raise ValueError(
                    f"max_batch={max_batch} conflicts with explicit buckets "
                    f"{self.buckets} (their top is the max batch — pass one "
                    "or the other, or make them agree)")
        else:
            self.buckets = make_buckets(64 if max_batch is None else max_batch,
                                        align)
        if any(b % align for b in self.buckets):
            raise ValueError(
                f"buckets {self.buckets} must all be multiples of align={align}")
        self.max_batch = self.buckets[-1]
        self.max_delay_s = max_delay_ms / 1e3
        self._clock = clock
        self._q: "queue.Queue" = queue.Queue()
        self._seq = itertools.count()
        self._last_seq = -1    # highest seq ever submitted
        self._handed_seq = -1  # highest seq handed to a consumer batch
        self._handed = threading.Condition()
        self._closed = False
        # serializes submit vs close/drain: a submit either lands before
        # the close sentinel (and is served or drained) or raises — no
        # request can slip into the queue after drain() has emptied it
        self._state_lock = threading.Lock()

    # -- producer side ------------------------------------------------------

    def submit(self, iq: np.ndarray) -> ServeFuture:
        """Enqueue one (IC, L) frame; returns a future for its prediction."""
        iq = np.asarray(iq, dtype=np.float32)
        if iq.shape != self.frame_shape:
            raise ValueError(
                f"expected frame of shape {self.frame_shape}, got {iq.shape}")
        with self._state_lock:
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            fut = ServeFuture()
            seq = next(self._seq)
            self._last_seq = seq
            self._q.put(Request(seq=seq, iq=iq,
                                t_enqueue=self._clock(), future=fut))
        return fut

    def qsize(self) -> int:
        return self._q.qsize()

    def drain_barrier(self, timeout: Optional[float] = None) -> bool:
        """Block until every request enqueued *before this call* has been
        handed to a consumer batch; False on timeout.

        This is the hot-swap drain point: after flipping the primary
        version, waiting on the barrier guarantees the pre-flip backlog
        has been batched (on the old or new plan — either way it will be
        served, never dropped).  Requests submitted after the call do not
        extend the wait.
        """
        with self._state_lock:
            target = self._last_seq
        deadline = None if timeout is None else self._clock() + timeout
        with self._handed:
            while self._handed_seq < target:
                remaining = None
                if deadline is not None:
                    remaining = deadline - self._clock()
                    if remaining <= 0:
                        return False
                self._handed.wait(timeout=remaining)
        return True

    def close(self) -> None:
        """Wake all worker loops; pending get_batch calls return None."""
        with self._state_lock:
            self._closed = True
            self._q.put(self._CLOSE)

    def drain(self) -> List[Request]:
        """Remove and return every still-queued request (after close).

        The engine resolves their futures with an error so no caller is
        left blocking on a request that will never be served.
        """
        with self._state_lock:
            if not self._closed:
                raise RuntimeError("drain() is only valid after close()")
            pending: List[Request] = []
            while True:
                try:
                    item = self._q.get_nowait()
                except queue.Empty:
                    break
                if item is not self._CLOSE:
                    pending.append(item)
            if pending:
                # drained requests count as handled (their futures are
                # failed by the engine), so a pending drain_barrier wakes
                # instead of waiting on requests that will never batch
                self._mark_handed(max(r.seq for r in pending))
            return pending

    # -- consumer side ------------------------------------------------------

    def get_batch(self, timeout: Optional[float] = None) -> Optional[MicroBatch]:
        """Block for the next batch; None on timeout or close.

        Waits for a first request, then keeps draining the queue until the
        batch is full (**size flush**) or ``max_delay`` has elapsed since
        the batch started forming (**timeout flush**).
        """
        try:
            first = self._q.get(timeout=timeout)
        except queue.Empty:
            return None
        if first is self._CLOSE:
            self._q.put(self._CLOSE)  # re-wake sibling workers
            return None
        reqs = [first]
        deadline = self._clock() + self.max_delay_s
        while len(reqs) < self.max_batch:
            remaining = deadline - self._clock()
            if remaining <= 0:
                break
            try:
                nxt = self._q.get(timeout=remaining)
            except queue.Empty:
                break
            if nxt is self._CLOSE:
                self._q.put(self._CLOSE)
                break
            reqs.append(nxt)
        bucket = bucket_for(len(reqs), self.buckets)
        frames = np.zeros((bucket,) + self.frame_shape, dtype=np.float32)
        for i, r in enumerate(reqs):
            frames[i] = r.iq
        self._mark_handed(max(r.seq for r in reqs))
        return MicroBatch(requests=reqs, bucket=bucket, frames=frames,
                          queue_depth=self._q.qsize())

    def _mark_handed(self, seq: int) -> None:
        with self._handed:
            if seq > self._handed_seq:
                self._handed_seq = seq
            self._handed.notify_all()
