"""internvl2-1b [vlm] — arXiv:2404.16821 (verified: hf).

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655 (Qwen2-0.5B LM
backbone).  InternViT frontend is a STUB: input_specs provides 256
precomputed patch embeddings prepended to the token sequence.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv=2, d_ff=4864,
    vocab=151655, head_dim=64,
    qkv_bias=True, rope_theta=1_000_000.0,
    n_patches=256, tie_embeddings=True,
    notes="InternViT stubbed to precomputed patch embeddings",
)
