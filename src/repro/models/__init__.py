"""Model zoo: the paper's SNN classifier + the 10 assigned LM-family archs."""
