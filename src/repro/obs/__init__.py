"""End-to-end observability: metrics registry, request tracing, activity
telemetry, the HTTP exposition endpoint — and the analysis plane on top
(time-series recording, SLO burn-rate alerting, anomaly detection,
Perfetto trace export).

See README "Observability" for the metric naming scheme, the SLO spec
format, and examples.
"""
from repro.obs.activity import (
    SCHEDULE_KEYS,
    ActivityObserver,
    static_schedule_counts,
)
from repro.obs.anomaly import (
    Alert,
    AlertManager,
    BurnRateWatcher,
    EwmaDetector,
    SeriesWatcher,
    WatchSpec,
    autoscaler_sink,
    canary_shadow_sink,
    default_drift_watches,
    get_default_alert_manager,
    log_file_sink,
    set_default_alert_manager,
)
from repro.obs.export import to_perfetto, validate_perfetto, write_perfetto
from repro.obs.http import (
    MetricsServer,
    alert_health_check,
    engine_health_check,
    engine_ready_probe,
)
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS,
    MetricsRegistry,
    default_registry,
    set_default_registry,
)
from repro.obs.slo import (
    DEFAULT_BURN_WINDOWS,
    SLO,
    BurnRateEngine,
    BurnWindow,
    SLOStatus,
    default_serve_slos,
    parse_slo_spec,
    scaled_windows,
)
from repro.obs.timeseries import (
    Series,
    TimeSeriesRecorder,
    get_default_recorder,
    set_default_recorder,
)
from repro.obs.trace import (
    TERMINAL_EVENTS,
    RequestTrace,
    TraceEvent,
    TraceLog,
    begin_trace,
    disable_tracing,
    enable_tracing,
    get_tracer,
    tadd,
    tfinish,
)

__all__ = [
    "MetricsRegistry",
    "default_registry",
    "set_default_registry",
    "DEFAULT_LATENCY_BUCKETS",
    "TraceEvent",
    "RequestTrace",
    "TraceLog",
    "TERMINAL_EVENTS",
    "enable_tracing",
    "disable_tracing",
    "get_tracer",
    "begin_trace",
    "tadd",
    "tfinish",
    "ActivityObserver",
    "static_schedule_counts",
    "SCHEDULE_KEYS",
    "MetricsServer",
    "alert_health_check",
    "engine_health_check",
    "engine_ready_probe",
    "Series",
    "TimeSeriesRecorder",
    "get_default_recorder",
    "set_default_recorder",
    "SLO",
    "SLOStatus",
    "BurnWindow",
    "BurnRateEngine",
    "DEFAULT_BURN_WINDOWS",
    "scaled_windows",
    "parse_slo_spec",
    "default_serve_slos",
    "EwmaDetector",
    "Alert",
    "AlertManager",
    "WatchSpec",
    "default_drift_watches",
    "SeriesWatcher",
    "BurnRateWatcher",
    "autoscaler_sink",
    "canary_shadow_sink",
    "log_file_sink",
    "set_default_alert_manager",
    "get_default_alert_manager",
    "to_perfetto",
    "write_perfetto",
    "validate_perfetto",
]
