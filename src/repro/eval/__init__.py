"""Scenario robustness evaluation: (scenario x SNR x backend) sweeps.

:func:`evaluate_robustness` runs plan-compiled batched forwards over the
:mod:`repro.channel` scenario suite and an SNR grid, producing
per-modulation confusion matrices and a per-SNR accuracy surface as one
JSON-serializable report.  CLI: ``python -m repro.launch.eval``.
"""

from .robustness import (
    RobustnessConfig,
    evaluate_robustness,
    format_report,
    stable_cell_seed,
)

__all__ = [
    "RobustnessConfig",
    "evaluate_robustness",
    "format_report",
    "stable_cell_seed",
]
