"""Interactive HLO inspection helpers for the perf hillclimb loop.

``python -m repro.launch.hlo_tools <file.hlo> [--thresh 2e8]`` prints the
big-buffer census and the collective census grouped by (op, shape) — the
two views every §Perf iteration starts from.
"""
from __future__ import annotations

import argparse
import collections
import re
import sys
from typing import Dict, List, Tuple

from .hlo_analysis import DTYPE_BYTES, _SHAPE_RE

__all__ = ["type_bytes", "big_buffers", "collectives_by_shape"]

_RESULT_RE = re.compile(
    r"\s*(?:ROOT )?%?[\w\.\-]+ = (\(.*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")


def type_bytes(t: str) -> int:
    tot = 0
    for dt, dims in _SHAPE_RE.findall(t):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        tot += n * DTYPE_BYTES[dt]
    return int(tot)


def big_buffers(text: str, thresh: float = 2e8) -> List[Tuple[str, str, int, int]]:
    """(computation, shape, bytes, mentions) sorted by bytes*mentions."""
    comp = "?"
    ctr: Dict[Tuple[str, str], int] = collections.Counter()
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            comp = m.group(1)
            continue
        m = _RESULT_RE.match(line)
        if m and type_bytes(m.group(1)) > thresh:
            shape = re.sub(r"\{[^}]*\}", "", m.group(1))
            ctr[(comp, shape)] += 1
    rows = [(c, s, type_bytes(s), n) for (c, s), n in ctr.items()]
    return sorted(rows, key=lambda r: -r[2] * r[3])


def collectives_by_shape(text: str) -> List[Tuple[str, str, int, int]]:
    """(op, shape, bytes, count) for every collective, sorted by volume."""
    ops = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
           "collective-permute")
    ctr: Dict[Tuple[str, str], int] = collections.Counter()
    for line in text.splitlines():
        m = _RESULT_RE.match(line)
        if m and m.group(2).rstrip("-start") in ops:
            shape = re.sub(r"\{[^}]*\}", "", m.group(1))
            ctr[(m.group(2), shape)] += 1
    rows = [(op, s, type_bytes(s), n) for (op, s), n in ctr.items()]
    return sorted(rows, key=lambda r: -r[2] * r[3])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("hlo")
    ap.add_argument("--thresh", type=float, default=2e8)
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args(argv)
    text = open(args.hlo).read()
    print("== big buffers (comp, shape, GB, mentions)")
    for comp, shape, b, n in big_buffers(text, args.thresh)[: args.top]:
        print(f"  {b / 1e9:7.2f} GB x{n:4d}  {shape:44s} {comp[:40]}")
    print("== collectives (op, shape, GB each, count)")
    for op, shape, b, n in collectives_by_shape(text)[: args.top]:
        print(f"  {b / 1e9:7.3f} GB x{n:4d}  {op:20s} {shape[:70]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
