"""Shared neural layers for the architecture zoo (functional, pjit-ready).

Everything is a pure function over nested-dict params so pjit/shard_map can
shard freely.  Design notes:

* **Attention** is query-chunked (lax.scan over query blocks): peak score
  memory is (B, H, q_chunk, S) instead of (B, H, S, S), which is what makes
  the 32k prefill shapes fit HBM.  Supports GQA, QKV-bias, per-head q/k RMS
  norm (Qwen3), sliding windows (RecurrentGemma local attention) and
  single-token decode against a KV cache.
* **MoE** uses group-limited routing with a **static capacity schedule**:
  tokens are sorted by expert, placed into a fixed (E, C) slot table, and
  overflow/underflow become padded no-op slots — the same
  precomputed-schedule idea as the paper's empty/extra iterations (DESIGN.md
  §5): no dynamic shapes anywhere, compile-time-fixed dataflow.
* **Mamba-2 (SSD)** is the chunked state-space-duality algorithm: exact
  intra-chunk attention-form + sequential inter-chunk state pass.
* **RG-LRU** (RecurrentGemma) uses an associative scan over the gated
  diagonal recurrence.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import (
    constrain_expert,
    constrain_hidden,
    constrain_seq_gathered,
)

from .config import ArchConfig

__all__ = [
    "rms_norm", "rope", "attention", "swiglu", "moe", "mamba2_block",
    "rglru_block", "init_attention", "init_swiglu", "init_moe",
    "init_mamba2", "init_rglru", "init_embedding",
]

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


def init_embedding(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(key)
    vp = cfg.padded_vocab
    p = {"tok": _dense_init(k1, (vp, cfg.d_model), scale=0.02, dtype=dtype)}
    if not cfg.tie_embeddings:
        p["unemb"] = _dense_init(k2, (cfg.d_model, vp), dtype=dtype)
    return p


def mask_vocab_pad(logits: jax.Array, cfg: ArchConfig) -> jax.Array:
    """-inf the padded-vocab tail so softmax/argmax never see it."""
    vp = logits.shape[-1]
    if vp == cfg.vocab:
        return logits
    valid = jnp.arange(vp) < cfg.vocab
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def init_attention(key, cfg: ArchConfig, cross: bool = False, dtype=jnp.float32) -> Params:
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, nh * hd), dtype=dtype),
        "wk": _dense_init(ks[1], (cfg.d_model, nkv * hd), dtype=dtype),
        "wv": _dense_init(ks[2], (cfg.d_model, nkv * hd), dtype=dtype),
        "wo": _dense_init(ks[3], (nh * hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dtype)
        p["bk"] = jnp.zeros((nkv * hd,), dtype)
        p["bv"] = jnp.zeros((nkv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_swiglu(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "wu": _dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "wd": _dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = cfg.padded_experts, cfg.d_model, cfg.d_ff
    p = {
        # router stays (d, n_experts): padded experts are unreachable
        "router": _dense_init(ks[0], (d, cfg.n_experts), scale=0.02, dtype=dtype),
        "wg": _dense_init(ks[1], (e, d, f), dtype=dtype),
        "wu": _dense_init(ks[2], (e, d, f), dtype=dtype),
        "wd": _dense_init(ks[3], (e, f, d), scale=1.0 / math.sqrt(f), dtype=dtype),
    }
    if cfg.n_shared:
        p["shared"] = init_swiglu(ks[4], d, cfg.n_shared * cfg.d_ff, dtype=dtype)
    return p


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    heads = d_in // cfg.ssm_head_dim
    ks = jax.random.split(key, 4)
    conv_dim = d_in + 2 * cfg.ssm_state
    return {
        # fused in-proj -> [z (d_in), x (d_in), B (state), C (state), dt (heads)]
        "w_in": _dense_init(ks[0], (d, 2 * d_in + 2 * cfg.ssm_state + heads), dtype=dtype),
        "conv": _dense_init(ks[1], (cfg.ssm_conv, conv_dim), scale=0.5, dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(dtype),
        "d_skip": jnp.ones((heads,), dtype),
        "dt_bias": jnp.zeros((heads,), dtype),
        "gate_norm": jnp.ones((d_in,), dtype),
        "w_out": _dense_init(ks[2], (d_in, d), dtype=dtype),
    }


_RGLRU_BLOCKS = 16  # RG-LRU gate projections are block-diagonal (as in
                    # RecurrentGemma); also keeps the gate matmuls local
                    # per model shard (full (w, w) gates cost a 537 MB
                    # f32 activation all-reduce per gate per layer)


def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    w = cfg.lru_width or d
    nb = _RGLRU_BLOCKS if w % _RGLRU_BLOCKS == 0 else 1
    wb = w // nb
    ks = jax.random.split(key, 6)
    return {
        "w_x": _dense_init(ks[0], (d, w), dtype=dtype),
        "w_gate": _dense_init(ks[1], (d, w), dtype=dtype),
        "conv": _dense_init(ks[2], (cfg.ssm_conv, w), scale=0.5, dtype=dtype),
        # block-diagonal input & recurrence gate projections
        "w_r": _dense_init(ks[3], (nb, wb, wb), scale=0.02, dtype=dtype),
        "w_i": _dense_init(ks[4], (nb, wb, wb), scale=0.02, dtype=dtype),
        "lam": jnp.full((w,), 2.0, dtype),  # softplus(2) ~ broad decay init
        "w_out": _dense_init(ks[5], (w, d), dtype=dtype),
    }


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Vocab-parallel-safe cross entropy: (..., V) logits, (...) int labels.

    ``take_along_axis`` over a vocab-sharded logits tensor forces XLA to
    all-gather the full (B, S, V) buffer (observed: 39.8 GB/device on the
    qwen1.5-0.5b train_4k dry-run).  The one-hot reduction below is
    elementwise over V, so every term stays sharded and the only cross-
    shard traffic is the scalar max/sum all-reduces of the logsumexp.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    one_hot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.sum(logits * one_hot, axis=-1)
    return lse - label_logit


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _qk_headnorm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def attention(
    params: Params,
    x: jax.Array,                       # (B, S, d)
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,   # decode: {"k","v","len"}
    kv_x: Optional[jax.Array] = None,   # cross-attention source (B, Skv, d)
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    build_cache: bool = False,
    cache_headroom: int = 0,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Query-chunked (G)QA with optional KV cache decode / cross-attn.

    ``build_cache=True`` (prefill): the full-sequence path additionally
    returns a decode-ready KV cache — full context, or the last ``window``
    positions rotated into ring-buffer layout for local attention.
    """
    b, s, _ = x.shape
    hd, nh, nkv = cfg.hd, cfg.n_heads, cfg.n_kv
    rep = nh // max(1, nkv)

    q = x @ params["wq"]
    src = kv_x if kv_x is not None else x
    k = src @ params["wk"]
    v = src @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, src.shape[1], nkv, hd)
    v = v.reshape(b, src.shape[1], nkv, hd)
    if cfg.qk_norm:
        q = _qk_headnorm(q, params["q_norm"], cfg.norm_eps)
        k = _qk_headnorm(k, params["k_norm"], cfg.norm_eps)

    use_rope = cfg.rope_enabled and kv_x is None  # no rope on cross-attention
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        # single-token decode against a pre-allocated cache.  Windowed
        # caches are ring buffers (write at len % ctx); full caches are the
        # special case window == ctx where the ring never wraps.
        # int8 caches ("k_scale"/"v_scale" present) store per-(token, kv
        # head) symmetric-quantized entries: halves cache HBM, the decode
        # bottleneck (serve memory term == step latency).
        if use_rope:
            k = rope(k, positions, cfg.rope_theta)
        quant = "k_scale" in cache
        ctx = cache["k"].shape[1]
        idx = cache["len"]
        write = jax.lax.rem(idx, ctx)

        def _wr(buf, val):
            return jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), (0, write) + (0,) * (buf.ndim - 2))

        if quant:
            ks = jnp.maximum(jnp.abs(k).max(-1), 1e-8) / 127.0   # (b, s, nkv)
            vs_ = jnp.maximum(jnp.abs(v).max(-1), 1e-8) / 127.0
            ck = _wr(cache["k"], jnp.round(k / ks[..., None]))
            cv = _wr(cache["v"], jnp.round(v / vs_[..., None]))
            cks = _wr(cache["k_scale"], ks)
            cvs = _wr(cache["v_scale"], vs_)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs,
                         "len": idx + s}
            k_eff = ck.astype(x.dtype) * cks.astype(x.dtype)[..., None]
            v_eff = cv.astype(x.dtype) * cvs.astype(x.dtype)[..., None]
        else:
            ck = _wr(cache["k"], k)
            cv = _wr(cache["v"], v)
            new_cache = {"k": ck, "v": cv, "len": idx + s}
            k_eff, v_eff = ck, cv
        kpos = jnp.arange(ctx)
        valid = kpos[None, :] < jnp.minimum(idx + s, ctx)  # (1, ctx)
        qh = q.reshape(b, s, nkv, rep, hd)
        scores = jnp.einsum("bsgrh,bcgh->bgrsc", qh, k_eff) / math.sqrt(hd)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bgrsc,bcgh->bsgrh", probs, v_eff).reshape(b, s, nh * hd)
        return out @ params["wo"], new_cache

    # full (training / prefill) path
    if use_rope:
        k = rope(k, positions, cfg.rope_theta)
    skv = k.shape[1]
    if not window:
        # ---- online-softmax over KV chunks (flash-style dataflow) ----
        # q never gets sliced (it stays sequence-sharded; slicing a
        # sharded dim with a loop-variable offset costs a full-scores
        # all-reduce per chunk), k/v are gathered once per layer, and
        # the running (max, denom, acc) carries keep peak score memory
        # at (B, S_local, kv_chunk).
        k = constrain_seq_gathered(k)
        v = constrain_seq_gathered(v)
        kv_chunk = min(1024, skv)
        pad_kv = (-skv) % kv_chunk
        if pad_kv:
            k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        nkc = (skv + pad_kv) // kv_chunk
        qh = q.reshape(b, s, nkv, rep, hd)
        qpos = positions[0] if positions.ndim > 1 else positions  # (S,)

        def kv_step(carry, idx):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, idx * kv_chunk, kv_chunk, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, idx * kv_chunk, kv_chunk, 1)
            sc = jnp.einsum("bsgrh,bcgh->bgrsc", qh, ks) / math.sqrt(hd)
            sc = sc.astype(jnp.float32)
            kpos = idx * kv_chunk + jnp.arange(kv_chunk)
            valid = kpos[None, :] < skv
            if causal:
                valid = valid & (kpos[None, :] <= qpos[:, None])
            sc = jnp.where(valid[None, None, None], sc, -jnp.inf)
            m_new = jnp.maximum(m, sc.max(-1))
            # all-masked rows keep m = -inf; shift by a finite max instead
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(sc - m_safe[..., None])
            scale = jnp.exp(jnp.where(jnp.isfinite(m), m - m_safe, -jnp.inf))
            scale = jnp.where(jnp.isfinite(m), scale, 0.0)
            l_new = l * scale + p.sum(-1)
            pv = jnp.einsum("bgrsc,bcgh->bsgrh", p.astype(x.dtype), vs)
            acc_new = acc * jnp.moveaxis(scale, 3, 1)[..., None, None] \
                .reshape(b, s, nkv, rep, 1) + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nkv, rep, s), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, nkv, rep, s), jnp.float32)
        a0 = jnp.zeros((b, s, nkv, rep, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nkc))
        denom = jnp.moveaxis(jnp.maximum(l, 1e-30), 3, 1).reshape(
            b, s, nkv, rep, 1)
        out = (acc / denom).astype(x.dtype).reshape(b, s, nh * hd)
        new_cache = None
        if build_cache:
            pad = ((0, 0), (0, cache_headroom), (0, 0), (0, 0))
            kc = jnp.pad(k[:, :skv], pad)
            vc = jnp.pad(v[:, :skv], pad)
            new_cache = {"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32)}
        return out @ params["wo"], new_cache

    if window:
        # windowed layers gain nothing from big query chunks; smaller
        # chunks shrink the (qc x band) scores buffer proportionally
        q_chunk = min(q_chunk, max(256, window // 4))
    n_chunks = max(1, -(-s // q_chunk))
    pad = n_chunks * q_chunk - s
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qh = qp.reshape(b, n_chunks, q_chunk, nkv, rep, hd)
    # banded local attention: a window-w causal query chunk only sees K/V
    # in [chunk_start - w, chunk_end) — slice instead of scoring the full
    # sequence (O(S*w) instead of O(S^2): 10x compute on the 32k prefill).
    # Only engage when the band is a real saving (>= 2x): the slice's
    # backward is a per-chunk scatter-add that costs memory on short
    # sequences where the band ~= the full length.
    band = q_chunk + window if (window and causal) else skv
    band = band if band * 2 <= skv else skv
    band = min(band, skv)

    def chunk_fn(carry, inputs):
        qc, c_idx = inputs  # (B, qc, nkv, rep, hd), scalar
        qpos = c_idx * q_chunk + jnp.arange(q_chunk)
        if band < skv:
            start = jnp.clip(c_idx * q_chunk - window, 0, skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, 1)
            kpos = start + jnp.arange(band)
        else:
            kc, vc = k, v
            kpos = jnp.arange(skv)
        scores = jnp.einsum("bsgrh,bcgh->bgrsc", qc, kc) / math.sqrt(hd)
        mask = jnp.ones((q_chunk, band), bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        out = jnp.einsum("bgrsc,bcgh->bsgrh", probs, vc)
        return carry, out

    _, outs = jax.lax.scan(
        chunk_fn, None,
        (jnp.moveaxis(qh, 1, 0), jnp.arange(n_chunks)),
    )  # (n_chunks, B, qc, nkv, rep, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * q_chunk, nh * hd)[:, :s]
    new_cache = None
    if build_cache:
        if window and window < s:
            # ring-buffer layout: position p lives at slot p % window
            kc = jnp.roll(k[:, -window:], s % window, axis=1)
            vc = jnp.roll(v[:, -window:], s % window, axis=1)
        else:
            # headroom: room for generated tokens before the ring wraps
            pad = ((0, 0), (0, cache_headroom), (0, 0), (0, 0))
            kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
        new_cache = {"k": kc, "v": vc, "len": jnp.asarray(s, jnp.int32)}
    return out @ params["wo"], new_cache


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])) @ params["wd"]


# ---------------------------------------------------------------------------
# MoE with static-capacity schedule (paper's precomputed-schedule idea)
# ---------------------------------------------------------------------------

def moe(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Block-local top-k routing with a static (E, C) slot table.

    Routing groups are fixed ``moe_block``-token blocks (group-limited
    routing): the argsort/capacity bookkeeping never crosses a block, so
    with block size <= the sequence-shard size the whole dispatch stays
    local to each (data, model) shard — no all-gather of the sequence and
    no global sort buffers (observed 31.6 GB/device on qwen2-moe train_4k
    with whole-sequence routing).  Overflow tokens are dropped
    (capacity_factor slack) and unfilled slots are explicit no-op pads —
    static shapes everywhere, the paper's precomputed-schedule idea.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_pad = cfg.padded_experts       # routing never reaches [e, e_pad):
                                     # their slots are explicit no-op work,
                                     # the paper's 'extra iterations'
    blk = cfg.moe_block if (cfg.moe_block and s % cfg.moe_block == 0) else s
    nb = s // blk
    cap = int(math.ceil(blk * k / e * cfg.capacity_factor))
    cap = max(cap, k)

    nk = blk * k
    xb = x.reshape(b, nb, blk, d)

    # --- routing (index-space only; everything batched over (b, nb)) ---
    logits = xb @ params["router"]                      # (b, nb, blk, E)
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_ids = jax.lax.top_k(probs, k)            # (b, nb, blk, k)
    top_p = (top_p / (top_p.sum(-1, keepdims=True) + 1e-9)).astype(x.dtype)
    ids = top_ids.reshape(b, nb, nk)                    # copy -> expert
    sel = jax.nn.one_hot(ids, e, dtype=jnp.int32)       # (b, nb, nk, E)
    # FIFO capacity: copy position within its expert = exclusive prefix sum
    pos = ((jnp.cumsum(sel, axis=-2) - 1) * sel).sum(-1)  # (b, nb, nk)
    slot = jnp.where(pos < cap, ids * cap + pos, e * cap)

    # --- DENSE one-hot dispatch (mesh-TF style), TOKEN-level ---
    # gather/scatter dispatch made XLA materialize u32 scatter indices
    # broadcast over d (26.8 GB buffers on llama4-scout train); einsum
    # dispatch is pure MXU work (<1% of expert FLOPs) and — exactly like
    # the paper's precomputed schedule — a fixed dataflow whose dropped /
    # unfilled slots are explicit no-ops.  The dispatch matrices are
    # TOKEN x slot (one-hots summed over the k copies): a copy-level
    # formulation repeats activations k-fold and cost 155 GB/dev of
    # dispatch-tensor gathers on qwen2-moe train (top-4).
    n_slots = e_pad * cap
    slot_tok = slot.reshape(b, nb, blk, k)
    disp = sum(jax.nn.one_hot(slot_tok[..., i], n_slots + 1,
                              dtype=x.dtype)[..., :-1] for i in range(k))
    gathered = jnp.einsum("bnts,bntd->bnsd", disp, xb)
    gathered = gathered.reshape(b, nb, e_pad, cap, d)
    # anchor: expert dim -> model axis (EP); keeps e-sharded weights local
    gathered = constrain_expert(gathered, 2)

    h = jax.nn.silu(jnp.einsum("bnecd,edf->bnecf", gathered, params["wg"]))
    h = h * jnp.einsum("bnecd,edf->bnecf", gathered, params["wu"])
    y = jnp.einsum("bnecf,efd->bnecd", h, params["wd"])
    y = constrain_expert(y, 2).reshape(b, nb, n_slots, d)

    # combine: router-weighted one-hots in one token x slot matrix
    disp_w = sum(top_p[..., i, None] * jax.nn.one_hot(
        slot_tok[..., i], n_slots + 1, dtype=x.dtype)[..., :-1]
        for i in range(k))
    out = jnp.einsum("bnts,bnsd->bntd", disp_w, y).reshape(b, s, d)
    if cfg.n_shared:
        out = out + swiglu(params["shared"], x)
    return out


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, chunked)
# ---------------------------------------------------------------------------

def _ssd_chunked(xh, dt, a_log, bmat, cmat, chunk: int):
    """Chunked state-space duality.

    xh: (B, S, H, P) inputs per head; dt: (B, S, H) positive step sizes;
    a_log: (H,) (A = -exp(a_log)); bmat/cmat: (B, S, N) shared across heads.
    Returns y: (B, S, H, P).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    a = -jnp.exp(a_log.astype(jnp.float32))                 # (H,)
    dta = dt.astype(jnp.float32) * a                        # (B, S, H) negative
    x_ = xh.reshape(b, nc, chunk, h, p)
    dt_ = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    dta_ = dta.reshape(b, nc, chunk, h)
    b_ = bmat.reshape(b, nc, chunk, n)
    c_ = cmat.reshape(b, nc, chunk, n)

    cum = jnp.cumsum(dta_, axis=2)                          # (B, nc, Q, H)
    # intra-chunk: y_intra[t] = sum_{u<=t} C_t B_u^T exp(cum_t - cum_u) dt_u x_u
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask BEFORE exp: exp of the (positive) upper triangle overflows to
    # inf and where(tri, inf, 0) back-propagates NaN
    gate = jnp.exp(jnp.where(tri[None, None, :, :, None], decay, -jnp.inf))
    cb = jnp.einsum("bqtn,bqun->bqtu", c_, b_)              # (B,nc,Q,Q)
    w = cb[..., None] * gate * dt_[:, :, None, :, :]        # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bqtuh,bquhp->bqthp", w.astype(xh.dtype), x_)

    # chunk-final states: S_c = sum_u exp(cum_Q - cum_u) dt_u B_u x_u^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    sb = b_[..., None, :] * (decay_to_end * dt_)[..., None]  # (B,nc,Q,H,N)
    states = jnp.einsum("bquhn,bquhp->bqhnp", sb.astype(xh.dtype), x_)

    # inter-chunk recurrence over nc (sequential; nc is small)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    def scan_fn(hprev, inp):
        st, dec = inp                                        # (B,H,N,P), (B,H)
        hnew = hprev * dec[..., None, None].astype(xh.dtype) + st
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p), xh.dtype)
    h_final, hprevs = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )  # (nc, B, H, N, P) = state entering each chunk
    hprevs = jnp.moveaxis(hprevs, 0, 1)

    # inter-chunk contribution: y_inter[t] = C_t h_prev * exp(cum_t)
    in_decay = jnp.exp(cum)                                  # (B,nc,Q,H)
    y_inter = jnp.einsum(
        "bqtn,bqhnp->bqthp", c_.astype(xh.dtype), hprevs
    ) * in_decay[..., None].astype(xh.dtype)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_final


def mamba2_block(
    params: Params,
    x: jax.Array,                 # (B, S, d)
    cfg: ArchConfig,
    state: Optional[Dict[str, jax.Array]] = None,  # decode state
    build_state: bool = False,    # prefill: also return the decode state
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    heads = d_in // cfg.ssm_head_dim
    p_dim = cfg.ssm_head_dim
    n = cfg.ssm_state

    zxbcdt = x @ params["w_in"]
    z, xs, bmat, cmat, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + n, 2 * d_in + 2 * n], axis=-1
    )
    dt = jax.nn.softplus(dt + params["dt_bias"])             # (B, S, H)

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)     # (B, S, conv_dim)
    if state is None:
        # causal depthwise conv via padding
        pad = jnp.pad(conv_in, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
        conv = sum(
            pad[:, i : i + s] * params["conv"][i] for i in range(cfg.ssm_conv)
        )
        conv = jax.nn.silu(conv)
        xs, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)
        xh = xs.reshape(b, s, heads, p_dim)
        # chunk must divide S; fall back to the largest divisor (exact,
        # just less parallel) for ragged sequence lengths
        chunk = cfg.ssm_chunk
        if s % chunk:
            chunk = max(c for c in range(1, min(s, chunk) + 1) if s % c == 0)
        y, h_final = _ssd_chunked(xh, dt, params["a_log"], bmat, cmat, chunk)
        y = y + params["d_skip"][:, None] * xh
        new_state = None
        if build_state:
            new_state = {
                "conv": conv_in[:, -cfg.ssm_conv:],
                "ssm": h_final.astype(x.dtype),
            }
    else:
        # single-token decode: roll conv buffer, one recurrence step
        buf = jnp.concatenate([state["conv"][:, 1:], conv_in], axis=1)
        conv = jax.nn.silu(jnp.einsum("bts,ts->bs", buf, params["conv"]))[:, None]
        xs, bmat, cmat = jnp.split(conv, [d_in, d_in + n], axis=-1)
        xh = xs.reshape(b, 1, heads, p_dim)[:, 0]            # (B, H, P)
        a = -jnp.exp(params["a_log"].astype(jnp.float32))
        dec = jnp.exp(dt[:, 0].astype(jnp.float32) * a)      # (B, H)
        ssm = state["ssm"]                                   # (B, H, N, P)
        upd = (dt[:, 0][..., None, None] * bmat[:, 0, None, :, None].astype(jnp.float32)
               * xh[:, :, None, :].astype(jnp.float32))
        ssm = ssm * dec[..., None, None].astype(ssm.dtype) + upd.astype(ssm.dtype)
        y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(ssm.dtype), ssm)
        y = (y + params["d_skip"][:, None] * xh)[:, None]    # (B, 1, H, P)
        new_state = {"conv": buf, "ssm": ssm}

    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return y @ params["w_out"], new_state


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

_C_RGLRU = 8.0


def rglru_block(
    params: Params,
    x: jax.Array,                 # (B, S, d)
    cfg: ArchConfig,
    state: Optional[Dict[str, jax.Array]] = None,
    build_state: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    b, s, d = x.shape
    w = cfg.lru_width or d
    gate = constrain_hidden(jax.nn.gelu(x @ params["w_gate"]))  # (B, S, w)
    xs = constrain_hidden(x @ params["w_x"])

    if state is None:
        pad = jnp.pad(xs, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
        xc = sum(pad[:, i : i + s] * params["conv"][i] for i in range(cfg.ssm_conv))
    else:
        buf = jnp.concatenate([state["conv"][:, 1:], xs], axis=1)
        xc = jnp.einsum("bts,ts->bs", buf, params["conv"])[:, None]

    nb, wb, _ = params["w_r"].shape
    xg = xc.reshape(b, s, nb, wb)
    r = jax.nn.sigmoid(
        jnp.einsum("bsgi,gij->bsgj", xg, params["w_r"]).reshape(b, s, w))
    i = jax.nn.sigmoid(
        jnp.einsum("bsgi,gij->bsgj", xg, params["w_i"]).reshape(b, s, w))
    log_a = -_C_RGLRU * r * jax.nn.softplus(params["lam"])   # (B, S, w) <= 0
    a = jnp.exp(log_a.astype(jnp.float32))
    gated_x = i * xc
    beta = jnp.sqrt(jnp.clip(1.0 - a**2, 1e-9, None)).astype(x.dtype)

    if state is None:
        # h_t = a_t h_{t-1} + beta_t (i_t x_t), evaluated CHUNKED: a global
        # associative_scan over (B, S, w) in f32 materializes O(log S)
        # full-sequence temporaries and forces the sharded S axis to
        # gather (observed 72 GB/device on recurrentgemma-9b train_4k).
        # Within-chunk scans stay local to each sequence shard; only the
        # (B, nc, w) chunk-boundary states cross shards.
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        chunk = 256
        if s % chunk:
            chunk = max(c for c in range(1, min(s, chunk) + 1) if s % c == 0)
        nc = s // chunk
        a_ = a.reshape(b, nc, chunk, w)                       # f32
        bx = (beta * gated_x).astype(jnp.float32).reshape(b, nc, chunk, w)
        a_cum, h_local = jax.lax.associative_scan(
            combine, (a_, bx), axis=2)                        # within chunk

        def carry(h_in, inp):                                  # over chunks
            a_last, h_last = inp                               # (B, w)
            return a_last * h_in + h_last, h_in

        _, h_ins = jax.lax.scan(
            carry, jnp.zeros((b, w), jnp.float32),
            (jnp.moveaxis(a_cum[:, :, -1], 1, 0),
             jnp.moveaxis(h_local[:, :, -1], 1, 0)))
        h_ins = jnp.moveaxis(h_ins, 0, 1)                      # (B, nc, w)
        h = (h_local + a_cum * h_ins[:, :, None, :]).reshape(b, s, w)
        h = constrain_hidden(h.astype(x.dtype))
        new_state = None
        if build_state:
            new_state = {"conv": xs[:, -cfg.ssm_conv:], "lru": h[:, -1]}
    else:
        h = (a[:, 0].astype(x.dtype) * state["lru"] + beta[:, 0] * gated_x[:, 0])[:, None]
        new_state = {"conv": buf, "lru": h[:, 0]}

    return (h * gate) @ params["w_out"], new_state
