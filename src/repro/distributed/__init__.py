from .ctx import activation_constraints, constrain_acts, constrain_logits
from .sharding import (
    act_pspec,
    decode_state_specs,
    dp_axes,
    logits_pspec,
    named_tree,
    partition_params,
    train_batch_spec,
)

__all__ = [
    "activation_constraints", "constrain_acts", "constrain_logits",
    "act_pspec", "decode_state_specs", "dp_axes", "logits_pspec",
    "named_tree", "partition_params", "train_batch_spec",
]
