"""Fleet-scale serving: replica router, admission control, autoscaling.

The tier above :mod:`repro.serve`: a :class:`FleetRouter` fronts N
replica groups (each an async engine) with join-shortest-queue dispatch,
per-request deadlines and priority classes, queue-bound + p99-driven load
shedding, and an :class:`Autoscaler` control loop that grows/shrinks the
replica set against a latency target.  The whole :mod:`repro.deploy`
toolchain (hot-swap, canary routing, monitor) works on a fleet through
the router's engine-like surface.
"""

from .autoscaler import AutoscaleTick, Autoscaler
from .router import FleetRouter, Replica, ShedError, engine_factory, merge_stats

__all__ = [
    "FleetRouter",
    "Replica",
    "ShedError",
    "engine_factory",
    "merge_stats",
    "Autoscaler",
    "AutoscaleTick",
]
