"""Input pipelines: host-side generation -> sigma-delta encoding -> device.

``SpikeBatchPipeline`` is the AMC training/serving pipeline: a background
thread generates RadioML batches and sigma-delta-encodes them (numpy,
identical numerics to ``repro.core.encoder``) while the device computes —
the streaming-overlap analogue of the paper's fully-pipelined input stage.
A bounded queue provides backpressure; the depth is the straggler-absorption
budget (a slow generation step does not bubble the accelerator until the
queue drains).

``lm_token_batches`` serves the LM-family architectures with deterministic
synthetic token streams (Zipf-distributed ids) for trainer smoke tests.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .radioml import RadioMLDataset

__all__ = ["sigma_delta_encode_np", "sigma_delta_encode_batch",
           "SpikeBatchPipeline", "lm_token_batches"]


def sigma_delta_encode_np(iq: np.ndarray, osr: int) -> np.ndarray:
    """Vectorized numpy sigma-delta (matches repro.core.encoder exactly).

    iq: (B, 2, L) float -> (B, T=osr, 2, L) float32 in {0, 1}.
    """
    peak = np.max(np.abs(iq), axis=(-2, -1), keepdims=True)
    x = 0.5 * (iq / (peak + 1e-8) + 1.0)
    integ = np.zeros_like(x)
    y_prev = np.zeros_like(x)
    bits = np.empty((osr,) + x.shape, dtype=np.float32)
    for t in range(osr):
        integ = integ + x - y_prev
        y_prev = (integ >= 0.5).astype(np.float32)
        bits[t] = y_prev
    # (T, B, 2, L) -> (B, T, 2, L)
    return np.moveaxis(bits, 0, 1)


def sigma_delta_encode_batch(iq: jax.Array, osr: int) -> jax.Array:
    """Traceable batched sigma-delta encoder: (B, 2, L) -> (B, T, 2, L).

    Pure-jax counterpart of :func:`sigma_delta_encode_np` (identical
    numerics, asserted in tests).  Because it traces, the serving engine
    composes it with the bound forward pass under one ``jax.jit`` so
    encoding rides inside the compiled step instead of stalling the host —
    the software analogue of the paper's fully-pipelined Σ-Δ input stage.
    """
    from repro.core.encoder import encode_frames

    return jnp.moveaxis(encode_frames(iq, osr), 0, 1)


class SpikeBatchPipeline:
    """Background-threaded batch producer with bounded-queue backpressure.

    ``close()`` ends the stream for consumers too: a sentinel is left in
    the queue so a consumer blocked in (or arriving at) ``__next__`` gets
    ``StopIteration`` instead of hanging forever on an empty queue whose
    producer has stopped.

    ``scenario`` (a name from :data:`repro.channel.SCENARIOS` or a
    :class:`~repro.channel.ChannelScenario`) inserts a channel-augmentation
    stage: the generator emits *clean* modulated frames and the producer
    thread runs them through the scenario's jitted channel at each frame's
    SNR before Σ-Δ encoding — deterministic in ``(seed, batch index,
    scenario)``.
    """

    _CLOSED = object()  # sentinel: producer stopped, stream is over

    def __init__(
        self,
        batch_size: int,
        osr: int = 8,
        seed: int = 0,
        snr_db: Optional[float] = None,
        prefetch: int = 4,
        sharding: Optional[jax.sharding.Sharding] = None,
        scenario=None,
    ):
        self.osr = osr
        self.sharding = sharding
        self._scenario = scenario
        self._seed = seed
        self._ds = iter(RadioMLDataset(batch_size, seed=seed, snr_db=snr_db,
                                       apply_channel=scenario is None))
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = 0
        while not self._stop.is_set():
            iq, labels, snrs = next(self._ds)
            if self._scenario is not None:
                from repro.channel import apply_scenario_np

                iq = apply_scenario_np(self._scenario, iq, snrs,
                                       self._seed + step)
            step += 1
            frames = sigma_delta_encode_np(iq, self.osr)
            try:
                self._q.put((frames, labels, snrs), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                # retry; the consumer is slow, backpressure holds
                while not self._stop.is_set():
                    try:
                        self._q.put((frames, labels, snrs), timeout=1.0)
                        break
                    except queue.Full:
                        continue

    def __iter__(self) -> Iterator[Tuple[jax.Array, jax.Array, jax.Array]]:
        return self

    def _put_sentinel(self) -> None:
        """Non-blocking sentinel publish: never wait on a full queue (a
        straggler producer could have refilled it), make room instead."""
        while True:
            try:
                self._q.put_nowait(self._CLOSED)
                return
            except queue.Full:
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    pass

    def __next__(self):
        while True:
            item = self._q.get()
            if item is self._CLOSED:
                # leave the sentinel for siblings, then end the stream
                self._put_sentinel()
                raise StopIteration
            if self._stop.is_set():
                # a straggling producer (one that outlived close()'s join
                # timeout) can land a batch behind the sentinel; once the
                # stream is closed, stale batches are discarded so it can
                # never appear to resume after StopIteration
                continue
            frames, labels, snrs = item
            if self.sharding is not None:
                frames = jax.device_put(frames, self.sharding)
                labels = jax.device_put(labels, self.sharding)
            return frames, labels, snrs

    def close(self):
        """Stop the producer and end the stream for all consumers."""
        self._stop.set()
        # unblock a producer stuck in put(), then let it exit
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
        # drain anything the producer managed to enqueue while exiting so
        # the sentinel is what consumers reach next
        try:
            while True:
                item = self._q.get_nowait()
                if item is self._CLOSED:
                    break
        except queue.Empty:
            pass
        self._put_sentinel()


def lm_token_batches(
    batch: int, seq_len: int, vocab: int, seed: int = 0
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Deterministic Zipf-ish token stream: yields (tokens, labels)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq_len + 1), p=probs).astype(np.int32)
        yield toks[:, :-1], toks[:, 1:]
