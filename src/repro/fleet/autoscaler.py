"""Autoscaler: a p99/utilization control loop over a :class:`FleetRouter`.

The control law is deliberately boring — production autoscalers live or
die on predictability, not cleverness:

* **scale up** when the fleet is visibly past its latency budget:
  recent p99 above ``target_p99_ms``, *or* requests shed / expired since
  the last tick, *or* windowed worker utilization above
  ``high_utilization`` — sustained for ``up_patience`` consecutive ticks;
* **scale down** when the fleet is comfortably idle: p99 under
  ``down_ratio * target_p99_ms``, no shedding/expiry, queue empty-ish,
  and utilization under ``low_utilization`` — sustained for
  ``down_patience`` ticks (down is slower than up: adding capacity late
  costs tail latency, removing it late costs only money);
* every action starts a ``cooldown_ticks`` refractory window so the loop
  never flaps on its own transient (a fresh replica's warmup blip must
  not trigger the next decision).

``step()`` is a single deterministic control tick — the unit the tests
and the open-loop bench drive directly; ``start()``/``stop()`` run the
same tick on a daemon thread every ``interval_s`` for live deployments.
Every tick appends an :class:`AutoscaleTick` to ``trace`` — the
``BENCH_fleet.json`` autoscaler trace is exactly this list.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs.metrics import default_registry

__all__ = ["AutoscaleTick", "Autoscaler"]


@dataclasses.dataclass(frozen=True)
class AutoscaleTick:
    """One control-loop observation + the action it produced."""

    tick: int
    t: float
    n_replicas: int
    p99_ms: float
    queue_depth: int
    utilization: float        # windowed: busy-seconds delta / capacity
    shed_delta: int           # requests shed since the previous tick
    expired_delta: int        # deadlines blown since the previous tick
    action: str               # "scale-up" | "scale-down" | "hold"
    reason: str

    def summary(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class Autoscaler:
    """Grow/shrink a fleet against a p99 target and utilization band.

    ``fleet`` needs only the control surface: ``signals()``,
    ``scale_up()``, ``scale_down()`` (the tests drive a fake) — a
    :class:`~repro.fleet.router.FleetRouter` in production.
    """

    def __init__(
        self,
        fleet,
        *,
        target_p99_ms: float,
        high_utilization: float = 0.75,
        low_utilization: float = 0.20,
        down_ratio: float = 0.5,
        up_patience: int = 1,
        down_patience: int = 4,
        cooldown_ticks: int = 2,
        interval_s: float = 0.5,
        clock=time.perf_counter,
    ):
        if target_p99_ms <= 0:
            raise ValueError(f"target_p99_ms must be > 0, got {target_p99_ms}")
        if not 0 <= low_utilization < high_utilization <= 1:
            raise ValueError(
                f"need 0 <= low < high <= 1 utilization, got "
                f"{low_utilization}/{high_utilization}")
        self.fleet = fleet
        self.target_p99_ms = target_p99_ms
        self.high_utilization = high_utilization
        self.low_utilization = low_utilization
        self.down_ratio = down_ratio
        self.up_patience = max(1, up_patience)
        self.down_patience = max(1, down_patience)
        self.cooldown_ticks = max(0, cooldown_ticks)
        self.interval_s = interval_s
        self._clock = clock
        self.trace: List[AutoscaleTick] = []
        self._tick = 0
        self._breach_ticks = 0
        self._idle_ticks = 0
        self._cooldown = 0
        self._last: Optional[Dict[str, Any]] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # alert-driven scale-up pressure (repro.obs.anomaly sinks): while
        # any named alert is pressing, every tick counts as overloaded —
        # an SLO burn is a longer-horizon signal than one tick's p99
        self._pressure_lock = threading.Lock()
        self._alert_pressure: set = set()
        # structured registry mirror of every control tick: action-labeled
        # tick counter plus the raw signals the decision was made on
        reg = default_registry()
        self._m_ticks = reg.counter(
            "repro_autoscale_ticks_total",
            "Autoscaler control ticks by resulting action", ("action",))
        self._m_p99 = reg.gauge(
            "repro_autoscale_p99_ms", "Fleet p99 (ms) at the last tick")
        self._m_util = reg.gauge(
            "repro_autoscale_utilization",
            "Windowed worker utilization at the last tick")
        self._m_depth = reg.gauge(
            "repro_autoscale_queue_depth",
            "Fleet queue depth at the last tick")
        self._m_replicas = reg.gauge(
            "repro_autoscale_replicas",
            "Replica count observed at the last tick")

    # -- alert pressure (the obs anomaly/burn-rate sink surface) -------------

    def set_alert_pressure(self, name: str) -> None:
        """Press scale-up while the named alert fires (idempotent)."""
        with self._pressure_lock:
            self._alert_pressure.add(name)

    def clear_alert_pressure(self, name: str) -> None:
        with self._pressure_lock:
            self._alert_pressure.discard(name)

    def alert_pressure(self) -> List[str]:
        with self._pressure_lock:
            return sorted(self._alert_pressure)

    # -- one deterministic control tick -------------------------------------

    def _utilization(self, sig: Dict[str, Any]) -> float:
        """Windowed busy fraction between the previous tick and this one."""
        if self._last is None:
            return 0.0
        dt = sig["t"] - self._last["t"]
        workers = max(1, sig.get("workers", 1))
        if dt <= 0:
            return 0.0
        busy = sig.get("busy_s", 0.0) - self._last.get("busy_s", 0.0)
        return min(1.0, max(0.0, busy / (dt * workers)))

    def step(self) -> AutoscaleTick:
        """Observe the fleet, decide, (maybe) act, and record the tick."""
        sig = self.fleet.signals()
        util = self._utilization(sig)
        last = self._last or {}
        shed_delta = int(sig.get("shed", 0) - last.get("shed", 0))
        expired_delta = int(sig.get("expired", 0) - last.get("expired", 0))
        self._last = sig
        p99 = float(sig.get("p99_ms", 0.0))
        depth = int(sig.get("queue_depth", 0))
        n = int(sig.get("n_replicas", 1))

        pressure = self.alert_pressure()
        overloaded = (p99 > self.target_p99_ms or shed_delta > 0
                      or expired_delta > 0 or util > self.high_utilization
                      or bool(pressure))
        idle = (p99 < self.down_ratio * self.target_p99_ms
                and shed_delta == 0 and expired_delta == 0
                and util < self.low_utilization and depth <= n
                and not pressure)
        self._breach_ticks = self._breach_ticks + 1 if overloaded else 0
        self._idle_ticks = self._idle_ticks + 1 if idle else 0

        action, reason = "hold", ""
        if self._cooldown > 0:
            self._cooldown -= 1
            reason = f"cooldown ({self._cooldown} ticks left)"
        elif overloaded and self._breach_ticks >= self.up_patience:
            why = []
            if p99 > self.target_p99_ms:
                why.append(f"p99 {p99:.1f}ms > target {self.target_p99_ms}ms")
            if shed_delta:
                why.append(f"{shed_delta} shed")
            if expired_delta:
                why.append(f"{expired_delta} expired")
            if util > self.high_utilization:
                why.append(f"util {util:.2f} > {self.high_utilization}")
            if pressure:
                why.append(f"alert pressure: {', '.join(pressure)}")
            added = self.fleet.scale_up()
            if added is not None:
                action = "scale-up"
                reason = f"{'; '.join(why)} -> +{added}"
                self._cooldown = self.cooldown_ticks
                self._breach_ticks = 0
            else:
                reason = f"{'; '.join(why)} (at max replicas)"
        elif idle and self._idle_ticks >= self.down_patience:
            removed = self.fleet.scale_down()
            if removed is not None:
                action = "scale-down"
                reason = (f"idle: p99 {p99:.1f}ms, util {util:.2f} "
                          f"-> -{removed}")
                self._cooldown = self.cooldown_ticks
                self._idle_ticks = 0
            else:
                reason = "idle (at min replicas)"

        tick = AutoscaleTick(
            tick=self._tick, t=float(sig.get("t", self._clock())),
            n_replicas=n, p99_ms=p99, queue_depth=depth, utilization=util,
            shed_delta=shed_delta, expired_delta=expired_delta,
            action=action, reason=reason)
        self._tick += 1
        self.trace.append(tick)
        self._m_ticks.labels(action=action).inc()
        self._m_p99.set(p99)
        self._m_util.set(util)
        self._m_depth.set(depth)
        self._m_replicas.set(n)
        return tick

    def trace_summary(self) -> List[Dict[str, Any]]:
        return [t.summary() for t in self.trace]

    # -- background loop ----------------------------------------------------

    def start(self) -> None:
        """Run ``step()`` every ``interval_s`` on a daemon thread."""
        if self._thread is not None:
            raise RuntimeError("autoscaler already started")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval_s):
                self.step()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="fleet-autoscaler")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
