"""Zero-downtime hot-swap for :class:`AsyncAMCServeEngine`.

The sequence mirrors a blue/green flip collapsed into one process:

1. **bind off the hot path** — the incoming version's plan is compiled in
   the swapping thread (``compile_plan`` through the content-addressed
   cache — a registry publish already warmed the COO/schedule artifacts)
   and every micro-batch bucket shape is pre-compiled, while the workers
   keep draining traffic on the current version;
2. **atomic flip** — ``engine.swap_to`` retargets the primary label
   between micro-batches: in-flight batches complete on the old plan,
   the next batch any worker picks up runs the new one.  No request is
   dropped, and none waits for more than one batch flush;
3. **drain barrier** — ``batcher.drain_barrier`` confirms every request
   enqueued before the flip has been batched, which is what the
   :class:`SwapReport` certifies.

``hot_swap`` blocks; ``hot_swap_async`` runs the same sequence on a
daemon thread and returns a ``concurrent.futures.Future[SwapReport]`` —
the pattern a control plane (or the canary monitor's promote path) uses.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import threading
import time
from typing import Any, Dict, Optional

from repro.deploy.registry import ModelRegistry
from repro.obs.metrics import default_registry

__all__ = ["SwapReport", "hot_swap", "hot_swap_async",
           "hot_swap_from_registry", "mark_production"]

#: Histogram bounds for bind/flip durations (seconds): swaps are rare,
#: seconds-scale events, so the default latency ladder is too fine.
_SWAP_BUCKETS = (0.01, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def mark_production(label: str) -> None:
    """Flag ``label`` as the production version in the metrics registry.

    Prometheus "info" pattern: the gauge family
    ``repro_deploy_production_info{version=...}`` holds exactly one child
    at 1 (all previously-marked versions drop to 0), so a scrape joins
    metrics against the serving version without a registry reset.
    """
    default_registry().gauge(
        "repro_deploy_production_info",
        "1 on the label currently marked production, 0 on prior labels",
        ("version",)).set_exclusive(version=label)


@dataclasses.dataclass(frozen=True)
class SwapReport:
    """What a completed hot-swap certifies (and what the bench records)."""

    old_label: str
    new_label: str
    backend: str
    bind_s: float          # off-thread compile + per-bucket warmup
    flip_s: float          # swap_to() -> pre-flip backlog fully batched
    queued_at_flip: int    # requests waiting in the queue at the flip
    drained: bool          # pre-flip backlog confirmed batched in time
    plan_digest: Optional[str]

    def summary(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def hot_swap(
    engine,
    params,
    masks=None,
    *,
    label: str,
    backend: Optional[str] = None,
    lsq_scales=None,
    quant_bits: Optional[int] = None,
    warmup: bool = True,
    drain_timeout: float = 30.0,
) -> SwapReport:
    """Bind ``params`` under ``label`` and flip the engine's primary to it.

    Safe under live traffic: the bind runs in this thread, the flip is a
    table-pointer update, and the drain barrier bounds how long the old
    plan's backlog lingers.  Raises if ``label`` is already bound (each
    version label is immutable once serving — publish a new version
    instead of mutating one in place).
    """
    if label in engine.versions():
        raise ValueError(f"version label {label!r} is already bound")
    t0 = time.perf_counter()
    ver = engine.bind_version(label, params, masks, backend=backend,
                              lsq_scales=lsq_scales, quant_bits=quant_bits,
                              warmup=warmup)
    bind_s = time.perf_counter() - t0

    queued = engine.batcher.qsize()
    t1 = time.perf_counter()
    old = engine.swap_to(label)
    drained = engine.batcher.drain_barrier(timeout=drain_timeout)
    flip_s = time.perf_counter() - t1
    reg = default_registry()
    reg.counter("repro_deploy_swaps_total", "Completed hot-swaps by outcome",
                ("outcome",)).labels(
        outcome="drained" if drained else "drain-timeout").inc()
    reg.histogram("repro_deploy_bind_seconds",
                  "Off-hot-path bind time (compile + bucket warmup)",
                  buckets=_SWAP_BUCKETS).observe(bind_s)
    reg.histogram("repro_deploy_flip_seconds",
                  "swap_to() through the pre-flip backlog drain",
                  buckets=_SWAP_BUCKETS).observe(flip_s)
    mark_production(label)
    return SwapReport(
        old_label=old, new_label=label, backend=ver.backend, bind_s=bind_s,
        flip_s=flip_s, queued_at_flip=queued, drained=drained,
        plan_digest=getattr(ver.plan, "digest", None))


def hot_swap_async(engine, params, masks=None, *, label: str,
                   backend: Optional[str] = None, lsq_scales=None,
                   quant_bits: Optional[int] = None, warmup: bool = True,
                   drain_timeout: float = 30.0
                   ) -> "concurrent.futures.Future[SwapReport]":
    """Run :func:`hot_swap` on a daemon thread; resolve to its report."""
    fut: "concurrent.futures.Future[SwapReport]" = concurrent.futures.Future()

    def _run() -> None:
        if not fut.set_running_or_notify_cancel():
            return
        try:
            fut.set_result(hot_swap(engine, params, masks, label=label,
                                    backend=backend, lsq_scales=lsq_scales,
                                    quant_bits=quant_bits, warmup=warmup,
                                    drain_timeout=drain_timeout))
        except BaseException as e:  # noqa: BLE001 — surface to the caller
            fut.set_exception(e)

    threading.Thread(target=_run, daemon=True, name=f"hot-swap-{label}").start()
    return fut


def hot_swap_from_registry(
    engine,
    registry: ModelRegistry,
    spec: str,
    *,
    label: Optional[str] = None,
    backend: Optional[str] = None,
    warmup: bool = True,
    drain_timeout: float = 30.0,
) -> SwapReport:
    """Resolve ``name[@version|@alias]``, validate, and hot-swap to it.

    The loaded version's config must equal the engine's — the micro-batch
    frame shape and the compiled bucket ladder are config-derived, so a
    config change is a redeploy, not a swap.  ``backend=None`` inherits
    the engine's (autotuned) serving backend; the assignment recorded at
    publish time only chose which plan artifacts were pre-warmed.
    """
    loaded = registry.load(spec)
    if loaded.cfg != engine.cfg:
        raise ValueError(
            f"registry version {loaded.version.spec} was trained with a "
            f"different SNNConfig than the engine is serving; hot-swap "
            f"requires matching configs (got {loaded.cfg} vs {engine.cfg})")
    return hot_swap(engine, loaded.params, loaded.masks,
                    label=label or loaded.version.spec, backend=backend,
                    lsq_scales=loaded.lsq_scales,
                    quant_bits=loaded.version.quant_bits,
                    warmup=warmup, drain_timeout=drain_timeout)
