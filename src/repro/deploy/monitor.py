"""Canary monitor: sliding-window shadow evaluation, auto-promote/rollback.

A canary that *serves* traffic tells you its latency; it does not tell
you whether its classifications got worse at -8 dB.  The monitor closes
that loop the way the paper's edge node would: it **shadow-evaluates**
both the production baseline and the canary on synthetic
:mod:`repro.data.radioml` frames, bucketed per SNR (the paper's Fig. 8
protocol — AMC accuracy is an SNR-conditional quantity, and a regression
confined to the low-SNR bins must not be averaged away), keeps a sliding
window of the last few evaluation rounds, and decides:

* **rollback** — any SNR bucket's windowed canary score drops more than
  ``acc_drop_tol`` below the baseline's, or the canary's served p99
  exceeds ``p99_factor`` x the baseline's: the canary is removed from the
  serving table and the router cleared, production keeps all traffic;
* **promote** — the canary stays within tolerance for ``promote_after``
  consecutive clean rounds: it becomes the engine's primary (via the
  same atomic flip a hot-swap uses) and, when a registry is attached,
  the ``production`` alias advances to it;
* **pending** — not enough evidence yet; keep watching.

Scoring modes:

* ``score="labels"`` — accuracy against the synthetic generator's ground
  truth (available here because the RadioML generator is part of the
  repo; in the field this is a labeled replay buffer);
* ``score="agreement"`` — fraction of frames where the canary's argmax
  matches *production's* (no ground truth needed at the edge: a retrained
  model that suddenly disagrees with the fleet baseline across an SNR
  bucket is exactly the continual-learning failure arXiv:2502.17168
  worries about).

``frame_source`` is pluggable (seed, n, snr) -> (iq, labels) so replay
buffers or recorded captures can stand in for the synthetic generator —
and so channel drift can be *injected*:
``repro.channel.make_frame_source("doppler_drift", frame_len=...)``
shadow-evaluates both sides under a fading/CFO/timing-drift channel
instead of the clean dataset channel (tested: a drift-sensitive canary
rolls back, an equivalent one is not falsely rolled back).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.channel import stable_seed
from repro.obs.metrics import default_registry

__all__ = ["MonitorConfig", "WindowResult", "CanaryMonitor"]

FrameSource = Callable[[int, int, float], Tuple[np.ndarray, np.ndarray]]


def _snr_bin_seed(snr_db: float) -> int:
    """Stable 32-bit seed offset for one SNR bucket.

    Hashes the bytes of the *float* (shared :func:`repro.channel.stable_seed`
    primitive): the old ``int(snr) * 131`` derivation collapsed fractional
    bins (0.5 and 0.9 both truncate to 0) into identical frame draws,
    silently evaluating two buckets on the same frames.
    """
    return stable_seed("snr-bin", snr_db)


def _default_frame_source(seed: int, n: int, snr_db: float,
                          frame_len: int, n_classes: int):
    from repro.data.radioml import N_CLASSES, generate_batch

    classes = (tuple(range(n_classes)) if n_classes < N_CLASSES else None)
    iq, labels, _ = generate_batch(seed, n, snr_db=snr_db, classes=classes,
                                   frame_len=frame_len)
    return iq, labels


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    snr_bins: Tuple[float, ...] = (-10.0, 0.0, 10.0)
    frames_per_bin: int = 32
    window: int = 3              # rounds kept in the sliding window
    min_rounds: int = 2          # evidence floor before any decision
    promote_after: int = 3       # consecutive clean rounds to promote
    acc_drop_tol: float = 0.05   # max windowed per-bin score drop
    p99_factor: float = 2.0      # max canary p99 / baseline p99
    min_latency_samples: int = 20  # per side, before p99 is trusted
    score: str = "labels"        # or "agreement"
    seed: int = 20_260_801

    def __post_init__(self):
        if self.score not in ("labels", "agreement"):
            raise ValueError(f"score must be 'labels' or 'agreement', "
                             f"got {self.score!r}")


@dataclasses.dataclass
class WindowResult:
    """One shadow-evaluation round (per-SNR scores + served p99s)."""

    round: int
    baseline_acc: Dict[float, float]
    canary_acc: Dict[float, float]
    baseline_p99_ms: float
    canary_p99_ms: float
    wall_s: float


class CanaryMonitor:
    """Watches one canary against the production baseline on an engine.

    Pull-based: each :meth:`step` runs one evaluation round and returns
    the decision so far (``"pending"`` / ``"promote"`` / ``"rollback"``);
    :meth:`run` loops until a decision or ``max_rounds``.  Decisions are
    enacted on the engine (and registry, when attached) exactly once.
    """

    def __init__(
        self,
        engine,
        *,
        baseline: str,
        canary: str,
        config: Optional[MonitorConfig] = None,
        frame_source: Optional[FrameSource] = None,
        registry=None,
        canary_spec: Optional[str] = None,
    ):
        self.engine = engine
        self.baseline = baseline
        self.canary = canary
        self.config = config or MonitorConfig()
        if frame_source is None:
            width = engine.cfg.input_width  # frames must match the model
            n_cls = engine.cfg.n_classes    # labels must stay in range
            frame_source = (lambda seed, n, snr:
                            _default_frame_source(seed, n, snr, width,
                                                  n_cls))
        self.frame_source = frame_source
        self.registry = registry
        self.canary_spec = canary_spec
        self.history: List[WindowResult] = []
        self.decision = "pending"
        self.reason = ""
        self._round = 0
        self._clean_rounds = 0
        for label in (baseline, canary):
            engine.get_version(label)  # fail fast on unbound labels
        # structured registry mirror of the monitor's lifecycle
        reg = default_registry()
        self._m_rounds = reg.counter(
            "repro_canary_rounds_total",
            "Shadow-evaluation rounds run per canary",
            ("canary",)).labels(canary=canary)
        self._m_decisions = reg.counter(
            "repro_canary_decisions_total",
            "Terminal canary decisions by kind",
            ("decision", "canary"))
        self._m_clean = reg.gauge(
            "repro_canary_clean_rounds",
            "Consecutive clean (regression-free) rounds so far",
            ("canary",)).labels(canary=canary)

    # -- shadow evaluation --------------------------------------------------

    def _predict(self, label: str, iq: np.ndarray) -> np.ndarray:
        """Class ids via the version's own compiled step (shadow path —
        does not enter the request queue, so it never skews served
        latency stats)."""
        ver = self.engine.get_version(label)
        return np.asarray(ver.step(jnp.asarray(iq))).argmax(-1)

    def _score(self, preds: np.ndarray, labels: np.ndarray,
               ref: np.ndarray) -> float:
        target = labels if self.config.score == "labels" else ref
        return float((preds == target).mean())

    def evaluate_round(self) -> WindowResult:
        """One evaluation pass over every SNR bucket (no decision)."""
        cfg = self.config
        t0 = time.perf_counter()
        base_acc: Dict[float, float] = {}
        can_acc: Dict[float, float] = {}
        for snr in cfg.snr_bins:
            seed = cfg.seed + 7919 * self._round + _snr_bin_seed(snr)
            iq, labels = self.frame_source(seed, cfg.frames_per_bin, snr)
            base_preds = self._predict(self.baseline, iq)
            can_preds = self._predict(self.canary, iq)
            base_acc[snr] = self._score(base_preds, labels, base_preds)
            can_acc[snr] = self._score(can_preds, labels, base_preds)
        stats = self.engine.version_stats()
        res = WindowResult(
            round=self._round,
            baseline_acc=base_acc, canary_acc=can_acc,
            baseline_p99_ms=stats[self.baseline].p99_ms,
            canary_p99_ms=stats[self.canary].p99_ms,
            wall_s=time.perf_counter() - t0)
        self._round += 1
        self.history.append(res)
        if len(self.history) > cfg.window:
            del self.history[: -cfg.window]
        return res

    # -- decision rule ------------------------------------------------------

    def _windowed(self, pick) -> Dict[float, float]:
        """Mean per-SNR score over the sliding window."""
        out: Dict[float, List[float]] = {}
        for res in self.history:
            for snr, v in pick(res).items():
                out.setdefault(snr, []).append(v)
        return {snr: float(np.mean(vs)) for snr, vs in out.items()}

    def _check(self) -> Tuple[str, str]:
        cfg = self.config
        if self._round < cfg.min_rounds:
            return "pending", f"warming up ({self._round}/{cfg.min_rounds})"
        base = self._windowed(lambda r: r.baseline_acc)
        can = self._windowed(lambda r: r.canary_acc)
        regressed = {snr: (base[snr], can[snr]) for snr in base
                     if can[snr] < base[snr] - cfg.acc_drop_tol}
        if regressed:
            worst = min(regressed, key=lambda s: regressed[s][1] -
                        regressed[s][0])
            b, c = regressed[worst]
            return ("rollback",
                    f"accuracy regression at {sorted(regressed)} dB "
                    f"(worst {worst:+.0f} dB: canary {c:.3f} vs baseline "
                    f"{b:.3f}, tol {cfg.acc_drop_tol})")
        stats = self.engine.version_stats()
        bs, cs = stats[self.baseline], stats[self.canary]
        if (len(bs.latencies_s) >= cfg.min_latency_samples
                and len(cs.latencies_s) >= cfg.min_latency_samples
                and bs.p99_ms > 0
                and cs.p99_ms > cfg.p99_factor * bs.p99_ms):
            return ("rollback",
                    f"latency regression: canary p99 {cs.p99_ms:.1f}ms > "
                    f"{cfg.p99_factor}x baseline p99 {bs.p99_ms:.1f}ms")
        if self._clean_rounds + 1 >= cfg.promote_after:
            return ("promote",
                    f"{self._clean_rounds + 1} clean rounds across "
                    f"{len(base)} SNR bins")
        return "pending", f"clean round {self._clean_rounds + 1}"

    # -- actions ------------------------------------------------------------

    def _enact_rollback(self) -> None:
        self.engine.set_router(None)
        try:
            self.engine.remove_version(self.canary)
        except ValueError:
            # the canary had already been made primary (manual swap):
            # flip back to the baseline first, then drop it
            self.engine.swap_to(self.baseline)
            self.engine.remove_version(self.canary)

    def _enact_promote(self) -> None:
        from repro.deploy.swap import mark_production

        self.engine.swap_to(self.canary)
        self.engine.set_router(None)
        mark_production(self.canary)
        if self.registry is not None and self.canary_spec:
            name, version = self.registry.resolve(self.canary_spec)
            self.registry.set_alias(name, "production", version)

    # -- public loop --------------------------------------------------------

    def step(self) -> str:
        """One evaluation round + decision; enacts promote/rollback once."""
        if self.decision != "pending":
            return self.decision
        self.evaluate_round()
        self._m_rounds.inc()
        decision, reason = self._check()
        self.reason = reason
        if decision == "rollback":
            self._enact_rollback()
            self.decision = "rollback"
        elif decision == "promote":
            self._enact_promote()
            self.decision = "promote"
        elif self._round >= self.config.min_rounds:
            # warm-up rounds gather evidence but are not regression-checked
            # — only checked-and-clean rounds count toward promote_after
            self._clean_rounds += 1
        self._m_clean.set(self._clean_rounds)
        if self.decision != "pending":
            self._m_decisions.labels(decision=self.decision,
                                     canary=self.canary).inc()
        return self.decision

    def run(self, max_rounds: int = 10,
            sleep_s: float = 0.0) -> str:
        """Step until a decision or ``max_rounds`` evaluation rounds."""
        for _ in range(max_rounds):
            if self.step() != "pending":
                break
            if sleep_s:
                time.sleep(sleep_s)
        return self.decision

    def summary(self) -> Dict[str, Any]:
        return {
            "decision": self.decision,
            "reason": self.reason,
            "rounds": self._round,
            "score": self.config.score,
            "windowed_baseline": self._windowed(lambda r: r.baseline_acc),
            "windowed_canary": self._windowed(lambda r: r.canary_acc),
        }
