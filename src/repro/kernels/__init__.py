"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships with a pure-jnp oracle in ``ref.py`` and a jit'd
framework-facing wrapper in ``ops.py``; tests sweep shapes/dtypes in
interpret mode (this container is CPU-only; TPU v5e is the target).
"""

from .goap_conv import goap_conv_block_sparse
from .wm_fc import wm_fc_matmul
from .lif_update import lif_update_fused
from .ops import goap_conv_op, wm_fc_op, lif_op
from . import ref
