"""Synthetic RadioML 2016.10A-equivalent dataset (paper §IV-A).

The original dataset [13] is generated with GNU Radio: 11 modulation schemes
(8 digital, 3 analog), 128-sample complex baseband frames, AWGN SNRs from
-20 to 18 dB in 2 dB steps.  It is not redistributable here, so we implement
the generator: proper constellation mapping + root-raised-cosine pulse
shaping for linear digital schemes, Gaussian/continuous-phase frequency
modulation for (G/CP)FSK, an audio-like AR source for the analog schemes,
and a channel with AWGN, random carrier frequency/phase offset and timing
jitter — the same impairment family GNU Radio's dynamic channel model
applies.

All generation is vectorized numpy on the host; every sample is
deterministic in (seed, index).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.channel.impairments import legacy_awgn_channel

__all__ = [
    "MODULATIONS",
    "N_CLASSES",
    "SNR_GRID",
    "generate_sample",
    "generate_batch",
    "RadioMLDataset",
]

MODULATIONS = (
    "BPSK", "QPSK", "8PSK", "PAM4", "QAM16", "QAM64", "GFSK", "CPFSK",  # digital
    "WBFM", "AM-DSB", "AM-SSB",                                         # analog
)
N_CLASSES = len(MODULATIONS)
SNR_GRID = tuple(range(-20, 20, 2))

FRAME_LEN = 128
SPS = 8  # samples per symbol for linear digital modulations


# ---------------------------------------------------------------------------
# Pulse shaping
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _rrc_taps(beta: float = 0.35, span: int = 8, sps: int = SPS) -> np.ndarray:
    """Root-raised-cosine filter taps (vectorized, cached per parameter set).

    The closed form has two removable singularities — t = 0 and
    |4*beta*t| = 1 — handled by ``np.where`` over the same formulas the old
    per-tap loop branched on (elementwise identical, so bit-equal).  The
    cache returns one read-only array per (beta, span, sps): tap
    construction never re-runs per generated batch.
    """
    n = span * sps
    t = (np.arange(-n // 2, n // 2 + 1)) / sps
    near_zero = np.abs(t) < 1e-9
    singular = np.abs(np.abs(4 * beta * t) - 1.0) < 1e-9
    with np.errstate(divide="ignore", invalid="ignore"):
        num = np.sin(np.pi * t * (1 - beta)) + 4 * beta * t * np.cos(np.pi * t * (1 + beta))
        den = np.pi * t * (1 - (4 * beta * t) ** 2)
        taps = num / den
    taps = np.where(
        singular,
        (beta / np.sqrt(2)) * (
            (1 + 2 / np.pi) * np.sin(np.pi / (4 * beta))
            + (1 - 2 / np.pi) * np.cos(np.pi / (4 * beta))
        ),
        taps,
    )
    taps = np.where(near_zero, 1.0 - beta + 4 * beta / np.pi, taps)
    taps = taps / np.sqrt(np.sum(taps**2))
    taps.flags.writeable = False  # shared across callers via the cache
    return taps


_RRC = _rrc_taps()

_GAUSS_BT = 0.35


@functools.lru_cache(maxsize=None)
def _gaussian_taps(bt: float = _GAUSS_BT, span: int = 4, sps: int = SPS) -> np.ndarray:
    t = np.arange(-span * sps // 2, span * sps // 2 + 1) / sps
    sigma = np.sqrt(np.log(2)) / (2 * np.pi * bt)
    taps = np.exp(-(t**2) / (2 * sigma**2))
    taps = taps / taps.sum()
    taps.flags.writeable = False
    return taps


_GAUSS = _gaussian_taps()

# ---------------------------------------------------------------------------
# Constellations
# ---------------------------------------------------------------------------

def _psk_points(m: int) -> np.ndarray:
    k = np.arange(m)
    return np.exp(1j * (2 * np.pi * k / m + np.pi / m))


def _qam_points(m: int) -> np.ndarray:
    side = int(np.sqrt(m))
    re, im = np.meshgrid(np.arange(side), np.arange(side))
    pts = (2 * re - side + 1) + 1j * (2 * im - side + 1)
    pts = pts.ravel()
    return pts / np.sqrt((np.abs(pts) ** 2).mean())


def _pam_points(m: int) -> np.ndarray:
    pts = 2 * np.arange(m) - m + 1
    return (pts / np.sqrt((pts**2).mean())).astype(complex)


_CONSTELLATIONS = {
    "BPSK": _psk_points(2),
    "QPSK": _psk_points(4),
    "8PSK": _psk_points(8),
    "PAM4": _pam_points(4),
    "QAM16": _qam_points(16),
    "QAM64": _qam_points(64),
}

# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------

def _audio_like(rng: np.random.Generator, n: int) -> np.ndarray:
    """Speech-like lowpass AR(2) source, normalized to unit peak."""
    w = rng.normal(size=n + 64)
    x = np.zeros_like(w)
    a1, a2 = 1.6, -0.72  # poles well inside unit circle, lowpass
    for i in range(2, len(w)):
        x[i] = w[i] + a1 * x[i - 1] + a2 * x[i - 2]
    x = x[64:]
    return x / (np.max(np.abs(x)) + 1e-9)


def _modulate_linear(rng: np.random.Generator, scheme: str, n: int) -> np.ndarray:
    const = _CONSTELLATIONS[scheme]
    n_sym = n // SPS + len(_RRC) // SPS + 4
    syms = const[rng.integers(0, len(const), n_sym)]
    up = np.zeros(n_sym * SPS, dtype=complex)
    up[::SPS] = syms
    shaped = np.convolve(up, _RRC, mode="same")
    start = len(_RRC) // 2
    return shaped[start : start + n]


def _modulate_fsk(rng: np.random.Generator, scheme: str, n: int) -> np.ndarray:
    n_sym = n // SPS + 8
    bits = rng.integers(0, 2, n_sym) * 2.0 - 1.0
    freq = np.repeat(bits, SPS)
    if scheme == "GFSK":
        freq = np.convolve(freq, _GAUSS, mode="same")
    h = 0.5  # modulation index
    phase = np.cumsum(freq) * np.pi * h / SPS
    sig = np.exp(1j * phase)
    return sig[:n]


def _modulate_analog(rng: np.random.Generator, scheme: str, n: int) -> np.ndarray:
    x = _audio_like(rng, n)
    if scheme == "WBFM":
        kf = 0.4
        phase = 2 * np.pi * kf * np.cumsum(x)
        return np.exp(1j * phase)
    if scheme == "AM-DSB":
        m = 0.8
        return (1.0 + m * x).astype(complex)
    if scheme == "AM-SSB":
        # upper sideband via discrete Hilbert transform
        X = np.fft.fft(x)
        h = np.zeros(n)
        h[0] = 1
        if n % 2 == 0:
            h[n // 2] = 1
            h[1 : n // 2] = 2
        else:
            h[1 : (n + 1) // 2] = 2
        analytic = np.fft.ifft(X * h)
        return analytic
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# Channel
# ---------------------------------------------------------------------------

# The channel is owned by repro.channel (where its jax-traceable scenario
# twins live); this alias keeps the generator's historical call sites and
# numerics — bit-equality is pinned in tests/test_channel.py.
_apply_channel = legacy_awgn_channel


def generate_sample(
    seed: int, modulation: str, snr_db: float, frame_len: int = FRAME_LEN,
    apply_channel: bool = True,
) -> np.ndarray:
    """One (2, frame_len) float32 I/Q frame, deterministic in seed.

    ``apply_channel=False`` yields the clean modulated baseband (no AWGN /
    CFO / phase noise) — the input expected by
    :func:`repro.channel.apply_scenario`, which applies its own channel.
    The rng draw order is unchanged either way, so the underlying symbol
    stream for a given seed is identical clean and impaired.
    """
    rng = np.random.default_rng(seed)
    if modulation in _CONSTELLATIONS:
        sig = _modulate_linear(rng, modulation, frame_len)
    elif modulation in ("GFSK", "CPFSK"):
        sig = _modulate_fsk(rng, modulation, frame_len)
    else:
        sig = _modulate_analog(rng, modulation, frame_len)
    if apply_channel:
        sig = _apply_channel(rng, sig, snr_db)
    out = np.stack([sig.real, sig.imag]).astype(np.float32)
    # match RadioML's roughly unit-energy frames
    return out / (np.sqrt(np.mean(out**2)) * np.sqrt(2) + 1e-9)


def generate_batch(
    seed: int,
    batch: int,
    snr_db: Optional[float] = None,
    classes: Optional[Tuple[int, ...]] = None,
    frame_len: int = FRAME_LEN,
    apply_channel: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (iq (B, 2, L) f32, labels (B,) i32, snrs (B,) f32).

    With ``apply_channel=False`` the frames are clean modulated baseband
    (``snrs`` still names each frame's *intended* operating SNR, for the
    scenario channel to realize later).
    """
    rng = np.random.default_rng(seed)
    cls_pool = np.asarray(classes if classes is not None else range(N_CLASSES))
    labels = cls_pool[rng.integers(0, len(cls_pool), batch)]
    snrs = (
        np.full(batch, snr_db, dtype=np.float32)
        if snr_db is not None
        else np.asarray(rng.choice(SNR_GRID, batch), dtype=np.float32)
    )
    iq = np.stack([
        generate_sample(int(seed * 1_000_003 + i), MODULATIONS[labels[i]],
                        float(snrs[i]), frame_len, apply_channel)
        for i in range(batch)
    ])
    return iq.astype(np.float32), labels.astype(np.int32), snrs


@dataclasses.dataclass
class RadioMLDataset:
    """Deterministic infinite stream of (iq, label, snr) batches.

    ``apply_channel=False`` streams clean modulated frames for consumers
    that run their own :mod:`repro.channel` scenario (the pipeline's
    augmentation stage sets this automatically).
    """

    batch_size: int
    seed: int = 0
    snr_db: Optional[float] = None  # None -> uniform over the SNR grid
    frame_len: int = FRAME_LEN
    apply_channel: bool = True

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield generate_batch(
                self.seed + step, self.batch_size, self.snr_db,
                frame_len=self.frame_len, apply_channel=self.apply_channel,
            )
            step += 1
