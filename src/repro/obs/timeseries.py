"""Bounded time-series recording over a metrics registry (analysis plane).

The registry (:mod:`repro.obs.metrics`) is a point-in-time scrape: it can
say "789930 accumulations so far" but not "effective density fell 30% in
the last minute".  The paper's central claim — throughput and power
tracking *effective* input/weight sparsity — is only verifiable over
time, and so is every question the alerting layer asks (burn rates,
drift, canary trends).  A :class:`TimeSeriesRecorder` closes that gap:

* it sweeps any registry's families at a fixed interval on a daemon
  thread (or deterministically via :meth:`sample` — what the tests and
  the burn-rate fixtures drive with a fake clock);
* every (family, label-set) child becomes one :class:`Series` holding a
  bounded ring of ``(t, value)`` points with **monotonic timestamps**
  (a sweep whose clock did not advance past the previous sweep is
  dropped, never recorded out of order);
* counters stay *cumulative* in the ring — :meth:`Series.rate` and
  :meth:`Series.delta` derive rates/windows on read, clamping the
  negative deltas a registry swap would produce to zero;
* histogram children record the full cumulative bucket vector per
  sample, so a windowed quantile (:meth:`Series.quantile_over`) or an
  over-bound fraction (:meth:`Series.fraction_over`) is computable for
  any trailing window — the latency-SLO primitive;
* ``registry`` may be a callable returning a registry, so fleet-merged
  sampling is one lambda:
  ``TimeSeriesRecorder(lambda: MetricsRegistry.merged(parts))``;
* :meth:`to_json` exports the whole store (the ``/timeseries`` endpoint
  body).

Cost model: one sweep is a lock-guarded copy of each family's children
plus one float append per series — the recorder gate in
``benchmarks/obs_bench.py`` runs it live (with the SLO engine) inside
the <5% tracing-overhead bar.
"""
from __future__ import annotations

import bisect
import collections
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from repro.obs.metrics import MetricsRegistry, default_registry

__all__ = ["Series", "TimeSeriesRecorder", "set_default_recorder",
           "get_default_recorder"]

#: Histogram sample payload: (cumulative bucket counts incl. +Inf, sum,
#: count).  Stored whole so windowed quantiles need no extra bookkeeping.
HistPoint = Tuple[Tuple[float, ...], float, float]


class Series:
    """One (metric, label-set) ring of ``(t, value)`` samples.

    ``kind`` follows the source family (``counter``/``gauge``/
    ``histogram``); histogram values are :data:`HistPoint` tuples, the
    scalar kinds plain floats.  Appends keep timestamps strictly
    monotonic.  All reads copy under the lock, so a sampler thread and a
    reader (the SLO engine, the HTTP endpoint) never race.
    """

    __slots__ = ("name", "labels", "kind", "buckets", "_lock", "_ring")

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...],
                 kind: str, capacity: int,
                 buckets: Optional[Tuple[float, ...]] = None):
        self.name = name
        self.labels = labels
        self.kind = kind
        self.buckets = buckets
        self._lock = threading.Lock()
        self._ring: "collections.deque[Tuple[float, Any]]" = \
            collections.deque(maxlen=capacity)

    def append(self, t: float, value) -> bool:
        with self._lock:
            if self._ring and t <= self._ring[-1][0]:
                return False      # monotonic timestamps only
            self._ring.append((t, value))
            return True

    def points(self) -> List[Tuple[float, Any]]:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def latest(self) -> Optional[Tuple[float, Any]]:
        with self._lock:
            return self._ring[-1] if self._ring else None

    # -- windowed reads ------------------------------------------------------

    def _at_or_before(self, t: float) -> Optional[Tuple[float, Any]]:
        """Latest point with timestamp <= t (None before the first)."""
        pts = self.points()
        i = bisect.bisect_right([p[0] for p in pts], t)
        return pts[i - 1] if i else None

    def window(self, window_s: float,
               now: Optional[float] = None) -> List[Tuple[float, Any]]:
        """Points in the trailing ``window_s`` seconds ending at ``now``
        (default: the newest sample), *plus* the last point before the
        window so deltas across its left edge are computable."""
        pts = self.points()
        if not pts:
            return []
        t1 = pts[-1][0] if now is None else now
        t0 = t1 - window_s
        ts = [p[0] for p in pts]
        i = bisect.bisect_left(ts, t0)
        # a sample exactly on the edge anchors the delta itself; only
        # step back when the first in-window point is strictly after t0
        lo = i if i < len(ts) and ts[i] == t0 else max(0, i - 1)
        hi = bisect.bisect_right(ts, t1)
        return pts[lo:hi]

    def delta(self, window_s: float, now: Optional[float] = None) -> float:
        """Cumulative-counter increase over the trailing window (>= 0)."""
        w = self.window(window_s, now)
        if len(w) < 2:
            return 0.0
        return max(0.0, float(w[-1][1]) - float(w[0][1]))

    def rate(self, window_s: float, now: Optional[float] = None) -> float:
        """Counter increase per second over the trailing window."""
        w = self.window(window_s, now)
        if len(w) < 2:
            return 0.0
        dt = w[-1][0] - w[0][0]
        if dt <= 0:
            return 0.0
        return max(0.0, float(w[-1][1]) - float(w[0][1])) / dt

    def rates(self) -> List[Tuple[float, float]]:
        """Per-interval counter rates between consecutive samples."""
        pts = self.points()
        out = []
        for (t0, v0), (t1, v1) in zip(pts, pts[1:]):
            dt = t1 - t0
            if dt > 0:
                out.append((t1, max(0.0, float(v1) - float(v0)) / dt))
        return out

    def values(self) -> List[float]:
        """Scalar sample values, oldest first (gauge/counter kinds)."""
        return [float(v) for _, v in self.points()]

    # -- histogram-window primitives (the latency-SLO math) ------------------

    def _hist_delta(self, window_s: float,
                    now: Optional[float] = None) -> Optional[HistPoint]:
        """Bucket/sum/count increase over the trailing window."""
        if self.kind != "histogram":
            raise ValueError(f"{self.name} is a {self.kind}, not histogram")
        w = self.window(window_s, now)
        if not w:
            return None
        if len(w) == 1:   # whole history inside the window: delta from zero
            counts1, sum1, count1 = w[0][1]
            return counts1, sum1, count1
        counts0, sum0, count0 = w[0][1]
        counts1, sum1, count1 = w[-1][1]
        counts = tuple(max(0.0, b - a) for a, b in zip(counts0, counts1))
        return counts, max(0.0, sum1 - sum0), max(0.0, count1 - count0)

    def fraction_over(self, bound: float, window_s: float,
                      now: Optional[float] = None) -> Optional[float]:
        """Fraction of windowed observations above ``bound`` seconds.

        ``bound`` snaps to the nearest bucket boundary >= it (cumulative
        buckets can only answer at their own edges); None when the window
        saw no observations.
        """
        d = self._hist_delta(window_s, now)
        if d is None or d[2] <= 0:
            return None
        counts, _, count = d
        bounds = (self.buckets or ()) + (float("inf"),)
        i = bisect.bisect_left(list(self.buckets or ()), float(bound))
        under = sum(counts[: i + 1])
        del bounds
        return max(0.0, 1.0 - under / count)

    def quantile_over(self, q: float, window_s: float,
                      now: Optional[float] = None) -> Optional[float]:
        """Windowed quantile estimate by linear interpolation in-bucket."""
        d = self._hist_delta(window_s, now)
        if d is None or d[2] <= 0:
            return None
        counts, _, count = d
        bounds = list(self.buckets or ()) + [float("inf")]
        target = q * count
        cum = 0.0
        for i, n in enumerate(counts):
            prev_cum, cum = cum, cum + n
            if cum >= target and n > 0:
                lo = bounds[i - 1] if i else 0.0
                hi = bounds[i]
                if hi == float("inf"):
                    return lo  # unbounded bucket: best defensible answer
                return lo + (hi - lo) * (target - prev_cum) / n
        return bounds[-2] if len(bounds) > 1 else 0.0

    def to_dict(self) -> Dict[str, Any]:
        pts = self.points()
        if self.kind == "histogram":
            points = [[t, {"buckets": list(v[0]), "sum": v[1],
                           "count": v[2]}] for t, v in pts]
        else:
            points = [[t, float(v)] for t, v in pts]
        return {"name": self.name, "labels": dict(self.labels),
                "kind": self.kind, "points": points}


def _series_key(name: str, labelnames: Sequence[str],
                labelvalues: Sequence[str]) -> Tuple:
    return (name,) + tuple(zip(labelnames, labelvalues))


class TimeSeriesRecorder:
    """Periodic sampler turning a registry into bounded time series.

    ``registry`` is a :class:`MetricsRegistry` or a zero-arg callable
    returning one (resolved per sweep — fleet-merged sampling passes
    ``lambda: MetricsRegistry.merged(parts)``); None samples the
    process-wide default registry *live* (a ``set_default_registry``
    swap is picked up on the next sweep).
    """

    def __init__(
        self,
        registry: Union[MetricsRegistry, Callable[[], MetricsRegistry],
                        None] = None,
        *,
        interval_s: float = 1.0,
        capacity: int = 512,
        clock: Callable[[], float] = time.monotonic,
    ):
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        if interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {interval_s}")
        self._registry = registry
        self.interval_s = interval_s
        self.capacity = capacity
        self._clock = clock
        self._lock = threading.Lock()
        self._series: Dict[Tuple, Series] = {}
        self._sorted: Optional[List[Series]] = None  # series() cache
        self.n_sweeps = 0
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    def _resolve(self) -> MetricsRegistry:
        reg = self._registry
        if reg is None:
            return default_registry()
        if callable(reg):
            return reg()
        return reg

    # -- sampling ------------------------------------------------------------

    def sample(self, t: Optional[float] = None) -> int:
        """One sweep over the registry; returns points appended.

        Safe against concurrent registry mutation: ``families()`` /
        ``items()`` snapshot under the registry locks, and a family or
        child appearing mid-sweep simply starts its series on the next
        sweep it is seen in.
        """
        now = self._clock() if t is None else float(t)
        reg = self._resolve()
        appended = 0
        for fam in reg.families():
            for key, child in fam.items():
                skey = _series_key(fam.name, fam.labelnames, key)
                with self._lock:
                    series = self._series.get(skey)
                    if series is None:
                        series = self._series[skey] = Series(
                            fam.name, tuple(zip(fam.labelnames, key)),
                            fam.kind, self.capacity, buckets=fam.buckets)
                        self._sorted = None  # invalidate series() cache
                if fam.kind == "histogram":
                    with fam._lock:
                        value: Any = (tuple(float(c) for c in child.counts),
                                      float(child.sum), float(child.count))
                else:
                    value = float(child.value)
                if series.append(now, value):
                    appended += 1
        with self._lock:
            self.n_sweeps += 1
        return appended

    # -- lookup / export -----------------------------------------------------

    def series(self) -> List[Series]:
        # sorted once per series-set change, not per read: the burn-rate
        # engine reads this several times per evaluation tick
        with self._lock:
            if self._sorted is None:
                self._sorted = [self._series[k]
                                for k in sorted(self._series, key=repr)]
            return list(self._sorted)

    def get(self, name: str, **labels) -> Optional[Series]:
        fam_labels = tuple(sorted(labels.items()))
        with self._lock:
            for (sname, *skv), series in self._series.items():
                if sname == name and tuple(sorted(skv)) == fam_labels:
                    return series
        return None

    def to_json(self) -> Dict[str, Any]:
        return {
            "interval_s": self.interval_s,
            "capacity": self.capacity,
            "n_sweeps": self.n_sweeps,
            "series": [s.to_dict() for s in self.series()],
        }

    # -- background loop -----------------------------------------------------

    def start(self) -> "TimeSeriesRecorder":
        if self._thread is not None:
            raise RuntimeError("recorder already started")
        self._stop.clear()

        def loop() -> None:
            while not self._stop.wait(self.interval_s):
                self.sample()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="obs-timeseries")
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "TimeSeriesRecorder":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


# -- process-wide recorder (what the /timeseries endpoint serves) ------------

_recorder: Optional[TimeSeriesRecorder] = None
_recorder_lock = threading.Lock()


def set_default_recorder(
        recorder: Optional[TimeSeriesRecorder]
) -> Optional[TimeSeriesRecorder]:
    """Install the process-wide recorder; returns the previous one."""
    global _recorder
    with _recorder_lock:
        old, _recorder = _recorder, recorder
        return old


def get_default_recorder() -> Optional[TimeSeriesRecorder]:
    with _recorder_lock:
        return _recorder
