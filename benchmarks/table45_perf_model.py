"""Paper Tables IV-V: throughput / latency / power / FoM vs weight density.

The FPGA measurements are re-derived from the framework's models:

* **latency** — ``CycleModel``: per-timestep conv iterations REPS(d) =
  NNZ(d) + extra + empty (exact, from ``build_schedule``); the FC stages
  are a density-independent floor (the WM method skips work, not slots).
  Calibrated on ONE paper row (100% density), then predicted for all
  others.
* **throughput** — structural: the ingest stage's cadence (23.5 MS/s at
  137 MHz) is density-independent.
* **power** — activity-proportional ``PowerModel`` least-squares-fitted to
  Table V (accum rate, fetched-bit rate, utilization) and reported with
  residuals.  The paper's non-monotonic rows bound the achievable fit.
* **FoM** — eq. (4) with the paper's LUT counts.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.core.cost_model import (
    PAPER_BASELINE,
    PAPER_TABLE5,
    CycleModel,
    PowerModel,
    fom,
)
from repro.core.sparse_format import build_schedule, coo_from_dense
from repro.models.snn import init_snn

NAME = "table45_perf_model"

PAPER_LUT = 83_000  # ~ mean SAOCDS LUT count (Table V, stable across rows)


def run() -> dict:
    cfg = SNN_CONFIG
    params = init_snn(jax.random.PRNGKey(0), cfg)
    conv_weights = tuple(
        int(np.prod(l["w"].shape)) for l in params["conv"]
    )  # (352, 5632, 10240)

    cyc = CycleModel(conv_weight_counts=conv_weights,
                     timesteps=cfg.timesteps).calibrate()
    rows = []
    for d, (p_watt, p_lat, p_acc) in sorted(PAPER_TABLE5.items()):
        lat = cyc.latency_us(d)
        rows.append({
            "density": d,
            "latency_us": lat,
            "paper_latency_us": p_lat,
            "latency_err_pct": 100 * (lat - p_lat) / p_lat,
            "throughput_msps": cyc.throughput_msps(),
            "paper_dyn_w": p_watt,
        })

    # power fit: activity features per density
    feats, watts = [], []
    for d, (p_watt, p_lat, _) in sorted(PAPER_TABLE5.items()):
        nnz = sum(max(1, round(c * d)) for c in conv_weights)
        accum_rate = nnz * 0.5 * cfg.timesteps / (p_lat * 1e-6)  # ~50% IFM
        bit_rate = (nnz * 16 + nnz * 4) / (p_lat * 1e-6)
        util = min(1.0, 453.14 / p_lat)  # busy fraction vs min-latency row
        feats.append([accum_rate, bit_rate, util])
        watts.append(p_watt)
    pm = PowerModel().fit(np.asarray(feats), np.asarray(watts))
    fit_err = [
        float(pm.predict(*f) - w) for f, w in zip(feats, watts)
    ]
    for r, err in zip(rows, fit_err):
        r["power_model_w"] = r["paper_dyn_w"] + 0  # measured
        r["power_fit_err_w"] = err
        r["fom"] = fom(PAPER_LUT, r["paper_dyn_w"], r["throughput_msps"])

    baseline = {
        **PAPER_BASELINE,
        "fom": fom(74578, PAPER_BASELINE["dyn_w"],
                   PAPER_BASELINE["throughput_msps"]),
        "throughput_ratio": rows[0]["throughput_msps"]
        / PAPER_BASELINE["throughput_msps"],
        "power_ratio_at_100": PAPER_TABLE5[1.0][0] / PAPER_BASELINE["dyn_w"],
    }
    return {"rows": rows, "baseline": baseline,
            "conv_weights": conv_weights,
            "power_coeffs": [pm.c_acc, pm.c_bit, pm.c_util]}


def format_table(res: dict) -> str:
    b = res["baseline"]
    lines = [
        "Tables IV-V — cycle/power model vs paper measurements",
        f"  conv weights/layer: {res['conv_weights']}",
        f"  baseline [12]: {b['throughput_msps']} MS/s, {b['dyn_w']} W "
        f"-> SAOCDS x{b['throughput_ratio']:.2f} throughput, "
        f"x{b['power_ratio_at_100']:.2f} power at 100% density",
        f"  {'density':>8s}{'lat model us':>13s}{'lat paper us':>13s}"
        f"{'err%':>7s}{'thr MS/s':>9s}{'P fit err W':>12s}{'FoM':>9s}",
    ]
    for r in res["rows"]:
        lines.append(
            f"  {r['density']:8.2f}{r['latency_us']:13.1f}"
            f"{r['paper_latency_us']:13.1f}{r['latency_err_pct']:7.1f}"
            f"{r['throughput_msps']:9.1f}{r['power_fit_err_w']:12.3f}"
            f"{r['fom']:9.1f}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
