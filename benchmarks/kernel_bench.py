"""Pallas kernel microbench: GOAP block-sparse conv / WM-FC / fused LIF.

CPU wall times are *indicative only* (interpret mode executes the kernel
body in Python); the real claims are (a) allclose vs the jnp oracle at
every shape, and (b) the block-skip ratio — the fraction of (OC-tile x
row-block) tiles the static schedule drops, which is the on-TPU work
saving of the paper's sparsity-aware dataflow.

Also benches the whole network once per execution backend through the
unified ``SNNProgram`` graph (dense / goap / pallas), asserting that the
interchangeable backends produce identical logits.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import SNNConfig, compile_plan, compile_snn, init_snn
from repro.core.goap import conv1d_dense_oracle
from repro.core.lif import init_lif_params
from repro.core.sparse_format import block_sparse_from_dense
from repro.kernels.ops import goap_conv_op, lif_op, wm_fc_op
from repro.kernels.ref import lif_update_fused_ref, wm_fc_matmul_ref

NAME = "kernel_bench"


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []

    from repro.train.pruning import block_magnitude_masks

    # (shape, density, block_prune): block_prune=True uses the TPU
    # co-design tile-granular pruning — unstructured zeros never empty a
    # whole (8 x 32) tile, tile-pruned kernels skip proportionally
    for (kw, ic, oc, wi, dens, blockp) in [(11, 16, 32, 256, 0.15, False),
                                           (11, 16, 32, 256, 0.15, True),
                                           (5, 32, 64, 128, 0.5, True),
                                           (11, 2, 16, 128, 1.0, False)]:
        k = rng.normal(size=(kw, ic, oc)).astype(np.float32)
        if blockp:
            k = k * np.asarray(block_magnitude_masks(
                jnp.asarray(k), dens, block_oc=8, block_k=32))
        else:
            k = k * (rng.random((kw, ic, oc)) < dens)
        ifm = (rng.random((ic, wi)) < 0.5).astype(np.float32)
        bs = block_sparse_from_dense(k, block_oc=8, block_k=32)
        # goap_conv_op consumes the conv input *padded* for 'same' output
        pad = kw // 2
        padded = np.pad(ifm, ((0, 0), (pad, kw - 1 - pad)))
        out = goap_conv_op(jnp.asarray(padded), bs)
        ref = conv1d_dense_oracle(jnp.asarray(padded), jnp.asarray(k))
        err = float(jnp.abs(out - ref).max())
        kept = int(bs.n_tiles_per_row.sum())
        total = bs.n_oc_tiles * (bs.padded_k // bs.block_k)
        rows.append({
            "kernel": "goap_conv" + ("/tile-pruned" if blockp else ""),
            "shape": f"{kw}x{ic}x{oc}@{wi}",
            "density": dens, "max_err": err,
            "tiles_kept": kept, "tiles_total": total,
            "tile_skip_ratio": 1.0 - kept / max(1, total),
            "wall_ms": _time(lambda x: goap_conv_op(x, bs), jnp.asarray(padded)) * 1e3,
        })

    for (n_in, n_out, dens) in [(1024, 128, 0.15), (128, 11, 0.5)]:
        w = ((rng.random((n_in, n_out)) < dens)
             * rng.normal(size=(n_in, n_out))).astype(np.float32)
        s = (rng.random((8, n_in)) < 0.3).astype(np.float32)
        out = wm_fc_op(jnp.asarray(s), jnp.asarray(w))
        ref = wm_fc_matmul_ref(jnp.asarray(s), jnp.asarray(w))
        rows.append({
            "kernel": "wm_fc", "shape": f"{n_in}->{n_out}", "density": dens,
            "max_err": float(jnp.abs(out - ref).max()),
            "wall_ms": _time(
                lambda ss: wm_fc_op(ss, jnp.asarray(w)), jnp.asarray(s)) * 1e3,
        })

    t, n = 8, 2048
    cur = jnp.asarray(rng.normal(size=(t, n)).astype(np.float32))
    lif = init_lif_params((n,), 0.9, 1.0, 1.0)
    spk, vf = lif_op(cur, lif)
    rspk, rvf = lif_update_fused_ref(
        cur, jnp.zeros((n,)), jnp.broadcast_to(lif.alpha, (n,)),
        jnp.broadcast_to(lif.theta, (n,)), jnp.broadcast_to(lif.v_th, (n,)))
    rows.append({
        "kernel": "lif_fused", "shape": f"T{t}xN{n}",
        "max_err": float(jnp.abs(spk - rspk).max()
                         + jnp.abs(vf - rvf).max()),
        "wall_ms": _time(lambda c: lif_op(c, lif), cur) * 1e3,
    })

    # whole-network forward, one row per SNNProgram backend (reduced config
    # so the interpret-mode pallas path stays fast on CPU)
    from repro.train.pruning import make_mask_pytree

    cfg = SNNConfig(conv_specs=((5, 2, 8), (5, 8, 16)), pool=2,
                    fc_specs=((16 * 8, 32), (32, 11)), input_width=32,
                    timesteps=4)
    program = compile_snn(cfg)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    masks = make_mask_pytree(params, 0.25)
    frames = jnp.asarray((rng.random((cfg.timesteps, 2, cfg.input_width)) < 0.5)
                         .astype(np.float32))
    ref = program.apply(params, frames, "dense", masks=masks)
    for backend in ("dense", "goap", "pallas"):
        bound = compile_plan(program, params, masks=masks,
                             assignment=backend).bound
        out = bound(frames)
        rows.append({
            "kernel": f"program/{backend}",
            "shape": f"{len(cfg.conv_specs)}conv+{len(cfg.fc_specs)}fc",
            "max_err": float(jnp.abs(out - ref).max()),
            "wall_ms": _time(bound, frames) * 1e3,
        })
    return {"rows": rows}


def format_table(res: dict) -> str:
    lines = ["Kernel microbench (interpret mode; allclose vs jnp oracle)"]
    for r in res["rows"]:
        extra = ""
        if "tile_skip_ratio" in r:
            extra = (f"  tiles {r['tiles_kept']}/{r['tiles_total']} "
                     f"(skip {r['tile_skip_ratio'] * 100:.0f}%)")
        lines.append(f"  {r['kernel']:10s} {r['shape']:14s} "
                     f"err {r['max_err']:.2e}  {r['wall_ms']:7.1f} ms{extra}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
