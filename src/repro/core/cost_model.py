"""Fetch/accumulation counting, cycle and power models (paper Tables I-V).

Everything the paper measures on the FPGA is re-derived here analytically or
by exact event counting on real spike data:

* ``sw_conv_counts`` / ``goap_conv_counts``   — input fetches, weight
  fetches, gated accumulations for the sliding-window and GOAP dataflows
  (paper Table I; exact on the Fig. 3 example).
* ``fc_traditional_counts`` / ``fc_wm_counts`` — FC fetch/accumulate counts
  with and without the weight-mask method (paper §III-B, Fig. 2).
* ``bits_fetched``                             — 1-bit IFM vs 16-bit weight
  traffic (paper §III-C.2: 240 vs 1560 bits on the example).
* ``CycleModel``                               — streaming-pipeline latency /
  throughput vs density (paper Tables IV-V trends: constant throughput,
  latency ∝ density, FC-stage plateau at extreme sparsity).
* ``PowerModel``                               — activity-proportional
  dynamic power fitted to the paper's measurements.

The paper's FPGA measurements (Tables IV-V) are embedded as constants so the
benchmarks can report model-vs-paper errors.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from .sparse_format import CooKernel, WeightMask

__all__ = [
    "ConvCounts",
    "sw_conv_counts",
    "goap_conv_counts",
    "fc_traditional_counts",
    "fc_wm_counts",
    "bits_fetched",
    "CycleModel",
    "PowerModel",
    "PAPER_TABLE5",
    "PAPER_BASELINE",
    "fom",
]


@dataclasses.dataclass(frozen=True)
class ConvCounts:
    input_fetches: int
    weight_fetches: int
    accumulations: int

    def asdict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


def _as_2d_frames(ifm) -> np.ndarray:
    """Accept (IC, WI) or (T, IC, WI); return (T, IC, WI)."""
    a = np.asarray(ifm)
    if a.ndim == 2:
        a = a[None]
    if a.ndim != 3:
        raise ValueError(f"expected (T, IC, WI) or (IC, WI), got {a.shape}")
    return a


def sw_conv_counts(ifm, kernel_shape) -> ConvCounts:
    """Sliding-window counts (paper Table I, SW column).

    ifm: (T, IC, WI) pre-padded binary frames; kernel_shape: (KW, IC, OC).
    Per frame: every window fetches its KW*IC inputs once (shared across all
    output channels), fetches KW*IC weights *per output channel*, and
    accumulates once per non-zero input bit per output channel (SW exploits
    only temporal sparsity).
    """
    frames = _as_2d_frames(ifm)
    kw, ic, oc = kernel_shape
    t, ic2, wi = frames.shape
    assert ic2 == ic, (ic2, ic)
    oi = wi - kw + 1

    input_fetches = t * kw * ic * oi
    weight_fetches = t * kw * ic * oi * oc
    # per window: count of non-zero inputs inside it, summed over windows
    nz_per_window = 0
    for f in frames:
        window_view = np.lib.stride_tricks.sliding_window_view(f, kw, axis=1)
        nz_per_window += int(window_view.sum())
    accumulations = nz_per_window * oc
    return ConvCounts(input_fetches, weight_fetches, accumulations)


def goap_conv_counts(ifm, coo: CooKernel) -> ConvCounts:
    """GOAP counts (paper Table I, GOAP column).

    Each non-zero weight is fetched once; its enable map fetches OI inputs;
    it accumulates once per non-zero input bit inside its enable map
    (temporal AND spatial sparsity).
    """
    frames = _as_2d_frames(ifm)
    t, icn, wi = frames.shape
    oi = wi - coo.kw + 1

    input_fetches = t * coo.nnz * oi
    weight_fetches = t * coo.nnz
    ic_idx = coo.row_idx % coo.ic
    ci_idx = coo.col_idx
    accumulations = 0
    for f in frames:
        # EM of nnz n = f[ic_n, ci_n : ci_n + OI]
        for n in range(coo.nnz):
            accumulations += int(f[ic_idx[n], ci_idx[n] : ci_idx[n] + oi].sum())
    return ConvCounts(input_fetches, weight_fetches, accumulations)


def fc_traditional_counts(spikes, weights: np.ndarray) -> ConvCounts:
    """FC without weight masks: every active input fetches its full weight
    row; accumulation per fetched weight (zeros included)."""
    s = np.asarray(spikes).reshape(-1, weights.shape[0]).astype(bool)
    n_active = int(s.sum())
    out = weights.shape[1]
    return ConvCounts(
        input_fetches=int(s.size),
        weight_fetches=n_active * out,
        accumulations=n_active * out,
    )


def fc_wm_counts(spikes, wm: WeightMask) -> ConvCounts:
    """FC with the weight-mask method: FM = IFM AND WM selects fetches."""
    s = np.asarray(spikes).reshape(-1, wm.weights.shape[0]).astype(bool)
    fetches = int((s[:, :, None] & wm.mask[None]).sum())
    return ConvCounts(
        input_fetches=int(s.size),
        weight_fetches=fetches,
        accumulations=fetches,
    )


def bits_fetched(c: ConvCounts, input_bits: int = 1, weight_bits: int = 16) -> int:
    return c.input_fetches * input_bits + c.weight_fetches * weight_bits


# ---------------------------------------------------------------------------
# Cycle model (Tables IV-V): latency / throughput of the streaming pipeline.
# ---------------------------------------------------------------------------

# Paper Table V rows: density -> (dyn W, latency us, rel-accuracy %).
PAPER_TABLE5 = {
    1.00: (0.473, 3246.42, 100.0),
    0.75: (0.432, 2460.18, 99.98),
    0.50: (0.493, 1640.98, 99.51),
    0.25: (0.481, 822.10, 99.22),
    0.20: (0.541, 658.90, 99.17),
    0.15: (0.552, 497.94, 97.64),
    0.10: (0.473, 453.14, 93.33),
    0.05: (0.361, 453.14, 73.19),
}
# FINN-style baseline [12]: dyn power, latency, throughput.
PAPER_BASELINE = {"dyn_w": 1.146, "latency_us": 454.85, "throughput_msps": 11.45}
PAPER_FMAX_MHZ = 137.0
PAPER_THROUGHPUT_MSPS = 23.5


def fom(n_lut: float, dyn_power_w: float, throughput_msps: float) -> float:
    """Figure of merit, eq. (4): LUT * dyn_power / throughput  [uJ/S]."""
    return n_lut * dyn_power_w / throughput_msps


@dataclasses.dataclass
class CycleModel:
    """Latency/throughput model of the SAOCDS streaming pipeline.

    Per timestep, conv layer l executes ``REPS_l(d) = NNZ_l(d) + extra +
    empty`` iterations (one iteration per cpi_conv cycles: the enable-map
    accumulate across OI lanes is fully parallel, so iteration count is
    independent of OI).  The FC stages iterate over their input neurons
    regardless of sparsity (the WM method skips *work*, not *slots* — paper
    §V-C.2), so their latency is a density-independent floor.

    Per-frame latency = max(conv pipeline path, FC floor) + io fill;
    throughput is set by the input-ingestion initiation interval and is
    density-independent (23.5 MS/s at 137 MHz).
    """

    conv_weight_counts: tuple      # dense weight count per conv layer
    timesteps: int = 8
    fmax_mhz: float = PAPER_FMAX_MHZ
    cpi_conv: float = 1.0          # cycles per conv iteration (calibrated)
    fc_floor_us: float = PAPER_TABLE5[0.10][1]
    io_fill_us: float = 0.0

    def calibrate(self, density: float = 1.0, latency_us: float = PAPER_TABLE5[1.0][1]):
        """Fit cpi_conv so the model reproduces one measured latency row."""
        reps = sum(max(1, round(c * density)) for c in self.conv_weight_counts)
        cycles = reps * self.timesteps
        target_cycles = (latency_us - self.io_fill_us) * self.fmax_mhz
        self.cpi_conv = target_cycles / cycles
        return self

    def latency_us(self, density: float) -> float:
        reps = sum(max(1, round(c * density)) for c in self.conv_weight_counts)
        conv_us = reps * self.timesteps * self.cpi_conv / self.fmax_mhz
        return max(conv_us, self.fc_floor_us) + self.io_fill_us

    def throughput_msps(self) -> float:
        # structural: input stage ingests at a fixed cadence, so throughput
        # is density-independent (paper §V-C.2)
        return PAPER_THROUGHPUT_MSPS


@dataclasses.dataclass
class PowerModel:
    """Activity-proportional dynamic power.

    P_dyn = c_acc * (accum/s) + c_bit * (bits fetched/s) + c_util * util

    where util is the busy fraction of the conv pipeline (stalled stages do
    not switch).  Coefficients are least-squares fitted to the paper's
    Table V measurements by the calibration benchmark; the model then
    reports per-density predictions + errors.  The paper's non-monotonic
    rows (mixed-density utilization effects, §V-C.2) bound the achievable
    fit and are discussed in EXPERIMENTS.md.
    """

    c_acc: float = 0.0
    c_bit: float = 0.0
    c_util: float = 0.0

    def fit(self, rows: np.ndarray, powers: np.ndarray) -> "PowerModel":
        """rows: (n, 3) of (accum/s, bits/s, util); powers: (n,) watts."""
        coef, *_ = np.linalg.lstsq(rows, powers, rcond=None)
        self.c_acc, self.c_bit, self.c_util = (float(c) for c in coef)
        return self

    def predict(self, accum_rate: float, bit_rate: float, util: float) -> float:
        return self.c_acc * accum_rate + self.c_bit * bit_rate + self.c_util * util
