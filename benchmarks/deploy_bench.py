"""Deployment benchmark: hot-swap latency + p99 impact under sustained load.

Publishes two versions of the paper model into a throwaway
:class:`ModelRegistry` (warming the plan cache the way a real deploy
pipeline would), serves version 1 through the async tier under sustained
closed-loop load, hot-swaps to version 2 mid-stream, and records what the
lifecycle subsystem promises:

* **zero dropped/failed requests** across the swap (every future must
  resolve — a single failure fails the bench);
* **swap latency** — off-thread bind (plan compile + per-bucket warmup)
  vs the atomic flip + drain of the pre-flip backlog;
* **bounded p99 impact** — request p99 before / during / after the swap
  window, plus how many requests were in flight while it happened.

Run:  PYTHONPATH=src python benchmarks/deploy_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile
import threading
import time

import numpy as np

import jax

from repro.api import init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.deploy import ModelRegistry, hot_swap_from_registry
from repro.serve import AsyncAMCServeEngine
from repro.train.pruning import make_mask_pytree

NAME = "deploy_bench"

DENSITY = 0.5
MAX_BATCH = 64
MAX_DELAY_MS = 2.0


def _p99_ms(lat_s) -> float:
    return float(np.percentile(lat_s, 99.0)) * 1e3 if len(lat_s) else 0.0


def run(load_s: float = 2.0, pumpers: int = 4) -> dict:
    p1 = init_snn(jax.random.PRNGKey(0), CFG)
    m1 = make_mask_pytree(p1, DENSITY)
    p2 = init_snn(jax.random.PRNGKey(1), CFG)
    m2 = make_mask_pytree(p2, DENSITY)

    with tempfile.TemporaryDirectory() as root:
        registry = ModelRegistry(root)
        t0 = time.perf_counter()
        registry.publish("amc", p1, CFG, masks=m1, alias="production")
        registry.publish("amc", p2, CFG, masks=m2, alias="staging")
        publish_s = time.perf_counter() - t0

        loaded = registry.load("amc@production")
        engine = AsyncAMCServeEngine(
            loaded.params, CFG, masks=loaded.masks, backend="auto",
            max_batch=MAX_BATCH, max_delay_ms=MAX_DELAY_MS,
            version_label="amc@1")

        records = []          # (t_done, latency_s) per completed request
        failures = [0]
        stop = threading.Event()
        lock = threading.Lock()

        def pump(seed: int) -> None:
            rng = np.random.default_rng(seed)
            while not stop.is_set():
                iq = rng.normal(size=(2, CFG.input_width)).astype(np.float32)
                t_sub = time.perf_counter()
                try:
                    engine.submit(iq).result(timeout=60.0)
                except Exception:  # noqa: BLE001 — any failure is the story
                    with lock:
                        failures[0] += 1
                    continue
                t_done = time.perf_counter()
                with lock:
                    records.append((t_done, t_done - t_sub))

        threads = [threading.Thread(target=pump, args=(i,), daemon=True)
                   for i in range(pumpers)]
        for t in threads:
            t.start()

        time.sleep(load_s)                      # steady state on v1
        t_sw0 = time.perf_counter()
        report = hot_swap_from_registry(engine, registry, "amc@staging",
                                        backend=engine.backend)
        t_sw1 = time.perf_counter()
        time.sleep(load_s)                      # steady state on v2

        stop.set()
        for t in threads:
            t.join(timeout=30.0)
        stats = {k: v.summary() for k, v in engine.version_stats().items()}
        engine.close()

    before = [l for t, l in records if t < t_sw0]
    during = [l for t, l in records if t_sw0 <= t <= t_sw1]
    after = [l for t, l in records if t > t_sw1]
    p99_before, p99_after = _p99_ms(before), _p99_ms(after)
    return {
        "jax_backend": jax.default_backend(),
        "density": DENSITY,
        "max_batch": MAX_BATCH,
        "max_delay_ms": MAX_DELAY_MS,
        "pumpers": pumpers,
        "load_s_per_phase": load_s,
        "registry_publish_s": publish_s,
        "swap": report.summary(),
        "swap_window_s": t_sw1 - t_sw0,
        "requests": {"before": len(before), "during": len(during),
                     "after": len(after), "total": len(records)},
        "failed_requests": failures[0],
        "p99_ms": {"before": p99_before, "during": _p99_ms(during),
                   "after": p99_after},
        "p99_after_over_before": (p99_after / p99_before
                                  if p99_before else 0.0),
        "version_stats": stats,
    }


def format_table(res: dict) -> str:
    sw, p99, req = res["swap"], res["p99_ms"], res["requests"]
    lines = [
        f"Deploy bench: hot-swap under load ({res['pumpers']} closed-loop "
        f"pumpers, {res['load_s_per_phase']}s/phase, "
        f"{res['jax_backend']} backend)",
        f"  publish x2 (plan warmed): {res['registry_publish_s']:.2f}s",
        f"  swap {sw['old_label']} -> {sw['new_label']}: bind "
        f"{sw['bind_s']:.2f}s (off hot path), flip+drain "
        f"{sw['flip_s'] * 1e3:.1f}ms, {sw['queued_at_flip']} queued at "
        f"flip, drained={sw['drained']}",
        f"  requests: {req['total']} total, {req['during']} completed "
        f"inside the swap window, {res['failed_requests']} failed",
        f"  p99: before {p99['before']:.1f}ms  during "
        f"{p99['during']:.1f}ms  after {p99['after']:.1f}ms "
        f"(after/before {res['p99_after_over_before']:.2f}x)",
    ]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short phases for CI smoke runs")
    ap.add_argument("--load-s", type=float, default=None)
    ap.add_argument("--pumpers", type=int, default=4)
    ap.add_argument("--out", default="BENCH_deploy.json")
    args = ap.parse_args(argv)

    load_s = args.load_s if args.load_s else (0.8 if args.smoke else 2.0)
    res = run(load_s=load_s, pumpers=args.pumpers)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    if res["failed_requests"]:
        print(f"FAIL: {res['failed_requests']} requests failed during the "
              "swap — hot-swap must drop nothing")
        return 1
    if not res["swap"]["drained"]:
        print("FAIL: pre-flip backlog not drained in time")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
