"""Training driver: ``python -m repro.launch.train --arch <id>``.

Two modes:

* ``--arch saocds-amc`` — the paper's SNN classifier end-to-end (Σ-Δ
  encoded synthetic RadioML, surrogate-grad BPTT, optional pruning/LSQ,
  checkpointed + resumable).  This is the paper-faithful training path.
* ``--arch <assigned-lm-id>`` — any of the 10 assigned architectures at
  its ``--scale reduced`` (CPU-runnable) or ``--scale full`` config, on
  synthetic token streams, with AdamW + clipping + checkpoint/resume.
  On real hardware the same step runs under the production mesh via
  ``--mesh single|multi`` (CPU default: no mesh).

Fault tolerance: atomic keep-N checkpoints every ``--ckpt-every`` steps,
``--resume`` continues bitwise-identically (tests/test_train.py), and a
straggler monitor flags steps >3x the trailing median.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.data.pipeline import lm_token_batches
from repro.models.config import ArchConfig
from repro.models.lm import init_lm, lm_loss
from repro.models.whisper import init_whisper, whisper_loss
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import adamw, apply_updates, clip_by_global_norm

__all__ = ["LMTrainer", "main"]


class LMTrainer:
    """Synthetic-stream LM trainer for the assigned architectures."""

    def __init__(self, cfg: ArchConfig, *, lr: float = 3e-4, seed: int = 0,
                 batch: int = 8, seq: int = 64,
                 ckpt_dir: Optional[str] = None, keep: int = 3):
        self.cfg = cfg
        self.batch, self.seq = batch, seq
        key = jax.random.PRNGKey(seed)
        if cfg.family == "encdec":
            self.params = init_whisper(key, cfg, max_dec_pos=max(seq, 128))
        else:
            self.params = init_lm(key, cfg)
        self.opt_init, self.opt_update = adamw(lr, weight_decay=0.01)
        self.opt_state = self.opt_init(self.params)
        self.step = 0
        self.step_times: list = []
        self.stragglers: list = []
        self.ckpt = CheckpointManager(ckpt_dir, keep=keep) if ckpt_dir else None

        cfg_ = cfg

        def train_step(params, opt_state, tokens, labels, extra):
            def lf(p):
                if cfg_.family == "encdec":
                    return whisper_loss(p, extra, tokens, labels, cfg_)
                return lm_loss(p, tokens, labels, cfg_, patch_embeds=extra)

            loss, grads = jax.value_and_grad(lf)(params)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            updates, opt_state = self.opt_update(grads, opt_state, params)
            return apply_updates(params, updates), opt_state, loss, gnorm

        self._jit_step = jax.jit(train_step)

    def _extra(self, rng: np.random.Generator):
        if self.cfg.family == "vlm":
            return jnp.asarray(
                rng.normal(size=(self.batch, self.cfg.n_patches,
                                 self.cfg.d_model)).astype(np.float32) * 0.02,
                jnp.bfloat16)
        if self.cfg.family == "encdec":
            return jnp.asarray(
                rng.normal(size=(self.batch, self.seq, self.cfg.d_model)
                           ).astype(np.float32) * 0.02)
        return None

    def run(self, steps: int, log_every: int = 20,
            ckpt_every: int = 0) -> dict:
        history = {"step": [], "loss": []}
        gen = lm_token_batches(self.batch, self.seq, self.cfg.vocab,
                               seed=self.step + 1)
        rng = np.random.default_rng(17 + self.step)
        end = self.step + steps
        while self.step < end:
            t0 = time.perf_counter()
            tokens, labels = next(gen)
            self.params, self.opt_state, loss, gnorm = self._jit_step(
                self.params, self.opt_state,
                jnp.asarray(tokens), jnp.asarray(labels), self._extra(rng))
            self.step += 1
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            if len(self.step_times) >= 10:
                med = float(np.median(self.step_times[-50:]))
                if dt > 3.0 * med:
                    self.stragglers.append(self.step)
            if self.step % log_every == 0 or self.step == end:
                history["step"].append(self.step)
                history["loss"].append(float(loss))
                print(f"step {self.step:5d} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.3f} {dt * 1e3:.0f} ms")
            if self.ckpt and ckpt_every and self.step % ckpt_every == 0:
                self.save()
        if self.ckpt:
            self.save()
            self.ckpt.wait()
        return history

    # -- fault tolerance ----------------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state}

    def save(self):
        if self.ckpt:
            self.ckpt.save(self.step, self._state_tree(),
                           extra={"step": self.step})

    def resume(self) -> bool:
        if not self.ckpt or self.ckpt.latest_step() is None:
            return False
        tree, manifest = self.ckpt.restore(self._state_tree())
        self.params = tree["params"]
        self.opt_state = (type(self.opt_state)(*tree["opt"])
                          if isinstance(tree["opt"], tuple) else tree["opt"])
        self.step = int(manifest["extra"]["step"])
        return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True,
                    choices=list(ARCH_IDS) + ["saocds-amc"])
    ap.add_argument("--scale", default="reduced", choices=["reduced", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--density", type=float, default=None,
                    help="saocds-amc: target weight density (pruning)")
    ap.add_argument("--lsq", action="store_true",
                    help="saocds-amc: 16-bit LSQ quantization-aware training")
    args = ap.parse_args(argv)

    if args.arch == "saocds-amc":
        from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
        from repro.train.trainer import SNNTrainer, TrainerConfig

        tcfg = TrainerConfig(
            total_steps=args.steps, batch_size=args.batch, lr=args.lr,
            final_density=args.density, use_lsq=args.lsq,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        )
        trainer = SNNTrainer(SNN_CONFIG, tcfg)
        if args.resume and trainer.resume():
            print(f"resumed at step {trainer.step}")
        hist = trainer.run()
        acc = trainer.evaluate(snr_db=10.0)
        print(f"final loss {hist['loss'][-1]:.4f}  acc@10dB {acc:.3f}  "
              f"stragglers {len(trainer.stragglers)}")
        return 0

    cfg = get_config(args.arch) if args.scale == "full" else reduced_config(args.arch)
    trainer = LMTrainer(cfg, lr=args.lr, batch=args.batch, seq=args.seq,
                        ckpt_dir=args.ckpt_dir)
    if args.resume and trainer.resume():
        print(f"resumed at step {trainer.step}")
    hist = trainer.run(args.steps, ckpt_every=args.ckpt_every)
    print(f"final loss {hist['loss'][-1]:.4f}  stragglers "
          f"{len(trainer.stragglers)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
