"""The paper's inter-layer streaming as SPMD pipeline parallelism.

The SAOCDS accelerator instantiates each SNN layer as its own hardware
stage with activations streamed stage-to-stage (paper §III).  This example
maps that structure onto a JAX device mesh: a 4-stage ``spmd_pipeline``
(conv1 | conv2 | conv3 | FC head) where microbatches of spike frames flow
through ``ppermute`` handoffs on a fixed tick schedule — bubbles included
as explicit no-op slots, the paper's precomputed empty/extra iterations.

Every stage is built from the same shared ``LayerSpec`` graph that the
single-device forward executes (``compile_snn`` -> ``SNNProgram``): the
pipeline partitions the graph (``conv_block(i)`` / ``head_layers()``) and
runs each slice through the dense backend — no layer is re-implemented
here.

Needs >=4 devices, so it re-execs itself with
``xla_force_host_platform_device_count=4`` (CPU).

Run:  PYTHONPATH=src python examples/snn_pipeline.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import compile_snn, init_snn
from repro.compat import AxisType, make_mesh
from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.data.pipeline import sigma_delta_encode_np
from repro.data.radioml import generate_batch
from repro.distributed.pipeline import spmd_pipeline


def main():
    cfg = SNN_CONFIG
    program = compile_snn(cfg)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    mesh = make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))

    # heterogeneous stages share one fixed-width buffer — the software
    # analogue of the accelerator's fixed inter-layer stream width.
    # buffer: (T, C_max, W_max) with C_max=64, W_max=128
    t, cmax, wmax = cfg.timesteps, 64, cfg.input_width

    def conv_stage(li):
        # the (Conv1dLIF, MaxPool) slice of the shared layer graph
        block = program.conv_block(li)
        conv = block[0]
        w_in = cfg.input_width // (cfg.pool ** li)

        def fn(p, buf):   # buf (T, Cmax, Wmax)
            x = buf[:, : conv.ic, : w_in]
            out = program.run_layers(block, p, x)
            pad_c, pad_w = cmax - out.shape[1], wmax - out.shape[2]
            return jnp.pad(out, ((0, 0), (0, pad_c), (0, pad_w)))

        return fn

    def head_stage(p, buf):
        # FC1 -> FC2 -> readout slice of the same graph
        w_in = cfg.input_width // (cfg.pool ** len(cfg.conv_specs))
        x = buf[:, : cfg.conv_specs[-1][2], : w_in]
        logits = program.run_layers(program.head_layers(), p, x)
        out = jnp.zeros((t, cmax, wmax), jnp.float32)
        return out.at[0, 0, : cfg.n_classes].set(logits)

    stages = [conv_stage(0), conv_stage(1), conv_stage(2), head_stage]

    def stage_fn(stage_params, buf):
        idx = jax.lax.axis_index("stage")
        outs = [f(stage_params, buf) for f in stages]
        return jnp.select([idx == i for i in range(4)], outs)

    # data: 8 microbatches of one sample each
    iq, labels, _ = generate_batch(seed=7, batch=8, snr_db=10.0)
    frames = sigma_delta_encode_np(iq, t).astype(np.float32)  # (8, T, 2, 128)
    mbs = jnp.asarray(np.pad(
        frames, ((0, 0), (0, 0), (0, cmax - 2), (0, 0))))     # fixed buffer

    # every stage holds the FULL param tree here (stage_fn selects); a
    # stacked per-stage tree is the memory-lean option for big models
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (4,) + x.shape), params)

    out = spmd_pipeline(stage_fn, stacked, mbs, mesh, stage_axis="stage")
    pipe_logits = np.asarray(out[:, 0, 0, : cfg.n_classes])

    ref_logits = np.asarray(
        program.apply_batch(params, jnp.asarray(frames), "dense"))
    err = np.abs(pipe_logits - ref_logits).max()
    print(f"4-stage pipeline vs single-device forward: max err {err:.2e}")
    assert err < 1e-3
    print(f"ticks executed: {8 + 4 - 1} (8 microbatches + 3 bubble slots, "
          f"the paper's precomputed schedule)")
    print("predictions:", pipe_logits.argmax(-1), "labels:", labels)


if __name__ == "__main__":
    main()
