"""Trip-count-aware HLO cost model for the dry-run roofline.

``compiled.cost_analysis()`` visits each ``while`` body **once**, but every
assigned architecture scans over its layer stack (and attention scans over
query chunks), so XLA's numbers under-count FLOPs/bytes by the trip count.
This module parses the *optimized* HLO text and computes:

* ``dot_flops``   — 2*M*N*K per dot/convolution, recursively descending
  into while bodies multiplied by their trip count (extracted from the
  loop-condition ``compare(counter, constant)`` pattern jax scans lower
  to), and into call/fusion computations.
* ``bytes``       — per-instruction operand+result bytes at **fusion
  granularity** (a fusion is one kernel: its operands/result are the HBM
  traffic), again trip-count aware.  Bookkeeping ops (tuple plumbing,
  parameters, constants, bitcasts) are free.
* ``collectives`` — per-type counts and bytes for all-gather/all-reduce/
  reduce-scatter/all-to-all/collective-permute, with both the raw result
  bytes and a ring-model "wire bytes" estimate using the parsed replica
  group size g:  AG: r*(g-1)/g,  AR: 2*r*(g-1)/g,  RS: r*(g-1),
  A2A: r*(g-1)/g,  CP: r  (r = result bytes).

All numbers are **per device** (the SPMD module is the per-device
program); multiply by chip count for global totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloAnalysis", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.*\{\s*$")
_CALLSITE_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)="
                          r"(?:\{([^}]*)\}|%?([\w\.\-]+))")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")

_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "call",
    "conditional", "custom-call", "reshape", "get-dimension-size",
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str            # operand list + attributes (tail of the line)


@dataclasses.dataclass
class CollectiveStat:
    count: int = 0
    result_bytes: float = 0.0
    wire_bytes: float = 0.0


@dataclasses.dataclass
class HloAnalysis:
    dot_flops: float
    bytes_accessed: float
    collectives: Dict[str, CollectiveStat]
    warnings: List[str]
    byte_contrib: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(c.result_bytes for c in self.collectives.values())

    @property
    def wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives.values())

    def summary(self) -> Dict:
        return {
            "dot_flops": self.dot_flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "wire_bytes": self.wire_bytes,
            "collectives": {
                k: dataclasses.asdict(v) for k, v in self.collectives.items()
            },
            "warnings": self.warnings[:20],
        }


def _type_bytes(type_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


def _split_top(s: str) -> List[str]:
    """Split an operand list on top-level commas."""
    parts, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
            if depth < 0:
                break
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur).strip())
    return parts


def _parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        if current is None:
            m = _COMP_RE.match(line)
            if m:
                current = m.group(1)
                comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, rtype, op, rest = m.groups()
            comps[current].append(Instr(name, rtype, op, rest))
    return comps


class _Analyzer:
    def __init__(self, comps: Dict[str, List[Instr]]):
        self.comps = comps
        self.warnings: List[str] = []
        self.collectives: Dict[str, CollectiveStat] = {}
        self._trip_cache: Dict[str, int] = {}
        self.byte_contrib: Dict[str, float] = {}   # trip-weighted, by shape
        self._sym: Dict[str, Dict[str, str]] = {
            cname: {i.name: i.result_type for i in instrs}
            for cname, instrs in comps.items()
        }

    # -- helpers ------------------------------------------------------------

    def _operands(self, instr: Instr, cname: str) -> List[Optional[Tuple[str, List[int]]]]:
        """Operand (dtype, dims) list; resolves bare %names via symbol table."""
        # operand text = up to the matching close paren of the op's '('
        ops_txt = _split_top(instr.rest)
        out = []
        for o in ops_txt:
            o = o.strip()
            if not o:
                continue
            sd = _shape_dims(o)
            if sd is None:
                ref = o.lstrip("%").split(" ")[-1].lstrip("%")
                t = self._sym.get(cname, {}).get(ref)
                sd = _shape_dims(t) if t else None
            out.append(sd)
        return out

    def trip_count(self, cond_name: str) -> int:
        if cond_name in self._trip_cache:
            return self._trip_cache[cond_name]
        trip = 1
        instrs = self.comps.get(cond_name, [])
        consts = []
        for i in instrs:
            m = _CONST_RE.search(f"= {i.result_type} {i.op}({i.rest}")
            if i.op == "constant" and i.result_type.startswith("s32[]"):
                mc = re.search(r"constant\((\d+)\)", "constant(" + i.rest)
                if mc:
                    consts.append(int(mc.group(1)))
        if consts:
            trip = max(consts)
        else:
            self.warnings.append(f"no trip count for {cond_name}; assuming 1")
        self._trip_cache[cond_name] = trip
        return trip

    def _called(self, instr: Instr) -> List[str]:
        names = []
        for m in _CALLSITE_RE.finditer(instr.rest):
            if m.group(1) is not None:
                names += [x.strip().lstrip("%") for x in m.group(1).split(",")]
            else:
                names.append(m.group(2))
        return [n for n in names if n in self.comps]

    # -- recursive cost -----------------------------------------------------

    def flops(self, cname: str, mult: float = 1.0, _depth=0) -> float:
        if _depth > 50:
            return 0.0
        total = 0.0
        for instr in self.comps.get(cname, []):
            if instr.op in ("dot", "convolution"):
                res = _shape_dims(instr.result_type)
                opnds = self._operands(instr, cname)
                k = 1
                mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
                if res and opnds and opnds[0] and mdims:
                    lhs_dims = opnds[0][1]
                    for ci in mdims.group(1).split(","):
                        if ci:
                            k *= lhs_dims[int(ci)]
                elif instr.op == "convolution" and opnds and len(opnds) > 1 and opnds[1]:
                    # rhs = kernel: spatial*input-feature contraction
                    k = 1
                    for d in opnds[1][1][:-1]:
                        k *= d
                n_out = 1
                if res:
                    for d in res[1]:
                        n_out *= d
                total += 2.0 * n_out * k
            elif instr.op == "while":
                called = dict(
                    body=None, condition=None
                )
                mb = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", instr.rest)
                trips = self.trip_count(mc.group(1)) if mc else 1
                if mb and mb.group(1) in self.comps:
                    total += self.flops(mb.group(1), trips, _depth + 1)
            elif instr.op in ("fusion", "call", "conditional", "reduce",
                              "scatter", "sort", "map", "reduce-window",
                              "select-and-scatter", "custom-call"):
                for sub in self._called(instr):
                    total += self.flops(sub, 1.0, _depth + 1)
        return total * mult

    def bytes_(self, cname: str, mult: float = 1.0, _depth=0) -> float:
        if _depth > 50:
            return 0.0
        total = 0.0
        for instr in self.comps.get(cname, []):
            if instr.op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", instr.rest)
                trips = self.trip_count(mc.group(1)) if mc else 1
                if mb and mb.group(1) in self.comps:
                    total += self.bytes_(mb.group(1), trips, _depth + 1)
                continue
            if instr.op in ("call", "conditional"):
                for sub in self._called(instr):
                    total += self.bytes_(sub, 1.0, _depth + 1)
                continue
            if instr.op.rstrip("-start").rstrip("-done") in _COLLECTIVES or \
               instr.op in _COLLECTIVES:
                continue  # network, tracked separately
            if instr.op in _SKIP_BYTES and instr.op != "custom-call":
                continue
            # fusion and any remaining compute op: operands + result
            res_b = _type_bytes(instr.result_type)
            opnd_b = 0.0
            for sd in self._operands(instr, cname):
                if sd:
                    n = 1
                    for d in sd[1]:
                        n *= d
                    opnd_b += n * DTYPE_BYTES.get(sd[0], 0)
            total += res_b + opnd_b
            key = re.sub(r"\{[^}]*\}", "", instr.result_type)[:80]
            self.byte_contrib[key] = self.byte_contrib.get(key, 0.0) + \
                (res_b + opnd_b) * mult
        return total * mult

    def collect(self, cname: str, mult: float = 1.0, _depth=0) -> None:
        if _depth > 50:
            return
        for instr in self.comps.get(cname, []):
            base_op = instr.op
            if base_op.endswith("-done"):
                continue
            stripped = base_op[:-6] if base_op.endswith("-start") else base_op
            if stripped in _COLLECTIVES:
                r = _type_bytes(instr.result_type)
                if base_op.endswith("-start"):
                    r = r / 2.0  # start tuples carry (src, dst) buffers
                g = self._group_size(instr)
                wire = {
                    "all-gather": r * (g - 1) / max(1, g),
                    "all-reduce": 2.0 * r * (g - 1) / max(1, g),
                    "reduce-scatter": r * (g - 1),
                    "all-to-all": r * (g - 1) / max(1, g),
                    "collective-permute": r,
                }[stripped]
                st = self.collectives.setdefault(stripped, CollectiveStat())
                st.count += int(mult)
                st.result_bytes += r * mult
                st.wire_bytes += wire * mult
            elif base_op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", instr.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", instr.rest)
                trips = self.trip_count(mc.group(1)) if mc else 1
                if mb and mb.group(1) in self.comps:
                    self.collect(mb.group(1), mult * trips, _depth + 1)
            elif base_op in ("call", "conditional", "fusion"):
                for sub in self._called(instr):
                    self.collect(sub, mult, _depth + 1)

    def _group_size(self, instr: Instr) -> int:
        m = _GROUPS_IOTA_RE.search(instr.rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(instr.rest)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip() != ""])
        if "collective-permute" in instr.op:
            return 2
        self.warnings.append(f"no replica_groups on {instr.name}")
        return 1


def _entry_name(text: str) -> Optional[str]:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_RE.match(line)
            if m:
                return m.group(1)
    return None


def analyze_hlo(text: str) -> HloAnalysis:
    comps = _parse_computations(text)
    entry = _entry_name(text)
    if entry is None:
        return HloAnalysis(0.0, 0.0, {}, ["no ENTRY computation found"])
    a = _Analyzer(comps)
    flops = a.flops(entry)
    nbytes = a.bytes_(entry)
    a.collect(entry)
    top = dict(sorted(a.byte_contrib.items(), key=lambda kv: -kv[1])[:25])
    return HloAnalysis(flops, nbytes, a.collectives, a.warnings, top)
