"""Gated one-to-all product (GOAP) convolution (paper §III-C).

Convention (matches paper Fig. 3): the input feature map is **pre-padded**,
I: (IC, WI) binary; the kernel is (KW, IC, OC); valid convolution gives
O: (OC, OI) with OI = WI - KW + 1, stride 1 (the paper's RF signals are 1-D,
H = 1 everywhere).

Four implementations, all equal to the dense oracle:

* ``conv1d_dense_oracle``  — im2col matmul, the mathematical ground truth
  and the sliding-window (SW) baseline compute.
* ``goap_conv_packed``     — the serving hot path: COO pre-sorted by output
  channel and packed into a padded (OC, S) layout at plan-compile time
  (:func:`goap_pack`), so the whole timestep lowers to one gather + one
  fused contraction (no ``segment_sum`` scatter dispatch).
* ``goap_conv_nnz``        — vectorized weight-priority iteration: every
  non-zero weight w@(oc, ic, ci) contributes ``w * I[ic, ci:ci+OI]`` to
  output row oc (its *enable map*); gathered + segment-summed, jittable.
* ``goap_conv_reference``  — Algorithm-1 emulation in numpy (tests only);
  vectorized behind a cached index table, bit-identical to the literal
  double loop (``goap_conv_reference_loop``).

``build_shift_buffer`` produces the binary shifted-input matrix
X'(IC*KW, OI) with X'[ic*KW + ci, oi] = I[ic, oi + ci]; dense conv is then
``W'(OC, IC*KW) @ X'`` which is the layout the TPU block-sparse kernel uses.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from .sparse_format import CooKernel

__all__ = [
    "conv1d_dense_oracle",
    "build_shift_buffer",
    "PackedCoo",
    "goap_pack",
    "goap_conv_packed",
    "goap_conv_nnz",
    "goap_conv_reference",
    "goap_conv_reference_loop",
]


def build_shift_buffer(ifm: jax.Array, kw: int) -> jax.Array:
    """(IC, WI) -> X'(IC*KW, OI): row ic*KW+ci holds I[ic] shifted by ci."""
    ic, wi = ifm.shape
    oi = wi - kw + 1
    if oi <= 0:
        raise ValueError(f"input width {wi} < kernel width {kw}")
    # windows[ci, oi] = I[:, oi + ci]
    idx = jnp.arange(kw)[:, None] + jnp.arange(oi)[None, :]  # (KW, OI)
    shifted = ifm[:, idx]  # (IC, KW, OI)
    return shifted.reshape(ic * kw, oi)


def conv1d_dense_oracle(ifm: jax.Array, kernel: jax.Array) -> jax.Array:
    """Dense valid 1-D conv: (IC, WI) x (KW, IC, OC) -> (OC, OI)."""
    kw, ic, oc = kernel.shape
    x = build_shift_buffer(ifm, kw)                     # (IC*KW, OI)
    w = jnp.transpose(kernel, (2, 1, 0)).reshape(oc, ic * kw)  # W'
    return w @ x.astype(w.dtype)


# ---------------------------------------------------------------------------
# Packed per-output-channel layout (the serving hot path).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PackedCoo:
    """COO kernel re-packed per output channel for one-op execution.

    Row ``oc`` of ``w_pad``/``row_pad`` holds that channel's non-zero
    weights in the COO streaming order, padded to S = max per-channel nnz
    with **zero weights pointing at shift-buffer row 0** — a no-op
    contribution, the same static-schedule trick the accelerator (extra/
    empty iterations) and the block-sparse TPU layout use.  The whole
    timestep is then ``einsum('os,osk->ok', w_pad, X'[row_pad])``: one
    gather + one fused contraction, no data-dependent scatter.
    """

    w_pad: np.ndarray    # (OC, S) float32 weights, zero padded
    row_pad: np.ndarray  # (OC, S) int32 rows into X' (= ic*KW + ci)
    kw: int
    ic: int
    oc: int

    @property
    def s(self) -> int:
        return int(self.w_pad.shape[1])


def goap_pack(coo: CooKernel) -> PackedCoo:
    """Pack an (oc-major sorted) COO kernel into the padded (OC, S) layout."""
    oc_idx = (coo.row_idx // coo.ic).astype(np.int64)
    ic_idx = (coo.row_idx % coo.ic).astype(np.int64)
    counts = np.bincount(oc_idx, minlength=coo.oc) if coo.nnz else \
        np.zeros(coo.oc, dtype=np.int64)
    s = max(1, int(counts.max()) if counts.size else 1)
    w_pad = np.zeros((coo.oc, s), dtype=np.float32)
    row_pad = np.zeros((coo.oc, s), dtype=np.int32)
    if coo.nnz:
        if np.any(np.diff(oc_idx) < 0):
            raise ValueError("COO kernel is not sorted output-channel-major")
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        slot = np.arange(coo.nnz) - starts[oc_idx]   # position within its oc
        w_pad[oc_idx, slot] = coo.data.astype(np.float32)
        row_pad[oc_idx, slot] = ic_idx * coo.kw + coo.col_idx
    return PackedCoo(w_pad=w_pad, row_pad=row_pad,
                     kw=coo.kw, ic=coo.ic, oc=coo.oc)


def goap_conv_packed(ifm: jax.Array, pack: PackedCoo) -> jax.Array:
    """GOAP conv through the packed layout: one gather, one contraction.

    Equivalent to :func:`goap_conv_nnz` (same enable-map sums, padded
    zero-weight slots contribute exactly +0.0) but lowers to a single
    fused dot instead of gather -> ``segment_sum`` scatter dispatch —
    the XLA:CPU scatter path is what made the goap backend ~14x slower
    than dense.
    """
    x = build_shift_buffer(ifm, pack.kw).astype(jnp.float32)  # (IC*KW, OI)
    ems = x[jnp.asarray(pack.row_pad)]                        # (OC, S, OI)
    return jnp.einsum("os,osk->ok", jnp.asarray(pack.w_pad), ems)


def goap_conv_nnz(ifm: jax.Array, coo: CooKernel) -> jax.Array:
    """Vectorized GOAP: iterate non-zero weights, accumulate enable maps.

    Faithful to the paper's dataflow: for each nnz weight, fetch its EM
    (OI contiguous binary inputs starting at its kernel column) and add
    ``w * EM`` into output row oc.  Gating by the binary input is the
    multiplication by {0,1}.
    """
    kw = coo.kw
    icn = coo.ic
    _, wi = ifm.shape
    oi = wi - kw + 1
    if coo.nnz == 0:
        return jnp.zeros((coo.oc, oi), dtype=jnp.result_type(jnp.float32))

    w = jnp.asarray(coo.data, dtype=jnp.float32)        # (nnz,)
    oc_idx = jnp.asarray(coo.row_idx // icn)            # (nnz,)
    ic_idx = jnp.asarray(coo.row_idx % icn)             # (nnz,)
    ci_idx = jnp.asarray(coo.col_idx)                   # (nnz,)

    # EM gather: ems[n, oi] = I[ic_n, oi + ci_n]
    cols = ci_idx[:, None] + jnp.arange(oi)[None, :]    # (nnz, OI)
    ems = ifm[ic_idx[:, None], cols].astype(jnp.float32)
    contrib = w[:, None] * ems                          # (nnz, OI)
    return jax.ops.segment_sum(contrib, oc_idx, num_segments=coo.oc)


# ---------------------------------------------------------------------------
# Algorithm-1 reference (tests only).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _reference_index_table(row_bytes: bytes, col_bytes: bytes, nnz: int,
                           kw: int, ic: int, wi: int):
    """Cached gather table for the vectorized reference emulator.

    Keyed on the COO index bytes so repeated property-test calls on the
    same kernel (hypothesis shrinking, parametrized sweeps) skip the
    table derivation entirely.
    """
    row_idx = np.frombuffer(row_bytes, dtype=np.int32)
    col_idx = np.frombuffer(col_bytes, dtype=np.int32)
    oi = wi - kw + 1
    oc_idx = (row_idx // ic).astype(np.int64)
    ic_idx = (row_idx % ic).astype(np.int64)
    # flat[n, o] indexes ifm.ravel() at (ic_n, o + ci_n)
    flat = (ic_idx[:, None] * wi
            + col_idx[:, None].astype(np.int64)
            + np.arange(oi, dtype=np.int64)[None, :])
    return oc_idx, flat


def goap_conv_reference(ifm: np.ndarray, coo: CooKernel) -> np.ndarray:
    """Algorithm-1 emulation, vectorized (numpy; tests/small shapes).

    Bit-identical to :func:`goap_conv_reference_loop`: ``np.add.at``
    applies contributions sequentially in COO order, so every (oc, o)
    accumulator sees the exact same float64 addition sequence as the
    literal loop (gated-off positions add +0.0, an exact identity).
    """
    icn, wi = ifm.shape
    oi = wi - coo.kw + 1
    out = np.zeros((coo.oc, oi), dtype=np.float64)
    if coo.nnz == 0:
        return out
    oc_idx, flat = _reference_index_table(
        np.ascontiguousarray(coo.row_idx, dtype=np.int32).tobytes(),
        np.ascontiguousarray(coo.col_idx, dtype=np.int32).tobytes(),
        coo.nnz, coo.kw, icn, wi)
    gate = (np.asarray(ifm).ravel()[flat] != 0)          # (nnz, OI)
    contrib = coo.data.astype(np.float64)[:, None] * gate
    np.add.at(out, oc_idx, contrib)
    return out


def goap_conv_reference_loop(ifm: np.ndarray, coo: CooKernel) -> np.ndarray:
    """Literal Algorithm-1 double loop (the original reference; kept as
    the bit-equality oracle for the vectorized emulator above)."""
    icn, wi = ifm.shape
    oi = wi - coo.kw + 1
    out = np.zeros((coo.oc, oi), dtype=np.float64)
    for n in range(coo.nnz):
        oc = int(coo.row_idx[n]) // icn
        ic = int(coo.row_idx[n]) % icn
        ci = int(coo.col_idx[n])
        w = float(coo.data[n])
        for o in range(oi):              # enable-map iteration
            if ifm[ic, o + ci] != 0:     # temporal-sparsity gate
                out[oc, o] += w
    return out
