"""Robustness benchmark: clean-vs-impaired accuracy + throughput, 4 backends.

Two questions the channel subsystem makes answerable:

* **accuracy** — what does each execution backend score on clean
  (legacy-channel) frames vs frames run through the scenario suite's
  channels, per SNR?  All four backends must agree on the impaired frames
  (max |dlogit| <= 1e-5) — sparsity-aware execution must not interact with
  channel conditions.
* **throughput** — what does running the channel *inside* the jitted step
  cost?  Per backend: frames/s for the bare Σ-Δ encode + forward vs the
  same step with ``apply_scenario`` fused in front (the serving-tier
  drift-injection path), plus the standalone channel application rate.

Run:  PYTHONPATH=src python benchmarks/robustness_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import init_snn
from repro.channel import scenario_fn, suite_scenarios
from repro.configs.saocds_amc import CONFIG as CFG
from repro.data.pipeline import sigma_delta_encode_batch
from repro.data.radioml import generate_batch
from repro.eval import RobustnessConfig, evaluate_robustness
from repro.models.graph import compile_snn
from repro.plan import compile_plan
from repro.train.pruning import make_mask_pytree

NAME = "robustness_bench"

BACKENDS = ("dense", "goap", "pallas", "stream")
DENSITY = 0.5


def _time_fn(fn, x, reps: int) -> float:
    jax.block_until_ready(fn(x))  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(x))
    return (time.perf_counter() - t0) / reps


def run(smoke: bool = False) -> dict:
    # sizes are bounded by the pallas interpret-mode path (~3 frames/s on a
    # CI-class CPU): full mode stays in the single-digit-minutes range
    frames_per_cell = 16 if smoke else 32
    snr_grid = (0.0, 10.0) if smoke else (-10.0, 0.0, 10.0)
    thr_batch = 32 if smoke else 64
    reps = 2 if smoke else 3

    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)

    # -- accuracy sweep (clean reference + quick scenario pair, 4 backends)
    eval_cfg = RobustnessConfig(
        suite="quick", snr_grid=snr_grid, frames_per_cell=frames_per_cell,
        backends=BACKENDS, seed=0)
    report = evaluate_robustness(params, CFG, eval_cfg, masks=masks)

    # -- throughput: bare step vs channel-fused step, per backend ----------
    program = compile_snn(CFG)
    scen = suite_scenarios("quick")[-1]          # doppler_drift
    sfn = scenario_fn(scen)
    iq, _, snrs = generate_batch(1, thr_batch, snr_db=10.0,
                                 frame_len=CFG.input_width,
                                 apply_channel=False)
    x = jnp.asarray(iq)
    snrs_j = jnp.asarray(snrs)
    key = jax.random.PRNGKey(0)

    throughput = {}
    for backend in BACKENDS:
        plan = compile_plan(program, params, masks=masks, assignment=backend)

        def bare(iq_b, p=plan):
            return p.bound.batch(sigma_delta_encode_batch(iq_b,
                                                          CFG.timesteps))

        def fused(iq_b, p=plan):
            imp = sfn(iq_b, snrs_j, key)
            return p.bound.batch(sigma_delta_encode_batch(imp,
                                                          CFG.timesteps))

        t_bare = _time_fn(jax.jit(bare), x, reps)
        t_fused = _time_fn(jax.jit(fused), x, reps)
        throughput[backend] = {
            "clean_fps": thr_batch / t_bare,
            "impaired_fps": thr_batch / t_fused,
            "channel_overhead": t_fused / t_bare - 1.0,
        }
    t_chan = _time_fn(lambda b: sfn(b, snrs_j, key), x, reps)

    primary = BACKENDS[0]
    clean_acc = {b: float(np.mean([c["accuracy"][b]
                                   for c in report["clean"].values()]))
                 for b in BACKENDS}
    impaired_acc = {b: float(np.mean(
        [cell["accuracy"][b]
         for s in report["scenarios"].values()
         for cell in s["per_snr"].values()]))
        for b in BACKENDS}

    return {
        "jax_backend": jax.default_backend(),
        "smoke": smoke,
        "density": DENSITY,
        "frames_per_cell": frames_per_cell,
        "snr_grid": list(snr_grid),
        "scenarios": report["config"]["scenarios"],
        "throughput_batch": thr_batch,
        "throughput_scenario": scen.name,
        "surface": report["surface"],
        "clean_accuracy_mean": clean_acc,
        "impaired_accuracy_mean": impaired_acc,
        "agreement": report["agreement"],
        "throughput": throughput,
        "channel_apply_fps": thr_batch / t_chan,
        "primary_backend": primary,
        "eval_wall_s": report["wall_s_by_backend"],
    }


def format_table(res: dict) -> str:
    ag = res["agreement"]
    lines = [
        f"Robustness bench ({res['jax_backend']} backend, "
        f"{res['frames_per_cell']} frames/cell, scenarios "
        f"{res['scenarios']}, SNRs {res['snr_grid']})",
        f"  cross-backend agreement on impaired frames: max |dlogit| = "
        f"{ag['max_abs_logit_diff']:.2e} "
        f"({'OK' if ag['agrees'] else 'DISAGREES'})",
        "  backend     acc(clean)  acc(impaired)   clean fps  impaired fps"
        "  chan overhead",
    ]
    for b in res["throughput"]:
        t = res["throughput"][b]
        lines.append(
            f"  {b:<11s}{res['clean_accuracy_mean'][b]:>9.3f}"
            f"{res['impaired_accuracy_mean'][b]:>14.3f}"
            f"{t['clean_fps']:>12.0f}{t['impaired_fps']:>14.0f}"
            f"{t['channel_overhead']:>13.1%}")
    lines.append(f"  standalone channel application: "
                 f"{res['channel_apply_fps']:.0f} frames/s "
                 f"({res['throughput_scenario']})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced cells/reps for CI smoke runs")
    ap.add_argument("--out", default="BENCH_robustness.json")
    args = ap.parse_args(argv)

    res = run(smoke=args.smoke)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    if not res["agreement"]["agrees"]:
        print("FAIL: backends disagree on impaired frames")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
