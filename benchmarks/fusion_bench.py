"""Fusion benchmark: fused streaming executor vs layer-by-layer execution,
plus cold/memory/disk plan-compile cost, on the paper config.

Two questions, answered with wall-clock numbers in ``BENCH_fusion.json``:

* **Execution** — does the fused streaming path (``ExecutionPlan.batch``:
  one ``lax.scan`` over timesteps, or — for the ``pallas_fused``
  assignment — one multi-layer Pallas kernel launch with all LIF state in
  VMEM) beat the layer-by-layer path (``plan.bound.batch``) that
  materializes every intermediate (T, C, W) sequence?  Measured across
  **all registered backends** on the paper config at 50% density; the two
  paths are also asserted allclose, and each row carries its achieved
  fraction of the analytic streaming-roofline target
  (``repro.launch.roofline.streaming_roofline``).
* **Compilation** — what does ``compile_plan`` cost cold (artifacts
  derived from weights), warm in memory (same process rebind: trainer
  eval loops), and warm from disk (process restart: serve redeploys)?
  The artifact build counter is recorded alongside so "cached" provably
  means "nothing rebuilt".

``benchmarks/run.py --check-regression`` diffs a fresh run of this module
against the committed ``BENCH_fusion.json`` and fails on >20% drops in
``fused_speedup`` or layered fps — the perf-gate CI job.

Run:  PYTHONPATH=src python benchmarks/fusion_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import compile_plan, compile_snn, init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.launch.roofline import streaming_roofline
from repro.models.graph import artifact_build_count
from repro.plan import PlanCache
from repro.train.pruning import make_mask_pytree

NAME = "fusion_bench"

DENSITY = 0.5
# Every registered execution backend.  Interpret-mode Pallas and the
# Algorithm-2 schedule interpreter are orders of magnitude slower per
# sample on CPU, so each backend gets a batch cap that keeps the sweep
# under a CPU-minute while still timing steady state.
EXEC_BACKENDS = ("dense", "goap", "pallas", "stream", "fixed",
                 "pallas_fused")
_BATCH_CAP = {"pallas": 2, "stream": 4, "pallas_fused": 8}
_INTERPRET_BACKENDS = ("pallas", "pallas_fused")


def _spike_frames(batch: int) -> jnp.ndarray:
    rng = np.random.default_rng(0)
    shape = (batch, CFG.timesteps, CFG.conv_specs[0][1], CFG.input_width)
    return jnp.asarray((rng.random(shape) < 0.5).astype(np.float32))


def _time(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm up
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def _backend_plan_and_frames(program, params, masks, backend: str,
                             batch: int):
    """(plan, frames) for one backend — the fixed backend binds with its
    LSQ quant_fn and consumes integer-encoded frames."""
    if backend == "fixed":
        from repro.data.radioml import generate_batch
        from repro.fixed import FixedQuantFn, fixed_encode_batch
        from repro.train.lsq import init_lsq_scales

        scales = init_lsq_scales(params, 16)
        plan = compile_plan(program, params, masks=masks,
                            quant_fn=FixedQuantFn(scales, bits=16),
                            assignment="fixed")
        iq, _, _ = generate_batch(0, batch, snr_db=10.0,
                                  frame_len=CFG.input_width)
        return plan, fixed_encode_batch(jnp.asarray(iq, jnp.float32),
                                        CFG.timesteps)
    plan = compile_plan(program, params, masks=masks, assignment=backend)
    return plan, _spike_frames(batch)


def run(batch: int = 32, reps: int = 3) -> dict:
    program = compile_snn(CFG)
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)

    # -- plan compile: cold vs memory-cached vs disk-cached -----------------
    tmp = tempfile.mkdtemp(prefix="fusion-bench-plans-")
    try:
        cache = PlanCache(tmp)
        n0 = artifact_build_count()
        t0 = time.perf_counter()
        compile_plan(program, params, masks=masks, assignment="goap",
                     cache=cache)
        cold_s = time.perf_counter() - t0
        cold_builds = artifact_build_count() - n0

        t0 = time.perf_counter()
        compile_plan(program, params, masks=masks, assignment="goap",
                     cache=cache)
        memory_s = time.perf_counter() - t0
        memory_builds = artifact_build_count() - n0 - cold_builds

        cache2 = PlanCache(tmp)  # fresh memory over same disk dir = restart
        t0 = time.perf_counter()
        compile_plan(program, params, masks=masks, assignment="goap",
                     cache=cache2)
        disk_s = time.perf_counter() - t0
        disk_builds = (artifact_build_count() - n0 - cold_builds
                       - memory_builds)

        compile_row = {
            "cold_s": cold_s, "cold_artifact_builds": cold_builds,
            "memory_hit_s": memory_s,
            "memory_hit_artifact_builds": memory_builds,
            "disk_hit_s": disk_s, "disk_hit_artifact_builds": disk_builds,
            "cold_over_memory": cold_s / max(memory_s, 1e-9),
            "cold_over_disk": cold_s / max(disk_s, 1e-9),
        }

        # -- execution: fused streaming path vs layer-by-layer ---------------
        on_tpu = jax.default_backend() == "tpu"
        rows = []
        for backend in EXEC_BACKENDS:
            b = batch if on_tpu else min(batch, _BATCH_CAP.get(backend,
                                                               batch))
            plan, frames = _backend_plan_and_frames(program, params, masks,
                                                    backend, b)
            layered = jax.jit(plan.bound.batch)
            fused = jax.jit(plan.preferred_batch())
            out_l = np.asarray(layered(frames))
            out_f = np.asarray(fused(frames))
            err = float(np.abs(out_l - out_f).max())
            t_layered = _time(layered, frames, reps=reps)
            t_fused = _time(fused, frames, reps=reps)
            roof = streaming_roofline(CFG, density=DENSITY, batch=b)
            rows.append({
                "backend": backend,
                "batch": b,
                "interpret": (backend in _INTERPRET_BACKENDS
                              and not on_tpu),
                "layered_ms": t_layered * 1e3,
                "fused_ms": t_fused * 1e3,
                "layered_fps": b / t_layered,
                "fused_fps": b / t_fused,
                "fused_speedup": t_layered / max(t_fused, 1e-9),
                "roofline_target_fps": roof["target_fps"],
                "roofline_fraction": (b / t_fused) / roof["target_fps"],
                "max_abs_err": err,
            })
            assert err <= 1e-5, f"fused != layered for {backend}: {err}"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    return {
        "config": "saocds-amc (paper)",
        "density": DENSITY,
        "batch": batch,
        "jax_backend": jax.default_backend(),
        "compile": compile_row,
        "execution": rows,
    }


def format_table(res: dict) -> str:
    c = res["compile"]
    lines = [
        f"Fusion bench: paper config, density {res['density']}, batch "
        f"{res['batch']}, {res['jax_backend']}",
        f"  compile_plan  cold {c['cold_s'] * 1e3:8.1f} ms "
        f"({c['cold_artifact_builds']} artifact builds)   "
        f"memory hit {c['memory_hit_s'] * 1e3:6.2f} ms   "
        f"disk hit {c['disk_hit_s'] * 1e3:6.2f} ms "
        f"(both rebuild {c['memory_hit_artifact_builds']}/"
        f"{c['disk_hit_artifact_builds']} artifacts)",
    ]
    for r in res["execution"]:
        tag = " [interpret]" if r.get("interpret") else ""
        lines.append(
            f"  {r['backend']:12s} b={r['batch']:<3d} "
            f"layered {r['layered_ms']:8.1f} ms "
            f"({r['layered_fps']:7.1f} fps)   fused {r['fused_ms']:8.1f} ms "
            f"({r['fused_fps']:7.1f} fps)   speedup {r['fused_speedup']:.2f}x"
            f"   roofline {r['roofline_fraction']:.2e}"
            f"   err {r['max_abs_err']:.1e}{tag}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced batch/reps for CI smoke runs")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_fusion.json")
    args = ap.parse_args(argv)

    batch = args.batch if args.batch else (8 if args.smoke else 32)
    reps = args.reps if args.reps else (1 if args.smoke else 3)
    res = run(batch=batch, reps=reps)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
