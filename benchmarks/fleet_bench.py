"""Fleet-tier benchmark: open-loop offered load against the replica router.

Every serving number so far (``BENCH_serve.json``, ``BENCH_deploy.json``)
came from a **closed-loop** driver: the generator waits for results, so
the system can never be offered more load than it can serve and
saturation behavior is invisible.  This bench is **open-loop**: a seeded
Poisson arrival process submits at a configured *offered* rate whether or
not the fleet keeps up — the honest way to measure tail latency, load
shedding, and autoscaling.

Three phases, all recorded into ``BENCH_fleet.json``:

* **latency-vs-offered-load sweep** — a fixed single-replica fleet swept
  across offered rates below and above its service capacity.  Below
  saturation: zero shed, zero expired, flat p99.  Above: admission
  control sheds at the door and served p99 stays bounded by the queue
  cap — *shedding, not unbounded latency*;
* **priority split** — the saturated points record per-class latency:
  realtime dequeues ahead of bulk (weighted round-robin), so realtime
  p99 stays strictly below bulk p99 under overload;
* **autoscaler trace** — a 1-replica fleet under fixed offered load past
  its capacity; the :class:`~repro.fleet.Autoscaler` observes the p99
  breach/shedding and adds a replica, and the bench records p99 before
  vs after the scale-up (the acceptance bar: adding a replica measurably
  lowers p99 at fixed offered load).

Per-replica capacity is set by the micro-batcher's **pace gate**
(``max_batch / pace_ms``), not by host FLOPs: on a 1-core CI container
the compute for this model is ~12% of a core per loaded replica, so
capacity genuinely scales with the replica count the way it would across
devices — the control plane is what is being measured, not the kernel.

Run:  PYTHONPATH=src python benchmarks/fleet_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import threading
import time
from typing import Dict, List, Optional

import numpy as np

import jax

from repro.api import init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.fleet import Autoscaler, FleetRouter, ShedError, engine_factory
from repro.serve import DeadlineExceeded
from repro.train.pruning import make_mask_pytree

NAME = "fleet_bench"

DENSITY = 0.5
MAX_BATCH = 8          # single bucket: every batch padded to 8
PACE_MS = 40.0         # pace gate -> per-replica capacity = 8/0.040 = 200/s
MAX_QUEUE = 48         # admission bound -> queueing delay capped ~240 ms
MAX_DELAY_MS = 5.0
DEADLINE_MS = 1500.0   # generous: shedding (not expiry) is the relief valve
BULK_FRACTION = 0.25   # offered-traffic priority mix
CAPACITY_RPS = MAX_BATCH / (PACE_MS / 1e3)


def _fleet(params, masks, *, replicas: int, max_replicas: int,
           shed_p99_ms: Optional[float] = None) -> FleetRouter:
    factory = engine_factory(
        params, CFG, masks=masks, backend="dense", buckets=[MAX_BATCH],
        max_delay_ms=MAX_DELAY_MS, pace_ms=PACE_MS, max_queue=MAX_QUEUE,
        warmup=True, count_activity=False)
    return FleetRouter(factory, replicas=replicas, min_replicas=1,
                       max_replicas=max_replicas,
                       default_deadline_ms=DEADLINE_MS,
                       shed_p99_ms=shed_p99_ms)


def _frames(n: int = 64) -> np.ndarray:
    rng = np.random.default_rng(0)
    iq = rng.normal(size=(n, 2, CFG.input_width)).astype(np.float32)
    return iq / np.sqrt(np.mean(iq**2, axis=(-2, -1), keepdims=True))


def _pctl(values: List[float], q: float) -> float:
    return float(np.percentile(values, q)) * 1e3 if values else 0.0


class _Recorder:
    """Thread-safe per-request outcome log (the harness's own clock)."""

    def __init__(self):
        self.lock = threading.Lock()
        self.rows: List[tuple] = []  # (priority, outcome, latency_s, t_done)

    def add(self, priority: str, outcome: str, latency_s: float,
            t_done: float) -> None:
        with self.lock:
            self.rows.append((priority, outcome, latency_s, t_done))

    def __len__(self) -> int:
        with self.lock:
            return len(self.rows)


def run_open_loop(fleet: FleetRouter, rate_rps: float, duration_s: float, *,
                  seed: int, frames: np.ndarray,
                  deadline_ms: float = DEADLINE_MS,
                  bulk_fraction: float = BULK_FRACTION,
                  drain_timeout_s: float = 30.0) -> Dict:
    """Offer a seeded Poisson arrival stream; summarize the outcomes.

    Open loop: arrival times are drawn up front (exponential gaps) and
    requests are submitted on schedule regardless of completions.  Every
    request resolves exactly one way — done, shed (at the door), expired
    (deadline passed while queued), failed — via its future's callback.
    """
    rng = np.random.default_rng(seed)
    n = max(1, int(round(rate_rps * duration_s)))
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, size=n))
    is_bulk = rng.random(n) < bulk_fraction
    rec = _Recorder()

    t0 = time.perf_counter()
    for i in range(n):
        target = t0 + arrivals[i]
        while True:
            now = time.perf_counter()
            if now >= target:
                break
            time.sleep(min(0.002, target - now))
        priority = "bulk" if is_bulk[i] else "realtime"
        t_sub = time.perf_counter()
        try:
            fut = fleet.submit(frames[i % len(frames)], priority=priority,
                               deadline_ms=deadline_ms)
        except ShedError as e:
            rec.add(priority, f"shed:{e.reason}", 0.0, t_sub)
            continue

        def _done(f, t_sub=t_sub, priority=priority):
            t_done = time.perf_counter()
            if f.cancelled():
                outcome = "cancelled"
            else:
                exc = f.exception()
                if exc is None:
                    outcome = "done"
                elif isinstance(exc, DeadlineExceeded):
                    outcome = "expired"
                else:
                    outcome = "failed"
            rec.add(priority, outcome, t_done - t_sub, t_done)

        fut.add_done_callback(_done)
    t_last = time.perf_counter()

    drain_by = t_last + drain_timeout_s
    while len(rec) < n and time.perf_counter() < drain_by:
        time.sleep(0.02)

    with rec.lock:
        rows = list(rec.rows)
    outcomes: Dict[str, int] = {}
    for _, outcome, _, _ in rows:
        key = outcome.split(":")[0]
        outcomes[key] = outcomes.get(key, 0) + 1
    done = [(p, lat, td) for p, o, lat, td in rows if o == "done"]
    lat_all = [lat for _, lat, _ in done]
    lat_rt = [lat for p, lat, _ in done if p == "realtime"]
    lat_bk = [lat for p, lat, _ in done if p == "bulk"]
    n_shed = sum(v for k, v in outcomes.items() if k == "shed")
    summary = {
        "offered_rps": rate_rps,
        "achieved_rps": n / max(1e-9, arrivals[-1]),
        "duration_s": t_last - t0,
        "n_requests": n,
        "outcomes": outcomes,
        "unresolved": n - len(rows),   # futures still pending at drain cap
        "shed_rate": n_shed / n,
        "expired_rate": outcomes.get("expired", 0) / n,
        "served_rate": outcomes.get("done", 0) / n,
        "latency_ms": {
            "p50": _pctl(lat_all, 50), "p95": _pctl(lat_all, 95),
            "p99": _pctl(lat_all, 99),
            "realtime_p99": _pctl(lat_rt, 99),
            "bulk_p99": _pctl(lat_bk, 99),
        },
        "_completions": [(lat, td) for _, lat, td in done],
    }
    return summary


def _strip(point: Dict) -> Dict:
    return {k: v for k, v in point.items() if not k.startswith("_")}


def run_sweep(params, masks, rates: List[float], duration_s: float,
              frames: np.ndarray) -> List[Dict]:
    """Single-replica fleet swept across offered rates (fresh queue each)."""
    points = []
    with _fleet(params, masks, replicas=1, max_replicas=1) as fleet:
        busy0 = 0.0
        for i, rate in enumerate(rates):
            point = run_open_loop(fleet, rate, duration_s,
                                  seed=100 + i, frames=frames)
            busy1 = fleet.signals()["busy_s"]
            point["busy_s"] = round(busy1 - busy0, 3)
            busy0 = busy1
            points.append(_strip(point))
            # let the backlog fully drain so points stay independent
            fleet.batcher.drain_barrier(timeout=10.0)
            time.sleep(3 * PACE_MS / 1e3)
    return points


def run_autoscale(params, masks, rate_rps: float, duration_s: float,
                  frames: np.ndarray, max_replicas: int = 2,
                  target_p99_ms: float = 150.0) -> Dict:
    """Fixed offered load past one replica's capacity; autoscaler on.

    The load runs on a background thread while the main thread ticks the
    control loop; p99 is compared between completions before the first
    scale-up and completions after it settled.
    """
    fleet = _fleet(params, masks, replicas=1, max_replicas=max_replicas)
    scaler = Autoscaler(fleet, target_p99_ms=target_p99_ms,
                        up_patience=1, down_patience=1_000_000,
                        cooldown_ticks=2, interval_s=0.5)
    result: Dict = {}

    def load():
        result.update(run_open_loop(fleet, rate_rps, duration_s, seed=777,
                                    frames=frames))

    t0 = time.perf_counter()
    thread = threading.Thread(target=load, name="open-loop-load")
    thread.start()
    t_scale_up = None
    while thread.is_alive():
        time.sleep(scaler.interval_s)
        tick = scaler.step()
        if tick.action == "scale-up" and t_scale_up is None:
            t_scale_up = time.perf_counter()
    thread.join()
    fleet.close()

    completions = result.pop("_completions", [])
    p99_before = p99_after = 0.0
    settle_s = 1.0  # exclude the new replica's bind/warmup blip
    if t_scale_up is not None:
        before = [lat for lat, td in completions if td < t_scale_up]
        after = [lat for lat, td in completions
                 if td > t_scale_up + settle_s]
        p99_before, p99_after = _pctl(before, 99), _pctl(after, 99)
    shed_after = 0
    for t in scaler.trace:
        if t_scale_up is not None and t.t > t_scale_up + settle_s:
            shed_after += t.shed_delta
    return {
        "offered_rps": rate_rps,
        "target_p99_ms": target_p99_ms,
        "single_replica_capacity_rps": CAPACITY_RPS,
        "scaled_up": t_scale_up is not None,
        "t_scale_up_s": (None if t_scale_up is None
                         else round(t_scale_up - t0, 3)),
        "p99_before_scale_up_ms": p99_before,
        "p99_after_scale_up_ms": p99_after,
        "shed_after_settle": shed_after,
        "load": _strip(result),
        "trace": scaler.trace_summary(),
    }


def run(smoke: bool = False) -> dict:
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    frames = _frames()

    mu = CAPACITY_RPS
    if smoke:
        # two replicas, low offered rates: exercises admission, priorities,
        # deadlines, and the control loop inside CI's budget
        rates = [0.2 * mu, 0.4 * mu]
        duration, scale_duration = 1.5, 6.0
    else:
        rates = [0.3 * mu, 0.6 * mu, 0.85 * mu, 1.4 * mu, 2.0 * mu]
        duration, scale_duration = 4.0, 12.0

    sweep = run_sweep(params, masks, rates, duration, frames)
    if smoke:
        with _fleet(params, masks, replicas=2, max_replicas=2) as fleet:
            two = run_open_loop(fleet, 0.5 * mu, duration, seed=9,
                                frames=frames)
            two_replica_point = _strip(two)
    else:
        two_replica_point = None
    autoscale = run_autoscale(params, masks, rate_rps=1.5 * mu,
                              duration_s=scale_duration, frames=frames)

    return {
        "smoke": smoke,
        "jax_backend": jax.default_backend(),
        "n_devices": jax.local_device_count(),
        "config": {
            "max_batch": MAX_BATCH, "pace_ms": PACE_MS,
            "max_queue": MAX_QUEUE, "max_delay_ms": MAX_DELAY_MS,
            "deadline_ms": DEADLINE_MS, "bulk_fraction": BULK_FRACTION,
            "capacity_rps_per_replica": CAPACITY_RPS,
        },
        "sweep": sweep,
        "two_replica_point": two_replica_point,
        "autoscale": autoscale,
    }


def format_table(res: dict) -> str:
    lines = [
        f"Fleet bench ({res['n_devices']} {res['jax_backend']} device(s)); "
        f"per-replica capacity {res['config']['capacity_rps_per_replica']:.0f} req/s "
        f"(pace {res['config']['pace_ms']}ms x batch {res['config']['max_batch']})",
        "  offered  served  shed   expired  p50      p99      rt-p99   bulk-p99",
    ]
    for p in res["sweep"]:
        lat = p["latency_ms"]
        lines.append(
            f"  {p['offered_rps']:6.0f}/s {p['served_rate']:6.1%} "
            f"{p['shed_rate']:6.1%} {p['expired_rate']:6.1%}  "
            f"{lat['p50']:7.1f}  {lat['p99']:7.1f}  "
            f"{lat['realtime_p99']:7.1f}  {lat['bulk_p99']:7.1f}")
    a = res["autoscale"]
    lines.append(
        f"  autoscale @ {a['offered_rps']:.0f}/s offered: scaled_up="
        f"{a['scaled_up']} at t={a['t_scale_up_s']}s  "
        f"p99 {a['p99_before_scale_up_ms']:.1f}ms -> "
        f"{a['p99_after_scale_up_ms']:.1f}ms  "
        f"shed_after_settle={a['shed_after_settle']}")
    for t in a["trace"]:
        if t["action"] != "hold":
            lines.append(f"    tick {t['tick']}: {t['action']} ({t['reason']})")
    return "\n".join(lines)


def check(res: dict) -> List[str]:
    """Acceptance gates (non-smoke): the claims BENCH_fleet.json makes."""
    problems = []
    mu = res["config"]["capacity_rps_per_replica"]
    for p in res["sweep"]:
        sat = p["offered_rps"] > mu
        if not sat and (p["shed_rate"] > 0 or p["expired_rate"] > 0):
            problems.append(
                f"shed/expiry below saturation ({p['offered_rps']:.0f}/s: "
                f"shed {p['shed_rate']:.2%}, expired {p['expired_rate']:.2%})")
        if sat and p["shed_rate"] == 0 and p["expired_rate"] == 0:
            problems.append(
                f"no shedding above saturation ({p['offered_rps']:.0f}/s)")
        if sat and p["latency_ms"]["p99"] > 2.5 * res["config"]["deadline_ms"]:
            problems.append(
                f"unbounded latency above saturation "
                f"(p99 {p['latency_ms']['p99']:.0f}ms)")
        if sat and p["outcomes"].get("done", 0) >= 50 and not (
                p["latency_ms"]["realtime_p99"]
                < p["latency_ms"]["bulk_p99"]):
            problems.append(
                f"realtime p99 not below bulk p99 under saturation "
                f"({p['latency_ms']['realtime_p99']:.1f} vs "
                f"{p['latency_ms']['bulk_p99']:.1f}ms)")
        if p["unresolved"]:
            problems.append(
                f"{p['unresolved']} futures never resolved "
                f"({p['offered_rps']:.0f}/s point)")
    a = res["autoscale"]
    if not a["scaled_up"]:
        problems.append("autoscaler never scaled up under overload")
    elif not a["p99_after_scale_up_ms"] < a["p99_before_scale_up_ms"]:
        problems.append(
            f"adding a replica did not lower p99 "
            f"({a['p99_before_scale_up_ms']:.1f} -> "
            f"{a['p99_after_scale_up_ms']:.1f}ms)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="two replicas, low offered rates (CI)")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args(argv)

    res = run(smoke=args.smoke)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    if not args.smoke:
        problems = check(res)
        if problems:
            print("FAIL:\n  " + "\n  ".join(problems))
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
