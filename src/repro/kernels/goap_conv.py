"""GOAP spike convolution as a static block-sparse Pallas TPU kernel.

TPU adaptation of the paper's GOAP dataflow (DESIGN.md §2):

* the 1-D conv is lowered to ``W'(OC, K=IC*KW) @ X'(K, OI)`` where X' is the
  binary shifted-input buffer (each non-zero weight's *enable map* is one
  row-slice of X');
* the non-zero structure of W' is compressed into (block_oc x block_k)
  tiles; only non-empty tiles execute, and each oc-tile row's tile list is
  **padded to a fixed length with explicit no-op tiles** — the direct TPU
  analogue of the paper's precomputed empty/extra iterations: a static
  schedule with zero dynamic control flow, so the grid shape (and therefore
  the pipeline) is compile-time fixed;
* tile k-indices are **scalar-prefetched** so the input-tile DMA for tile
  t+1 can be issued while tile t is in the MXU (compute/fetch overlap —
  the streaming-pipeline property of the paper's architecture);
* the {0,1} IFM tile is the gate: multiplying by a binary operand *is* the
  paper's enable-signal accumulation, executed 8x128-lane parallel.

VMEM budget per grid step: block (BO x BK) + input tile (BK x BOI) + output
tile (BO x BOI), all fp32 — with the default (8, 128, 128) tiling that is
8*128 + 128*128 + 8*128 floats = ~68 KB, far under the ~16 MB VMEM of a
TPU v5e core; BOI can be raised to 512 for wider layers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["goap_conv_block_sparse"]


def _kernel(cols_ref, blocks_ref, x_ref, out_ref):
    """One (oc-tile, oi-tile, tile-slot) grid step: out += block @ x_tile."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # padded no-op tiles carry zero data (and point at k-tile 0): they
    # contribute nothing — the static-schedule trick, no conditionals.
    out_ref[...] += jnp.dot(
        blocks_ref[0, 0], x_ref[...], preferred_element_type=out_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("block_oc", "block_k", "block_oi", "interpret")
)
def goap_conv_block_sparse(
    blocks: jax.Array,      # (n_oc_tiles, max_tiles, BO, BK) tile data
    block_cols: jax.Array,  # (n_oc_tiles, max_tiles) int32 k-tile indices
    x: jax.Array,           # (K_padded, OI_padded) binary shift buffer
    *,
    block_oc: int,
    block_k: int,
    block_oi: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Returns currents (n_oc_tiles * BO, OI_padded) = block-sparse W' @ X'."""
    n_oc_tiles, max_tiles, bo, bk = blocks.shape
    assert (bo, bk) == (block_oc, block_k), (blocks.shape, block_oc, block_k)
    k_padded, oi_padded = x.shape
    assert k_padded % block_k == 0, (k_padded, block_k)
    assert oi_padded % block_oi == 0, (oi_padded, block_oi)
    n_oi_tiles = oi_padded // block_oi

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_oc_tiles, n_oi_tiles, max_tiles),
        in_specs=[
            pl.BlockSpec(
                (1, 1, block_oc, block_k), lambda r, o, t, cols: (r, t, 0, 0)
            ),
            pl.BlockSpec(
                (block_k, block_oi), lambda r, o, t, cols: (cols[r, t], o)
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_oc, block_oi), lambda r, o, t, cols: (r, o)
        ),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(
            (n_oc_tiles * block_oc, oi_padded), blocks.dtype
        ),
        interpret=interpret,
        name="goap_conv_block_sparse",
    )(block_cols, blocks, x)
