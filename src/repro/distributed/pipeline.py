"""SPMD pipeline-stage runner: the paper's inter-layer streaming on a mesh.

The SAOCDS accelerator instantiates every SNN layer as its own hardware
stage and streams activations stage-to-stage with no global control logic
(paper §III).  On a TPU mesh the same structure is pipeline parallelism:
each device along a ``stage`` axis holds one stage's params, microbatches
stream through via ``ppermute``, and the schedule is a *fixed-length* tick
loop — ``n_micro + n_stages - 1`` ticks, bubbles included as explicit
no-op slots, exactly the paper's precomputed empty/extra iterations
(DESIGN.md §2).

Because each tick's ``ppermute`` result is only consumed at the *next*
tick, the transfer of tick *t* overlaps the compute of tick *t* (XLA
schedules the send/recv asynchronously on TPU): compute/comm overlap falls
out of the schedule shape rather than handwritten double buffering.

Stages must share one buffer shape; heterogeneous stages (the SNN's
conv/pool widths) embed into the max-shape buffer — the software analogue
of the accelerator's fixed-width inter-layer stream.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

__all__ = ["spmd_pipeline", "stack_stage_params"]


def stack_stage_params(per_stage_params) -> Any:
    """[stage0_tree, stage1_tree, ...] -> one tree with leading stage dim."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *per_stage_params
    )


def spmd_pipeline(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    params: Any,                 # pytree, leaves (n_stages, ...), sharded on stage
    microbatches: jax.Array,     # (n_micro, ...) same buffer shape per stage
    mesh: Mesh,
    *,
    stage_axis: str = "stage",
    collect: str = "psum",
) -> jax.Array:
    """Run ``y_mb = stageN(...stage0(x_mb))`` for every microbatch.

    Returns (n_micro, ...) outputs.  ``stage_fn(stage_params, x) -> y``
    must preserve the buffer shape (pad heterogeneous stages up).

    Only ``stage_axis`` is manual; any other mesh axes (data/model) stay
    in auto mode, so the stage body composes with the usual pjit TP/DP
    sharding — pipeline-over-stages x tensor-parallel-within-stage.

    ``collect``: "psum" broadcasts the last stage's outputs to every
    stage (one all-reduce); "stack" returns them stage-local as a
    (n_stages, n_micro, ...) array whose last row is the result — no
    collective (also dodges an XLA-CPU AllReducePromotion crash in
    mixed manual/auto programs).
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = microbatches.shape[0]
    ticks = n_micro + n_stages - 1

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P() if collect == "psum" else P(stage_axis),
        axis_names={stage_axis},
        # scan carries start as unvarying zeros and become stage-varying
        # after the first ppermute; skip the static vma check
        check_vma=False,
    )
    def run(stage_params, mbs):
        stage_params = jax.tree_util.tree_map(lambda x: x[0], stage_params)
        idx = jax.lax.axis_index(stage_axis)
        buf_shape = mbs.shape[1:]

        def tick(carry, t):
            state, outputs = carry
            # stage 0 injects microbatch t (clamped no-op slots at the tail)
            inject = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, n_micro - 1), keepdims=False
            )
            x = jnp.where(idx == 0, inject, state)
            y = stage_fn(stage_params, x)
            # the last stage banks microbatch (t - n_stages + 1)
            out_t = t - (n_stages - 1)
            is_out = jnp.logical_and(idx == n_stages - 1, out_t >= 0)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(is_out, y, jax.lax.dynamic_index_in_dim(
                    outputs, jnp.clip(out_t, 0, n_micro - 1), keepdims=False)),
                jnp.clip(out_t, 0, n_micro - 1),
                axis=0,
            )
            # hand y to the next stage (transfer overlaps next tick's compute)
            nxt = jax.lax.ppermute(
                y, stage_axis, [(i, i + 1) for i in range(n_stages - 1)]
            )
            return (nxt, outputs), None

        state0 = jnp.zeros(buf_shape, mbs.dtype)
        outputs0 = jnp.zeros((n_micro,) + buf_shape, mbs.dtype)
        (_, outputs), _ = jax.lax.scan(
            tick, (state0, outputs0), jnp.arange(ticks)
        )
        # outputs live on the last stage only
        if collect == "psum":
            keep = (idx == n_stages - 1).astype(outputs.dtype)
            return jax.lax.psum(outputs * keep, stage_axis)
        return outputs[None]  # (1, n_micro, ...) per stage -> stacked

    out = run(params, microbatches)
    return out if collect == "psum" else out[-1]
