"""Fault-tolerance: atomic checkpoints, keep-N GC, resume, elastic restore."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.train.checkpoint import CheckpointManager


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32))},
        "step": jnp.asarray(3, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree()
    mgr.save(10, tree, extra={"note": "x"})
    restored, manifest = mgr.restore(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert manifest["step"] == 10 and manifest["extra"]["note"] == "x"


def test_async_save_and_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(1, _tree())
    mgr.wait()
    assert mgr.all_steps() == [1]


def test_keep_n_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in [1, 2, 3, 4]:
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_restore_latest_by_default(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t1, t2 = _tree(1), _tree(2)
    mgr.save(1, t1)
    mgr.save(5, t2)
    restored, manifest = mgr.restore(t1)
    assert manifest["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(t2["w"]))


def test_half_written_checkpoint_invisible(tmp_path):
    """A crash mid-save (tmp dir left behind) must not corrupt discovery."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    # simulate a crashed save: orphan tmp dir + a step dir missing manifest
    os.makedirs(tmp_path / ".tmp.step_9")
    os.makedirs(tmp_path / "step_7")
    assert mgr.all_steps() == [1]
    restored, manifest = mgr.restore(_tree())
    assert manifest["step"] == 1


def test_structure_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    bad_shape = _tree()
    bad_shape["w"] = jnp.zeros((3, 3))
    with pytest.raises(ValueError):
        mgr.restore(bad_shape)
    bad_struct = {"only": jnp.zeros(2)}
    with pytest.raises(ValueError):
        mgr.restore(bad_struct)


def test_elastic_restore_onto_new_sharding(tmp_path):
    """Save under one device layout, restore and re-place under another:
    checkpoints are layout-free (unsharded arrays), so elastic rescaling is
    a restore + device_put with the new sharding."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree()
    mgr.save(1, tree)
    restored, _ = mgr.restore(tree)
    # single-device container: re-placement onto a (possibly different)
    # sharding is a plain device_put; on a real mesh the same call takes a
    # NamedSharding for the new mesh.
    dev = jax.devices()[0]
    replaced = jax.tree_util.tree_map(lambda a: jax.device_put(a, dev), restored)
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(replaced)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_resume_bitwise_identical(tmp_path):
    """Train k steps + save; new trainer resumes and matches exactly."""
    from repro.models.snn import SNNConfig
    from repro.train import SNNTrainer, TrainerConfig

    cfg = TrainerConfig(
        total_steps=6, batch_size=4, ckpt_dir=str(tmp_path), ckpt_every=3, osr=2,
    )
    small = SNNConfig(
        conv_specs=((3, 2, 4), (3, 4, 8), (3, 8, 8)),
        fc_specs=((8 * 16, 16), (16, 11)),
        timesteps=2,
    )
    tr = SNNTrainer(small, cfg)
    tr.run(steps=6, log_every=3)
    tr.ckpt.wait()

    tr2 = SNNTrainer(small, cfg)
    assert tr2.resume()
    assert tr2.step == 6
    for a, b in zip(
        jax.tree_util.tree_leaves(tr.params), jax.tree_util.tree_leaves(tr2.params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
