"""Whole-network streaming SNN as a single multi-layer Pallas kernel.

The paper's accelerator (§III, Fig. 6) streams spikes through every layer
concurrently with *no* control flow and *no* DRAM round-trips: each layer's
membrane potentials and the static Algorithm-2 schedule live on-chip and a
timestep flows conv1 -> pool -> ... -> FC -> readout in one pipeline pass.
This module is the TPU analogue: **one** ``pallas_call`` whose grid is
``(batch, timesteps)`` with time minor, keeping

* every conv/FC layer's membrane potential,
* the Σ-Δ encoder state (when encoding is fused in), and
* the readout/counter accumulators

resident in VMEM scratch across all T grid steps of a sample.  HBM traffic
per timestep is exactly one input frame read; weights are loaded once per
sample (constant ``index_map`` keeps their blocks resident); logits and the
Tables I/III accumulation counters are written once at ``t == T-1``.
Compare the generic fused executor (:mod:`repro.plan.streaming`), which
still launches every layer's XLA ops per scan step, and the per-layer
``pallas`` backend, which costs T x L kernel launches per sample.

The conv inside the kernel uses the GOAP shift-buffer identity: the
padded frame is expanded to X'(KW*IC, W) (rows ordered ci-major so the
expansion is a 2-D concatenation, Mosaic-friendly) and the layer current
is one ``(OC, KW*IC) @ (KW*IC, W)`` MXU matmul.  The gated-accumulation
counter of the ``stream`` backend is recovered exactly (integer-valued
f32) as ``counts · row_sums(X')`` where ``counts[r]`` is the number of
non-zero weights mapping to shift-buffer row ``r`` — summing enable maps
per non-zero weight and summing row occupancies are the same double sum.

Like every kernel in this repo, ``interpret=True`` is the CPU fallback
(this container is CPU-only; TPU v5e is the compile target).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "FusedConv",
    "FusedFC",
    "FusedPool",
    "FusedReadout",
    "FusedStack",
    "fused_conv_info",
    "fused_fc_info",
    "fused_stack_of",
    "stream_fused_forward",
    "fused_counters",
]

# Layer-kind strings of repro.models.graph (string literals keep kernels/
# import-independent of the model layer; graph.py imports *us* lazily).
_KIND_CONV = "conv_lif"
_KIND_POOL = "maxpool"
_KIND_FC = "fc_lif"
_KIND_READOUT = "readout"


def _lif_rows(lif, n: int) -> np.ndarray:
    """LIFParams -> concrete (3, n) f32 rows [alpha, theta, v_th]."""
    def row(a) -> np.ndarray:
        a = np.asarray(a, dtype=np.float32).reshape(-1)
        if a.size == 1:
            a = np.full((n,), float(a[0]), dtype=np.float32)
        if a.size != n:
            raise ValueError(f"LIF param size {a.size} != {n} neurons")
        return a

    # alpha through the same jax sigmoid the float cells use (f32-exact)
    alpha = np.asarray(jax.nn.sigmoid(jnp.asarray(lif.alpha_logit,
                                                  jnp.float32)))
    return np.stack([row(alpha), row(lif.theta), row(lif.v_th)])


@dataclasses.dataclass(frozen=True)
class FusedConv:
    """One conv layer's VMEM-resident operands (ci-major GOAP layout)."""

    name: str
    kw: int
    ic: int
    oc: int
    w_cm: np.ndarray           # (OC, KW*IC) f32; col r = ci*IC + ic
    counts: np.ndarray         # (1, KW*IC) f32; nnz per shift-buffer row
    lif: np.ndarray            # (3, OC) f32: alpha, theta, v_th
    static_counts: Dict[str, int]  # Algorithm-2 reps/compute/extra/empty


@dataclasses.dataclass(frozen=True)
class FusedFC:
    name: str
    w: np.ndarray              # (IN, OUT) f32, zeros = weight mask
    lif: np.ndarray            # (3, OUT) f32


@dataclasses.dataclass(frozen=True)
class FusedPool:
    pool: int


@dataclasses.dataclass(frozen=True)
class FusedReadout:
    mode: str                  # "current_sum" | "spikes"


@dataclasses.dataclass(frozen=True)
class FusedStack:
    """The whole network, flattened into kernel-ready operands."""

    layers: Tuple[Any, ...]
    timesteps: int
    in_ic: int
    in_width: int
    n_classes: int

    @property
    def conv_names(self) -> Tuple[str, ...]:
        return tuple(l.name for l in self.layers
                     if isinstance(l, FusedConv))


def fused_conv_info(name: str, coo, lif, sched) -> FusedConv:
    """Build a conv layer's fused operands from its COO kernel + schedule."""
    from repro.core.sparse_format import coo_to_dense

    w = np.asarray(coo_to_dense(coo), dtype=np.float32)   # (KW, IC, OC)
    w_cm = np.transpose(w, (2, 0, 1)).reshape(coo.oc, coo.kw * coo.ic)
    ic_idx = np.asarray(coo.row_idx) % coo.ic
    rows = np.asarray(coo.col_idx) * coo.ic + ic_idx      # ci-major row ids
    counts = np.bincount(rows, minlength=coo.kw * coo.ic) if coo.nnz else \
        np.zeros(coo.kw * coo.ic, dtype=np.int64)
    return FusedConv(
        name=name, kw=coo.kw, ic=coo.ic, oc=coo.oc,
        w_cm=np.ascontiguousarray(w_cm),
        counts=counts.astype(np.float32)[None, :],
        lif=_lif_rows(lif, coo.oc),
        static_counts={
            "reps_per_timestep": sched.reps,
            "compute_iters": sched.n_compute,
            "extra_iters": sched.n_extra,
            "empty_iters": sched.n_empty,
        })


def fused_fc_info(name: str, w: np.ndarray, lif) -> FusedFC:
    w = np.ascontiguousarray(np.asarray(w, dtype=np.float32))
    return FusedFC(name=name, w=w, lif=_lif_rows(lif, w.shape[1]))


def fused_stack_of(plan) -> Optional[FusedStack]:
    """Assemble a FusedStack from an ExecutionPlan, or None.

    Returns None unless *every* weighted layer is assigned the
    ``pallas_fused`` backend and carries fused operands — a partial
    assignment falls back to the generic streaming executor.
    """
    layers = []
    for lp in plan.layers:
        kind = lp.spec.kind
        if kind in (_KIND_CONV, _KIND_FC):
            if lp.backend != "pallas_fused" or lp.cell.fused is None:
                return None
            layers.append(lp.cell.fused)
        elif kind == _KIND_POOL:
            layers.append(FusedPool(lp.spec.pool))
        elif kind == _KIND_READOUT:
            layers.append(FusedReadout(lp.spec.mode))
        else:
            return None
    cfg = plan.cfg
    return FusedStack(
        layers=tuple(layers),
        timesteps=cfg.timesteps,
        in_ic=cfg.conv_specs[0][1],
        in_width=cfg.input_width,
        n_classes=cfg.fc_specs[-1][1],
    )


# ---------------------------------------------------------------------------
# The kernel.
# ---------------------------------------------------------------------------

def _shift_buffer_cm(x: jax.Array, kw: int) -> jax.Array:
    """Padded (IC, W) frame -> X'(KW*IC, W), rows ci-major (r = ci*IC+ic).

    pad_same + static slices: stays 2-D throughout (no rank-3 reshape for
    Mosaic to choke on).
    """
    ic, w = x.shape
    left = (kw - 1) // 2
    xp = jnp.pad(x, ((0, 0), (left, kw - 1 - left)))
    return jnp.concatenate([xp[:, ci:ci + w] for ci in range(kw)], axis=0)


def _lif_fire(v_acc: jax.Array, theta, v_th) -> Tuple[jax.Array, jax.Array]:
    """Threshold + soft reset (identical to core.lif.lif_step forward)."""
    s = (v_acc > v_th).astype(v_acc.dtype)
    return v_acc - theta * s, s


def stream_fused_forward(
    stack: FusedStack,
    frames: jax.Array,
    *,
    encode: bool = False,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run the whole network in one multi-layer kernel launch.

    frames: (B, T, IC0, W) binary spike frames — or, with ``encode=True``,
    (B, IC0, W) normalized analog values in [0, 1] that the fused Σ-Δ
    modulator turns into spikes in-kernel (one frame read per *sample*
    instead of per timestep).

    Returns ``(logits (B, n_classes), conv_accs (B, n_convs))`` where
    ``conv_accs`` are the gated-accumulation counters of paper Tables
    I/III, per sample and conv layer (see :func:`fused_counters`).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t_steps = stack.timesteps
    if encode:
        b, ic0, w0 = frames.shape
    else:
        b, t_f, ic0, w0 = frames.shape
        if t_f != t_steps:
            raise ValueError(f"frames have T={t_f}, stack expects {t_steps}")
    if (ic0, w0) != (stack.in_ic, stack.in_width):
        raise ValueError(f"frames are ({ic0}, {w0}), stack expects "
                         f"({stack.in_ic}, {stack.in_width})")

    convs = [l for l in stack.layers if isinstance(l, FusedConv)]
    n_convs = max(1, len(convs))

    # -- operands: frames + per-layer constants (all resident via constant
    #    index maps), walking the static width through the graph ------------
    whole = lambda a: pl.BlockSpec(a.shape, lambda bb, tt:
                                   (0,) * a.ndim)  # noqa: E731
    inputs: list = [frames]
    if encode:
        in_specs = [pl.BlockSpec((1, ic0, w0), lambda bb, tt: (bb, 0, 0))]
    else:
        in_specs = [pl.BlockSpec((1, 1, ic0, w0),
                                 lambda bb, tt: (bb, tt, 0, 0))]
    scratch_shapes: list = []
    scratch_dims: list = []           # parallel shapes, for zero-init
    if encode:
        scratch_shapes += [pltpu.VMEM((ic0, w0), jnp.float32)] * 2
        scratch_dims += [(ic0, w0)] * 2
    width, chans = w0, ic0
    layer_widths = []                 # input width at each layer
    for layer in stack.layers:
        layer_widths.append(width)
        if isinstance(layer, FusedConv):
            for a in (layer.w_cm, layer.counts, layer.lif):
                arr = jnp.asarray(a)
                inputs.append(arr)
                in_specs.append(whole(arr))
            scratch_shapes.append(pltpu.VMEM((layer.oc, width), jnp.float32))
            scratch_dims.append((layer.oc, width))
            chans = layer.oc
        elif isinstance(layer, FusedPool):
            width = (width // layer.pool)
        elif isinstance(layer, FusedFC):
            for a in (layer.w, layer.lif):
                arr = jnp.asarray(a)
                inputs.append(arr)
                in_specs.append(whole(arr))
            dout = layer.w.shape[1]
            scratch_shapes.append(pltpu.VMEM((1, dout), jnp.float32))
            scratch_dims.append((1, dout))
            chans, width = dout, 1

    out_shape = [
        jax.ShapeDtypeStruct((b, stack.n_classes), jnp.float32),
        jax.ShapeDtypeStruct((b, n_convs), jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((1, stack.n_classes), lambda bb, tt: (bb, 0)),
        pl.BlockSpec((1, n_convs), lambda bb, tt: (bb, 0)),
    ]

    def kernel(*refs):
        cursor = 0

        def take(n=1):
            nonlocal cursor
            out = refs[cursor:cursor + n]
            cursor += n
            return out if n > 1 else out[0]

        x_ref = take()
        layer_refs = []
        for layer in stack.layers:
            if isinstance(layer, FusedConv):
                layer_refs.append(take(3))
            elif isinstance(layer, FusedFC):
                layer_refs.append(take(2))
            else:
                layer_refs.append(None)
        logits_ref, accs_ref = take(), take()
        scratch = refs[cursor:]

        t = pl.program_id(1)

        @pl.when(t == 0)
        def _fresh_sample():
            logits_ref[...] = jnp.zeros_like(logits_ref[...])
            accs_ref[...] = jnp.zeros_like(accs_ref[...])
            for ref, dims in zip(scratch, scratch_dims):
                ref[...] = jnp.zeros(dims, jnp.float32)

        sc = 0
        if encode:
            # first-order Σ-Δ: integ += x - y_prev; y = (integ >= 0.5)
            integ_ref, yprev_ref = scratch[sc], scratch[sc + 1]
            sc += 2
            integ = integ_ref[...] + x_ref[0] - yprev_ref[...]
            x = (integ >= 0.5).astype(jnp.float32)
            integ_ref[...] = integ
            yprev_ref[...] = x
        else:
            x = x_ref[0, 0]

        acc_contribs = []
        last_cur = None
        for layer, lrefs, w_in in zip(stack.layers, layer_refs,
                                      layer_widths):
            if isinstance(layer, FusedConv):
                w_ref, c_ref, lif_ref = lrefs
                v_ref = scratch[sc]
                sc += 1
                sb = _shift_buffer_cm(x, layer.kw)          # (KW*IC, W)
                cur = jnp.dot(w_ref[...], sb,
                              preferred_element_type=jnp.float32)
                acc_contribs.append(
                    jnp.sum(c_ref[...] * jnp.sum(sb, axis=1)[None, :]))
                lif = lif_ref[...]                          # (3, OC)
                v_acc = lif[0][:, None] * v_ref[...] + cur
                v_next, x = _lif_fire(v_acc, lif[1][:, None],
                                      lif[2][:, None])
                v_ref[...] = v_next
            elif isinstance(layer, FusedPool):
                c = x.shape[0]
                w2 = (w_in // layer.pool) * layer.pool
                x = (x[:, :w2]
                     .reshape(c * (w2 // layer.pool), layer.pool)
                     .max(axis=1)
                     .reshape(c, w2 // layer.pool))
            elif isinstance(layer, FusedFC):
                w_ref, lif_ref = lrefs
                v_ref = scratch[sc]
                sc += 1
                cur = jnp.dot(x.reshape(1, -1), w_ref[...],
                              preferred_element_type=jnp.float32)
                lif = lif_ref[...]                          # (3, OUT)
                v_acc = lif[0][None, :] * v_ref[...] + cur
                v_next, x = _lif_fire(v_acc, lif[1][None, :],
                                      lif[2][None, :])
                v_ref[...] = v_next
                last_cur = cur
            else:  # FusedReadout
                contrib = last_cur if layer.mode == "current_sum" else x
                logits_ref[...] = logits_ref[...] + contrib.reshape(
                    1, stack.n_classes)
        if acc_contribs:
            accs_ref[...] = accs_ref[...] + jnp.stack(acc_contribs)[None, :]

    logits, accs = pl.pallas_call(
        kernel,
        grid=(b, t_steps),            # T minor: state persists across T
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        name="stream_fused",
    )(*inputs)
    return logits, accs


def fused_counters(stack: FusedStack, accs_row: jax.Array) -> Dict[str, Dict]:
    """Per-conv-layer Tables I/III counters for one sample's ``accs`` row,
    matching the ``stream`` backend's counter dict exactly."""
    out: Dict[str, Dict] = {}
    i = 0
    for layer in stack.layers:
        if isinstance(layer, FusedConv):
            out[layer.name] = {
                **layer.static_counts,
                "accumulations": accs_row[i],
                "timesteps": stack.timesteps,
            }
            i += 1
    return out
