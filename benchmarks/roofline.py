"""Roofline report: aggregates the dry-run sweep into the 40-cell table.

Reads ``experiments/dryrun/<mesh>/<arch>__<shape>.json`` (produced by
``python -m repro.launch.dryrun --all``) and renders EXPERIMENTS.md
§Roofline: the three terms, the bottleneck, MODEL_FLOPS/HLO ratio, and
the modeled-bound MFU per cell.
"""
from __future__ import annotations

import json
import pathlib

NAME = "roofline"
DRYRUN_DIR = pathlib.Path("experiments/dryrun")


def run(mesh: str = "single") -> dict:
    rows = []
    d = DRYRUN_DIR / mesh
    if not d.exists():
        return {"rows": [], "missing": True, "mesh": mesh}
    for f in sorted(d.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": rec["reason"][:40]})
            continue
        if not rec.get("ok"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "failed": True})
            continue
        r = rec["roofline"]
        m = rec.get("memory", {})
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": r["terms_s"]["compute"],
            "memory_s": r["terms_s"]["memory"],
            "collective_s": r["terms_s"]["collective"],
            "bottleneck": r["bottleneck"],
            "useful_ratio": r["useful_ratio"],
            "mfu_bound": r["mfu_bound"],
            "live_gb": m.get("peak_live_bytes", 0) / 1e9,
            "fits": m.get("fits_16g_hbm"),
        })
    return {"rows": rows, "mesh": mesh, "missing": False}


def format_table(res: dict) -> str:
    if res.get("missing"):
        return (f"roofline: no dry-run results under {DRYRUN_DIR}/"
                f"{res['mesh']} — run `python -m repro.launch.dryrun --all`")
    lines = [
        f"Roofline terms per cell ({res['mesh']} mesh; seconds/step)",
        f"  {'arch':22s}{'shape':13s}{'compute':>10s}{'memory':>10s}"
        f"{'collect':>10s} {'bound':10s}{'useful':>7s}{'MFU@bound':>10s}"
        f"{'liveGB':>8s}",
    ]
    for r in res["rows"]:
        if r.get("skipped"):
            lines.append(f"  {r['arch']:22s}{r['shape']:13s}  SKIP ({r['skipped']})")
            continue
        if r.get("failed"):
            lines.append(f"  {r['arch']:22s}{r['shape']:13s}  FAILED")
            continue
        lines.append(
            f"  {r['arch']:22s}{r['shape']:13s}{r['compute_s']:10.2e}"
            f"{r['memory_s']:10.2e}{r['collective_s']:10.2e} "
            f"{r['bottleneck']:10s}{r['useful_ratio']:7.2f}"
            f"{r['mfu_bound']:10.3f}{r['live_gb']:8.1f}"
            f"{'' if r['fits'] else '  OVER-HBM'}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run("single")))
    print()
    print(format_table(run("multi")))
