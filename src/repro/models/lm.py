"""Decoder LM assembly for dense / MoE / SSM / hybrid / VLM families.

Layers are **stacked and scanned** (params carry a leading (L, ...) axis,
``jax.lax.scan`` over layers with ``jax.checkpoint`` on the body): one
layer's HLO is compiled once regardless of depth — the difference between
minutes and hours for the 48-layer dry-runs — and remat keeps activation
memory at O(one layer).

Decode uses a pre-allocated KV cache (attention), rolling conv+SSM state
(mamba2) or conv+LRU state (RG-LRU), all stacked over layers and threaded
through the same scan.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain_acts, constrain_head, constrain_logits

from .config import ArchConfig
from .layers import (
    attention,
    init_attention,
    init_embedding,
    init_mamba2,
    init_moe,
    init_rglru,
    init_swiglu,
    mamba2_block,
    mask_vocab_pad,
    moe,
    rglru_block,
    rms_norm,
    softmax_cross_entropy,
    swiglu,
)

__all__ = [
    "init_lm",
    "lm_forward",
    "lm_loss",
    "lm_prefill",
    "init_decode_state",
    "lm_decode_step",
]

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str, dtype) -> Params:
    """One layer's params.
    kind: attn_mlp | attn_moe | ssm | rglru_mlp | hybrid_group."""
    if kind == "hybrid_group":
        # one scanned unit = (rglru, rglru, local-attn): stacking the
        # REPEATING GROUP keeps the hybrid model in a single long scan
        # (25 fragmented 1-2 layer stacks made every stack's grads
        # materialize at full f32 size — 9.9 GB of unsharded weight-grad
        # carries on recurrentgemma train)
        kb = jax.random.split(key, cfg.hybrid_period)
        subs = ["rglru_mlp"] * (cfg.hybrid_period - 1) + ["attn_mlp"]
        return {f"b{i}": _init_block(kb[i], cfg, sk, dtype)
                for i, sk in enumerate(subs)}
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"norm1": jnp.ones((d,), dtype), "norm2": jnp.ones((d,), dtype)}
    if kind == "attn_mlp":
        p["attn"] = init_attention(k1, cfg, dtype=dtype)
        p["mlp"] = init_swiglu(k2, d, cfg.d_ff, dtype=dtype)
    elif kind == "attn_moe":
        p["attn"] = init_attention(k1, cfg, dtype=dtype)
        p["moe"] = init_moe(k2, cfg, dtype=dtype)
    elif kind == "ssm":
        p.pop("norm2")
        p["ssm"] = init_mamba2(k1, cfg, dtype=dtype)
    elif kind == "rglru_mlp":
        p["rglru"] = init_rglru(k1, cfg, dtype=dtype)
        p["mlp"] = init_swiglu(k2, d, cfg.d_ff, dtype=dtype)
    else:
        raise ValueError(kind)
    return p


def _layer_kinds(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.family == "moe":
        return ("attn_moe",) * cfg.n_layers
    if cfg.family == "ssm":
        return ("ssm",) * cfg.n_layers
    if cfg.family == "hybrid":
        n_groups = cfg.n_layers // cfg.hybrid_period
        tail = cfg.n_layers - n_groups * cfg.hybrid_period
        return ("hybrid_group",) * n_groups + ("rglru_mlp",) * tail
    return ("attn_mlp",) * cfg.n_layers  # dense / vlm


def init_lm(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    kinds = _layer_kinds(cfg)
    k_emb, k_layers = jax.random.split(key)
    params: Params = {
        "emb": init_embedding(k_emb, cfg, dtype=dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    # one stacked param tree per run of identical layer kinds; the (kind,
    # count) layout itself is static structure derived from cfg via
    # _stack_layout, NOT stored in params (strings can't be pytree leaves
    # under jit)
    stacks = []
    keys = jax.random.split(k_layers, len(kinds))
    off = 0
    for kind, count in _stack_layout(cfg):
        ks = keys[off : off + count]
        off += count
        stacks.append(jax.vmap(lambda k: _init_block(k, cfg, kind, dtype))(ks))
    params["stacks"] = stacks
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_apply(cfg: ArchConfig, kind: str, p: Params, x, state, layer_in_stack,
                 build_state: bool = False, cache_headroom: int = 0):
    """One layer forward; state is None (train) or the layer's decode state.
    ``build_state`` (prefill) makes the stateless path also emit a
    decode-ready state."""
    if kind == "hybrid_group":
        subs = ["rglru_mlp"] * (cfg.hybrid_period - 1) + ["attn_mlp"]
        new_states = {}
        for i, sk in enumerate(subs):
            sub_state = None if state is None else state[f"s{i}"]
            x, ns = _block_apply(cfg, sk, p[f"b{i}"], x, sub_state, 0,
                                 build_state=build_state,
                                 cache_headroom=cache_headroom)
            new_states[f"s{i}"] = ns
        return x, (new_states if (state is not None or build_state) else None)
    window = cfg.window
    if kind in ("attn_mlp", "attn_moe"):
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        cache = None if state is None else state
        positions = None
        if cache is not None:
            positions = cache["len"] + jnp.arange(x.shape[1])[None, :]
        a, new_cache = attention(
            p["attn"], h, cfg, cache=cache, positions=positions,
            causal=True, window=window, build_cache=build_state,
            cache_headroom=cache_headroom,
        )
        x = x + a
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        ff = moe(p["moe"], h, cfg) if kind == "attn_moe" else swiglu(p["mlp"], h)
        return x + ff, new_cache
    if kind == "ssm":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y, new_state = mamba2_block(p["ssm"], h, cfg, state=state,
                                    build_state=build_state)
        return x + y, new_state
    if kind == "rglru_mlp":
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        y, new_state = rglru_block(p["rglru"], h, cfg, state=state,
                                   build_state=build_state)
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        return x + swiglu(p["mlp"], h), new_state
    raise ValueError(kind)


def _backbone(
    params: Params,
    tokens: jax.Array,
    cfg: ArchConfig,
    patch_embeds: Optional[jax.Array],
    remat: bool = True,
    remat_policy=None,
) -> jax.Array:
    """Embedding -> layer stacks -> final norm; (B, S, d) pre-unembedding,
    sequence-replicated (constrain_head)."""
    x = params["emb"]["tok"][tokens]
    if cfg.family == "vlm":
        assert patch_embeds is not None, "vlm needs stub patch embeddings"
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    x = constrain_acts(x)

    for stack_params, (kind, _count) in zip(params["stacks"], _stack_layout(cfg)):

        def body(h, layer_p, kind=kind):
            out, _ = _block_apply(cfg, kind, layer_p, h, None, 0)
            return constrain_acts(out), None

        body_fn = (jax.checkpoint(body, policy=remat_policy)
                   if remat else body)
        x, _ = jax.lax.scan(body_fn, x, stack_params)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm":
        x = x[:, patch_embeds.shape[1] :]  # logits over text positions
    return constrain_head(x)


def _unemb(params: Params) -> jax.Array:
    unemb = params["emb"].get("unemb")
    if unemb is None:
        unemb = params["emb"]["tok"].T
    return unemb


def lm_forward(
    params: Params,
    tokens: jax.Array,                      # (B, S) int32
    cfg: ArchConfig,
    patch_embeds: Optional[jax.Array] = None,  # (B, P, d) VLM stub frontend
    remat: bool = True,
) -> jax.Array:
    x = _backbone(params, tokens, cfg, patch_embeds, remat)
    return constrain_logits(mask_vocab_pad(x @ _unemb(params), cfg))


def lm_loss(
    params: Params,
    tokens: jax.Array,
    labels: jax.Array,
    cfg: ArchConfig,
    patch_embeds: Optional[jax.Array] = None,
    ce_chunk: int = 256,
    remat_policy=None,
) -> jax.Array:
    """Chunked cross entropy: the unembedding matmul + CE are evaluated
    per ``ce_chunk`` positions under remat, so only one (B, chunk, V/tp)
    fp32 logits block is ever live (a monolithic (B, S, V/tp) fp32 logits
    + softmax + grad set was ~10 GB/device on the qwen train cells)."""
    x = _backbone(params, tokens, cfg, patch_embeds,
                  remat_policy=remat_policy)
    unemb = _unemb(params)
    b, s, d = x.shape
    chunk = ce_chunk if (ce_chunk and s % ce_chunk == 0) else s
    nc = s // chunk

    def body(acc, inp):
        xc, lc = inp                               # (B, chunk, d), (B, chunk)
        logits = mask_vocab_pad(xc @ unemb, cfg)
        return acc + softmax_cross_entropy(logits, lc).sum(), None

    xcs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lcs = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (xcs, lcs))
    return total / (b * s)


def lm_prefill(
    params: Params,
    tokens: jax.Array,                      # (B, S) int32
    cfg: ArchConfig,
    patch_embeds: Optional[jax.Array] = None,
    cache_headroom: int = 0,
):
    """Prefill: full causal forward that also materializes decode state.

    Returns ``(last_logits (B, 1, V), states)`` where ``states`` matches
    :func:`init_decode_state` layout (KV caches hold exactly the prefill
    context; windowed caches are ring-rotated; SSM/LRU states are the
    post-sequence recurrent states).
    """
    x = params["emb"]["tok"][tokens]
    if cfg.family == "vlm":
        assert patch_embeds is not None, "vlm needs stub patch embeddings"
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    x = constrain_acts(x)

    states = []
    for stack_params, (kind, _count) in zip(params["stacks"], _stack_layout(cfg)):

        def body(h, layer_p, kind=kind):
            out, st = _block_apply(cfg, kind, layer_p, h, None, 0,
                                   build_state=True,
                                   cache_headroom=cache_headroom)
            return constrain_acts(out), st

        x, st = jax.lax.scan(jax.checkpoint(body), x, stack_params)
        if kind in ("attn_mlp", "attn_moe"):
            st = {"k": st["k"], "v": st["v"], "len": st["len"][0]}
        states.append(st)

    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    x = constrain_head(x)
    unemb = params["emb"].get("unemb")
    if unemb is None:
        unemb = params["emb"]["tok"].T
    return mask_vocab_pad(x @ unemb, cfg), states


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, ctx: int, dtype=jnp.bfloat16,
                      kv_int8: bool = False):
    """Pre-allocated decode state per stack (stacked over layers).

    ``kv_int8``: store K/V symmetric-quantized per (token, kv-head) with
    fp32 scales — halves the cache footprint and HBM traffic of decode
    (the serve-step bottleneck)."""
    states = []
    for kind, count in _stack_layout(cfg):
        if kind in ("attn_mlp", "attn_moe"):
            eff_ctx = min(ctx, cfg.window) if cfg.window else ctx
            shape = (count, batch, eff_ctx, cfg.n_kv, cfg.hd)
            kv = {
                "k": jnp.zeros(shape, jnp.int8 if kv_int8 else dtype),
                "v": jnp.zeros(shape, jnp.int8 if kv_int8 else dtype),
                "len": jnp.zeros((), jnp.int32),
            }
            if kv_int8:
                kv["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
                kv["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            states.append(kv)
        elif kind == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            heads = d_in // cfg.ssm_head_dim
            conv_dim = d_in + 2 * cfg.ssm_state
            states.append({
                "conv": jnp.zeros((count, batch, cfg.ssm_conv, conv_dim), dtype),
                "ssm": jnp.zeros(
                    (count, batch, heads, cfg.ssm_state, cfg.ssm_head_dim), dtype
                ),
            })
        elif kind == "rglru_mlp":
            w = cfg.lru_width or cfg.d_model
            states.append({
                "conv": jnp.zeros((count, batch, cfg.ssm_conv, w), dtype),
                "lru": jnp.zeros((count, batch, w), dtype),
            })
        elif kind == "hybrid_group":
            w = cfg.lru_width or cfg.d_model
            eff_ctx = min(ctx, cfg.window) if cfg.window else ctx
            group = {}
            for i in range(cfg.hybrid_period - 1):
                group[f"s{i}"] = {
                    "conv": jnp.zeros((count, batch, cfg.ssm_conv, w), dtype),
                    "lru": jnp.zeros((count, batch, w), dtype),
                }
            group[f"s{cfg.hybrid_period - 1}"] = {
                "k": jnp.zeros((count, batch, eff_ctx, cfg.n_kv, cfg.hd), dtype),
                "v": jnp.zeros((count, batch, eff_ctx, cfg.n_kv, cfg.hd), dtype),
                # per-layer lens thread through the decode scan as xs/ys
                "len": jnp.zeros((count,), jnp.int32),
            }
            states.append(group)
    return states


def _stack_layout(cfg: ArchConfig):
    kinds = _layer_kinds(cfg)
    segs = []
    for kind in kinds:
        if segs and segs[-1][0] == kind:
            segs[-1][1] += 1
        else:
            segs.append([kind, 1])
    return [(k, c) for k, c in segs]


def lm_decode_step(
    params: Params,
    states,
    token: jax.Array,       # (B, 1) int32
    cfg: ArchConfig,
):
    """One decode step: returns (logits (B, 1, V), new_states)."""
    x = constrain_acts(params["emb"]["tok"][token])
    new_states = []
    for stack_params, state, (kind, _count) in zip(
        params["stacks"], states, _stack_layout(cfg)
    ):
        # thread per-layer state through the scan as xs/ys
        if kind in ("attn_mlp", "attn_moe"):
            per_layer = {k2: v2 for k2, v2 in state.items() if k2 != "len"}
            shared_len = state["len"]

            def body_kv(h, xs, kind=kind, shared_len=shared_len):
                layer_p, st = xs
                cache = {**st, "len": shared_len}
                out, nc = _block_apply(cfg, kind, layer_p, h, cache, 0)
                return out, {k2: nc[k2] for k2 in st}

            x, new_kv = jax.lax.scan(body_kv, x, (stack_params, per_layer))
            new_states.append({**new_kv, "len": shared_len + token.shape[1]})
        else:

            def body(h, xs, kind=kind):
                layer_p, layer_state = xs
                out, new_state = _block_apply(cfg, kind, layer_p, h, layer_state, 0)
                return out, new_state

            x, new_state = jax.lax.scan(body, x, (stack_params, state))
            new_states.append(new_state)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = constrain_head(x)
    unemb = params["emb"].get("unemb")
    if unemb is None:
        unemb = params["emb"]["tok"].T
    return constrain_logits(mask_vocab_pad(x @ unemb, cfg)), new_states
