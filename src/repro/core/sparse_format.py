"""Compressed weight formats and static iteration schedules (paper §III-C/D).

The paper stores conv kernels in a merged-row-index COO format:

    W.RI = oc * IC + ic        (row index over the flattened (OC, IC) grid)
    W.CI = kernel column       (position within the 1-D kernel window)
    W.D  = 16-bit weight value

sorted in **output-channel order** so the accelerator can stream one output
channel at a time.  Because kernels are fixed at inference, every dataflow
irregularity induced by sparsity — *empty iterations* (input channel not yet
streamed in) and *extra iterations* (output channel with no non-zero weight)
— is precomputed here into a **static schedule** (paper Algorithm 2).

For the TPU kernel we additionally re-block the same sparse kernel into an
MXU-friendly **static block-sparse** layout: the flattened weight matrix
W'(OC, IC*KW) is tiled, empty tiles are dropped, and each row of tiles is
padded to a fixed per-row tile count with explicit no-op tiles — the direct
analogue of the paper's embedded empty/extra iterations (static schedule,
zero dynamic control flow).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "CooKernel",
    "coo_from_dense",
    "coo_to_dense",
    "coo_bit_widths",
    "coo_storage_bits",
    "dense_storage_bits",
    "break_even_density",
    "Schedule",
    "build_schedule",
    "WeightMask",
    "weight_mask_from_dense",
    "BlockSparseKernel",
    "block_sparse_from_dense",
    "block_sparse_to_dense",
]

# Iteration kinds in the static schedule (paper Algorithm 2).
ITER_COMPUTE = 0  # a real non-zero weight accumulation
ITER_EXTRA = 1    # output channel with no nnz: load/decay/emit/store only
ITER_EMPTY = 2    # stall slot: wait for an input channel to stream in


@dataclasses.dataclass(frozen=True)
class CooKernel:
    """Merged-row-index COO conv kernel (paper Fig. 5, eqs. (1)-(2)).

    A 1-D conv kernel of shape (KW, IC, OC) with entries sorted by
    (oc, ic, ci) — output-channel-major, matching the streaming order.
    """

    data: np.ndarray      # (nnz,) weight values
    row_idx: np.ndarray   # (nnz,) int32, RI = oc * IC + ic
    col_idx: np.ndarray   # (nnz,) int32, CI = kernel column in [0, KW)
    kw: int
    ic: int
    oc: int

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def density(self) -> float:
        total = self.kw * self.ic * self.oc
        return self.nnz / total if total else 0.0

    def oc_of(self, i: int) -> int:
        return int(self.row_idx[i]) // self.ic  # eq. (2)

    def ic_of(self, i: int) -> int:
        return int(self.row_idx[i]) % self.ic   # eq. (1)


def coo_from_dense(kernel: np.ndarray) -> CooKernel:
    """kernel: (KW, IC, OC) dense -> COO sorted by (oc, ic, ci)."""
    if kernel.ndim != 3:
        raise ValueError(f"expected (KW, IC, OC) kernel, got {kernel.shape}")
    kw, ic, oc = kernel.shape
    ci_g, ic_g, oc_g = np.nonzero(kernel)
    # sort output-channel-major, then input channel, then kernel column
    order = np.lexsort((ci_g, ic_g, oc_g))
    ci_g, ic_g, oc_g = ci_g[order], ic_g[order], oc_g[order]
    data = kernel[ci_g, ic_g, oc_g]
    row = (oc_g * ic + ic_g).astype(np.int32)
    return CooKernel(
        data=np.asarray(data),
        row_idx=row,
        col_idx=ci_g.astype(np.int32),
        kw=kw,
        ic=ic,
        oc=oc,
    )


def coo_to_dense(coo: CooKernel) -> np.ndarray:
    out = np.zeros((coo.kw, coo.ic, coo.oc), dtype=coo.data.dtype)
    oc = coo.row_idx // coo.ic
    ic = coo.row_idx % coo.ic
    out[coo.col_idx, ic, oc] = coo.data
    return out


def coo_bit_widths(kw: int, ic: int, oc: int, data_bits: int = 16) -> Tuple[int, int, int]:
    """(W.D, W.RI, W.CI) bit widths as in paper Table II."""
    ri_bits = max(1, int(np.ceil(np.log2(ic * oc))))
    ci_bits = max(1, int(np.ceil(np.log2(kw))))
    return data_bits, ri_bits, ci_bits


def dense_storage_bits(kw: int, ic: int, oc: int, data_bits: int = 16) -> int:
    return kw * ic * oc * data_bits


def coo_storage_bits(kw: int, ic: int, oc: int, density: float, data_bits: int = 16) -> float:
    d, ri, ci = coo_bit_widths(kw, ic, oc, data_bits)
    return (d + ri + ci) * kw * ic * oc * density


def break_even_density(kw: int, ic: int, oc: int, data_bits: int = 16) -> float:
    """Density below which COO is more bit-efficient than dense (Table II)."""
    d, ri, ci = coo_bit_widths(kw, ic, oc, data_bits)
    return data_bits / (d + ri + ci)


# ---------------------------------------------------------------------------
# Static schedule (Algorithm 2): NNZ + extra + empty iterations precomputed.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Schedule:
    """Fixed-length iteration schedule for one conv layer.

    Every entry is one accelerator iteration.  ``kind`` selects compute /
    extra / empty; compute entries carry the weight value and its (oc, ic,
    ci) coordinates; extra entries carry the oc whose state must be
    decayed/emitted; empty entries are pure stalls.  ``emit`` marks the last
    iteration touching an output channel (fire + store + stream out).
    """

    kind: np.ndarray     # (reps,) int32 in {COMPUTE, EXTRA, EMPTY}
    weight: np.ndarray   # (reps,) float; 0 for non-compute entries
    oc: np.ndarray       # (reps,) int32; channel acted upon (-1 for empty)
    ic: np.ndarray       # (reps,) int32; input channel (-1 if n/a)
    ci: np.ndarray       # (reps,) int32; kernel column (0 if n/a)
    emit: np.ndarray     # (reps,) bool; True -> fire/emit/store this oc now
    n_compute: int
    n_extra: int
    n_empty: int

    @property
    def reps(self) -> int:
        return int(self.kind.shape[0])


def build_schedule(coo: CooKernel) -> Schedule:
    """Precompute the Algorithm-2 iteration schedule for a COO kernel.

    Semantics follow Algorithm 2 exactly: **every iteration slot ingests at
    most one input channel, in streaming order** (lines 10-13: ``if IC_read
    < IC then Input I[ic]; IC_read += 1``).  A compute iteration for a
    weight needing input channel ``ic`` can only run once ``ic < IC_read``
    after the slot's ingest (line 22); otherwise the slot is an *empty
    iteration* (pure stall).  An output channel with no nnz weights gets an
    *extra iteration* (load, decay, emit, store — lines 14-19).  The last
    iteration touching each output channel is flagged ``emit``.

    Consequently empty iterations can only occupy the first IC slots of the
    schedule (once the input buffer is full they are impossible) — the
    paper's "empty iterations occur only during the first output channel".
    """
    kinds, weights, ocs, ics, cis, emits = [], [], [], [], [], []

    oc_of = coo.row_idx // coo.ic
    ic_of = coo.row_idx % coo.ic

    ic_read = 0   # input channels streamed in so far
    ptr = 0       # index into nnz list

    def ingest():
        nonlocal ic_read
        ic_read = min(ic_read + 1, coo.ic)

    for oc in range(coo.oc):
        start = ptr
        while ptr < coo.nnz and int(oc_of[ptr]) == oc:
            ptr += 1
        end = ptr
        if start == end:
            # extra iteration: decay + emit a channel with no nnz weights
            kinds.append(ITER_EXTRA)
            weights.append(0.0)
            ocs.append(oc)
            ics.append(-1)
            cis.append(0)
            emits.append(True)
            ingest()  # the slot still ingests one streaming channel
            continue
        for j in range(start, end):
            need_ic = int(ic_of[j])
            # stall (empty iterations) until the needed channel has arrived;
            # each stall slot ingests exactly one more channel
            while need_ic >= min(ic_read + 1, coo.ic):
                kinds.append(ITER_EMPTY)
                weights.append(0.0)
                ocs.append(-1)
                ics.append(min(ic_read, coo.ic - 1))
                cis.append(0)
                emits.append(False)
                ingest()
            kinds.append(ITER_COMPUTE)
            weights.append(float(coo.data[j]))
            ocs.append(oc)
            ics.append(need_ic)
            cis.append(int(coo.col_idx[j]))
            emits.append(j == end - 1)
            ingest()

    kind = np.asarray(kinds, dtype=np.int32)
    n_compute = int((kind == ITER_COMPUTE).sum())
    n_extra = int((kind == ITER_EXTRA).sum())
    n_empty = int((kind == ITER_EMPTY).sum())
    return Schedule(
        kind=kind,
        weight=np.asarray(weights, dtype=np.float32),
        oc=np.asarray(ocs, dtype=np.int32),
        ic=np.asarray(ics, dtype=np.int32),
        ci=np.asarray(cis, dtype=np.int32),
        emit=np.asarray(emits, dtype=bool),
        n_compute=n_compute,
        n_extra=n_extra,
        n_empty=n_empty,
    )


# ---------------------------------------------------------------------------
# Weight mask (paper §III-B, Fig. 2) — FC layers.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeightMask:
    """1-bit-per-weight mask for an FC weight matrix (IN, OUT)."""

    weights: np.ndarray  # (IN, OUT) with zeros at masked positions
    mask: np.ndarray     # (IN, OUT) bool, True where weight != 0

    @property
    def density(self) -> float:
        return float(self.mask.mean())

    def fetch_mask(self, spikes: np.ndarray) -> np.ndarray:
        """FM = IFM AND WM: which weights must actually be fetched."""
        s = np.asarray(spikes).astype(bool)
        return s[..., :, None] & self.mask  # (..., IN, OUT)


def weight_mask_from_dense(weights: np.ndarray) -> WeightMask:
    w = np.asarray(weights)
    mask = w != 0
    return WeightMask(weights=w * mask, mask=mask)


# ---------------------------------------------------------------------------
# Static block-sparse layout (TPU adaptation of the COO schedule).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSparseKernel:
    """W'(OC, K=IC*KW) tiled into (block_oc, block_k) tiles.

    Per oc-tile row, only non-empty tiles are kept and the list is padded to
    the max per-row count with zero tiles pointing at k-tile 0 — a no-op
    contribution, mirroring the paper's precomputed extra/empty iterations.
    The resulting arrays drive a Pallas kernel with a *static* grid.
    """

    blocks: np.ndarray       # (n_oc_tiles, max_tiles, block_oc, block_k)
    block_cols: np.ndarray   # (n_oc_tiles, max_tiles) int32 k-tile index
    tile_valid: np.ndarray   # (n_oc_tiles, max_tiles) bool
    n_tiles_per_row: np.ndarray  # (n_oc_tiles,) int32
    oc: int
    k: int                   # IC * KW (flattened reduction dim)
    kw: int
    ic: int
    block_oc: int
    block_k: int

    @property
    def n_oc_tiles(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def max_tiles(self) -> int:
        return int(self.blocks.shape[1])

    @property
    def padded_oc(self) -> int:
        return self.n_oc_tiles * self.block_oc

    @property
    def padded_k(self) -> int:
        return int(-(-self.k // self.block_k)) * self.block_k

    @property
    def tile_density(self) -> float:
        total = self.n_oc_tiles * (self.padded_k // self.block_k)
        return float(self.n_tiles_per_row.sum()) / total if total else 0.0


def _flatten_kernel(kernel: np.ndarray) -> np.ndarray:
    """(KW, IC, OC) -> W'(OC, IC*KW) with K index = ic * KW + ci.

    The K ordering matches the shifted-input buffer built by
    ``goap.build_shift_buffer`` (row ic*KW+ci holds I[ic] shifted by ci).
    """
    kw, ic, oc = kernel.shape
    # -> (OC, IC, KW) -> (OC, IC*KW)
    return np.transpose(kernel, (2, 1, 0)).reshape(oc, ic * kw)


def block_sparse_from_dense(
    kernel: np.ndarray, block_oc: int = 8, block_k: int = 128
) -> BlockSparseKernel:
    kw, ic, oc = kernel.shape
    w = _flatten_kernel(kernel)
    k = ic * kw
    pad_oc = (-oc) % block_oc
    pad_k = (-k) % block_k
    w = np.pad(w, ((0, pad_oc), (0, pad_k)))
    n_oc_tiles = w.shape[0] // block_oc
    n_k_tiles = w.shape[1] // block_k

    tiles = w.reshape(n_oc_tiles, block_oc, n_k_tiles, block_k).transpose(0, 2, 1, 3)
    nonempty = np.abs(tiles).sum(axis=(2, 3)) != 0  # (n_oc_tiles, n_k_tiles)
    counts = nonempty.sum(axis=1).astype(np.int32)
    max_tiles = max(1, int(counts.max()) if counts.size else 1)

    blocks = np.zeros((n_oc_tiles, max_tiles, block_oc, block_k), dtype=kernel.dtype)
    block_cols = np.zeros((n_oc_tiles, max_tiles), dtype=np.int32)
    tile_valid = np.zeros((n_oc_tiles, max_tiles), dtype=bool)
    for r in range(n_oc_tiles):
        cols = np.nonzero(nonempty[r])[0]
        for j, c in enumerate(cols):
            blocks[r, j] = tiles[r, c]
            block_cols[r, j] = c
            tile_valid[r, j] = True
        # padding tiles: zero data @ k-tile 0 -> no-op accumulation
    return BlockSparseKernel(
        blocks=blocks,
        block_cols=block_cols,
        tile_valid=tile_valid,
        n_tiles_per_row=counts,
        oc=oc,
        k=k,
        kw=kw,
        ic=ic,
        block_oc=block_oc,
        block_k=block_k,
    )


def block_sparse_to_dense(bs: BlockSparseKernel) -> np.ndarray:
    """Inverse of ``block_sparse_from_dense`` -> (KW, IC, OC)."""
    n_k_tiles = bs.padded_k // bs.block_k
    w = np.zeros((bs.n_oc_tiles, n_k_tiles, bs.block_oc, bs.block_k), dtype=bs.blocks.dtype)
    for r in range(bs.n_oc_tiles):
        for j in range(bs.max_tiles):
            if bs.tile_valid[r, j]:
                w[r, bs.block_cols[r, j]] = bs.blocks[r, j]
    w = w.transpose(0, 2, 1, 3).reshape(bs.n_oc_tiles * bs.block_oc, n_k_tiles * bs.block_k)
    w = w[: bs.oc, : bs.k]  # strip padding
    # (OC, IC*KW) -> (KW, IC, OC)
    return w.reshape(bs.oc, bs.ic, bs.kw).transpose(2, 1, 0)
