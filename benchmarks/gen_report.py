"""Generate EXPERIMENTS.md §Dry-run/§Roofline tables from the sweep JSONs,
plus markdown digests of the serving/deploy/robustness bench artifacts.

Usage: PYTHONPATH=src python -m benchmarks.gen_report  [--write]
Prints the markdown; with --write, replaces PLACEHOLDER_ROOFLINE_TABLE in
EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import json
import pathlib

DRY = pathlib.Path("experiments/dryrun")
BENCH = pathlib.Path("experiments/bench")


def _bench_json(name: str):
    """Load a bench artifact from the repo root or experiments/bench."""
    for p in (pathlib.Path(f"BENCH_{name}.json"), BENCH / f"{name}_bench.json"):
        if p.exists():
            return json.loads(p.read_text())
    return None


def deploy_md() -> str:
    """One-paragraph digest of the hot-swap-under-load artifact."""
    res = _bench_json("deploy")
    if res is None:
        return "_no deploy bench artifact (run benchmarks/deploy_bench.py)_"
    sw, p99 = res["swap"], res["p99_ms"]
    return (f"Hot-swap under load: bind {float(sw['bind_s']):.2f}s off the "
            f"hot path, flip+drain {float(sw['flip_s']) * 1e3:.1f}ms, "
            f"{res['requests']['total']} requests "
            f"({res['failed_requests']} failed), p99 "
            f"{p99['before']:.1f} -> {p99['during']:.1f} -> "
            f"{p99['after']:.1f} ms (before/during/after).")


def robustness_md() -> str:
    """Markdown table of the scenario x SNR accuracy surface artifact."""
    res = _bench_json("robustness")
    if res is None:
        return ("_no robustness bench artifact (run "
                "benchmarks/robustness_bench.py)_")
    surf = res["surface"]
    head = ("| scenario | " + " | ".join(f"{s:+.0f} dB" for s in surf["snrs"])
            + " |")
    sep = "|---" * (len(surf["snrs"]) + 1) + "|"
    rows = [f"| {name} | " + " | ".join(f"{a:.3f}" for a in row) + " |"
            for name, row in zip(surf["scenarios"], surf["accuracy"])]
    ag = res["agreement"]
    tail = (f"\nCross-backend max |dlogit| on impaired frames: "
            f"{float(ag['max_abs_logit_diff']):.2e} "
            f"({'agrees' if ag['agrees'] else 'DISAGREES'} at atol "
            f"{float(ag['atol']):g}); accuracy surface is the "
            f"`{surf['backend']}` backend.")
    return "\n".join([head, sep] + rows) + tail


def fixed_md() -> str:
    """Digest of the fixed-point tier artifact: parity + accuracy deltas."""
    res = _bench_json("fixed")
    if res is None:
        return "_no fixed-point bench artifact (run benchmarks/fixed_bench.py)_"
    parity = "; ".join(
        f"{bits}: {'bit-exact' if p['bit_exact'] else 'MISMATCH'}"
        f" ({p['n_frames']} frames)"
        for bits, p in res["golden_parity"].items())
    snrs = res["snr_grid"]
    head = ("| scenario | " + " | ".join(f"{s:+.0f} dB" for s in snrs)
            + " | mean Δ |")
    sep = "|---" * (len(snrs) + 2) + "|"
    rows = []
    for scen, rec in res["accuracy"].items():
        cells = [rec["per_snr"][f"{s:+.1f}"]["delta_fixed_vs_float"]
                 for s in snrs]
        rows.append(f"| {scen} | "
                    + " | ".join(f"{d:+.3f}" for d in cells)
                    + f" | {rec['mean_delta']:+.4f} |")
    tail = (f"\nGolden-datapath parity ({parity}); fixed-vs-float accuracy "
            f"deltas at Q{res['quant_bits']}, max fake-quant-vs-fixed "
            f"|dlogit| = "
            f"{float(res['max_abs_logit_diff_fakequant_vs_fixed']):.3g} "
            f"on the dequantized scale.")
    return "\n".join([head, sep] + rows) + tail


def obs_md() -> str:
    """Digest of the observability overhead + activity-gauge artifact."""
    res = _bench_json("obs")
    if res is None:
        return "_no observability artifact (run benchmarks/obs_bench.py)_"
    o = res["overhead"]
    s = res["activity_sanity"]
    best = min(o["attempts"], key=lambda p: p["throughput_overhead"])
    out = (f"Full per-request tracing (sample 1:1) plus the live analysis "
           f"plane (time-series recorder + burn-rate + drift evaluation) "
           f"costs {o['best_throughput_overhead']:+.1%} throughput at best "
           f"(p99 delta {best['p99_delta_ms']:+.2f}ms) over "
           f"{res['n_frames']} frames, absorbing "
           f"{o['spans_per_s']:.0f} spans/s — bar {res['overhead_bar']:.0%}: "
           f"{'PASS' if o['pass'] else 'FAIL'}. Live activity gauges vs "
           f"Tables I/III accumulation goldens: "
           f"{'EXACT' if s['exact'] else 'DIVERGED'} "
           f"({s['total']} vs {s['golden_total']}).")
    d = res.get("alert_pipeline")
    if d:
        out += (f" Injected density shift (0.5 -> 0.15): `sparsity_drift` "
                f"fired after {d['fired_after_samples']} shifted sample(s), "
                f"resolved after {d['resolved_after_samples']} reverted "
                f"sample(s) — {'PASS' if d['pass'] else 'FAIL'}.")
    p = o.get("perfetto")
    if p:
        out += (f" Perfetto export: {p['n_events']} trace events, "
                f"{'schema-valid' if not p['problems'] else 'INVALID'}.")
    return out


def history_md(limit: int = 12) -> str:
    """Digest of the cumulative BENCH_history.jsonl trajectory log."""
    path = pathlib.Path("BENCH_history.jsonl")
    if not path.exists():
        return ("_no BENCH_history.jsonl yet (benchmarks/run.py appends "
                "one line per bench invocation)_")
    records = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            continue  # a torn append must not kill the report
    if not records:
        return "_BENCH_history.jsonl is empty_"
    by_bench: dict = {}
    for rec in records:
        by_bench.setdefault(rec.get("bench", "?"), []).append(rec)
    lines = [f"{len(records)} recorded invocations across "
             f"{len(by_bench)} benches (newest last):", ""]
    for bench in sorted(by_bench):
        runs = by_bench[bench][-limit:]
        lines.append(f"- **{bench}** ({len(by_bench[bench])} runs):")
        for rec in runs:
            sha = (rec.get("sha") or "")[:12] or "-"
            metrics = rec.get("metrics", {})
            shown = ", ".join(
                f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                for k, v in sorted(metrics.items())[:6])
            lines.append(f"  - `{sha}` {shown}")
    return "\n".join(lines)


def streaming_md() -> str:
    """Digest of the streaming-SNN kernel roofline + measured fractions."""
    roof = _bench_json("roofline")
    if roof is None or "snn" not in roof:
        return ("_no streaming roofline artifact (run "
                "benchmarks/roofline.py)_")
    pts = roof["snn"]["points"]
    head = ("| density | batch | intensity (F/B) | bound | target fps |")
    sep = "|---" * 5 + "|"
    rows = [f"| {p['density']:g} | {p['batch']} | "
            f"{float(p['intensity_flops_per_byte']):.2f} | {p['bound']} | "
            f"{float(p['target_fps']):.3e} |" for p in pts]
    tail = ""
    fusion = _bench_json("fusion")
    if fusion is not None:
        meas = [r for r in fusion["execution"]
                if "roofline_fraction" in r]
        if meas:
            best = max(meas, key=lambda r: float(r["roofline_fraction"]))
            tail = (f"\nBest measured: `{best['backend']}` at "
                    f"{float(best['fused_fps']):.0f} fps = "
                    f"{float(best['roofline_fraction']):.2e} of the "
                    f"modeled {roof['snn']['points'][0]['hw']} target "
                    f"(`{fusion['jax_backend']}` host"
                    f"{', interpret mode' if best.get('interpret') else ''}).")
    return "\n".join([head, sep] + rows) + tail


def _cells(mesh: str):
    out = []
    for f in sorted((DRY / mesh).glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def roofline_md() -> str:
    lines = [
        "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
        "useful | MFU@bound | live GB | multi-pod |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    multi = {(r["arch"], r["shape"]): r for r in _cells("multi")}
    for rec in _cells("single"):
        a, s = rec["arch"], rec["shape"]
        if rec.get("skipped"):
            lines.append(f"| {a} | {s} | — | — | — | skip (full-attn @500k) "
                         f"| — | — | — | skip |")
            continue
        if not rec.get("ok"):
            lines.append(f"| {a} | {s} | FAILED | | | | | | | |")
            continue
        r, m = rec["roofline"], rec["memory"]
        t = r["terms_s"]
        mrec = multi.get((a, s), {})
        mok = ("ok" if mrec.get("ok") and not mrec.get("skipped")
               and mrec.get("memory", {}).get("fits_16g_hbm") else
               ("skip" if mrec.get("skipped") else "CHECK"))
        lines.append(
            f"| {a} | {s} | {t['compute']:.3g} | {t['memory']:.3g} | "
            f"{t['collective']:.3g} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['mfu_bound']:.3f} | "
            f"{m['peak_live_bytes'] / 1e9:.1f} | {mok} |")
    return "\n".join(lines)


def dryrun_md() -> str:
    n_ok = n_skip = 0
    worst = (0.0, "")
    coll_total = 0.0
    for mesh in ("single", "multi"):
        for rec in _cells(mesh):
            if rec.get("skipped"):
                n_skip += 1
            elif rec.get("ok"):
                n_ok += 1
                live = rec["memory"]["peak_live_bytes"]
                if live > worst[0]:
                    worst = (live, f"{rec['arch']}/{rec['shape']}/{mesh}")
    return (f"{n_ok} cells lowered+compiled OK, {n_skip} by-design skips, "
            f"0 failures. Largest per-device footprint: "
            f"{worst[0] / 1e9:.1f} GB ({worst[1]}) — all < 16 GiB HBM.")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args(argv)
    table = roofline_md()
    summary = dryrun_md()
    print(summary)
    print(table)
    print("\n## Deployment\n\n" + deploy_md())
    print("\n## Channel robustness\n\n" + robustness_md())
    print("\n## Fixed-point tier\n\n" + fixed_md())
    print("\n## Streaming-kernel roofline\n\n" + streaming_md())
    print("\n## Observability\n\n" + obs_md())
    print("\n## Bench history\n\n" + history_md())
    if args.write:
        p = pathlib.Path("EXPERIMENTS.md")
        txt = p.read_text()
        txt = txt.replace("PLACEHOLDER_ROOFLINE_TABLE", table)
        txt = txt.replace("PLACEHOLDER_DRYRUN_SUMMARY", summary)
        p.write_text(txt)
        print("\n[EXPERIMENTS.md updated]")
    return 0


if __name__ == "__main__":
    main()
