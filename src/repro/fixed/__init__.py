"""Fixed-point hardware-parity tier (paper §IV-C): the ``fixed`` backend.

Importing this package registers the ``fixed`` execution backend (integer
inference: int weight codes, int32 accumulation, int16 saturating LIF
membranes with shift-based leak, integer Sigma-Delta encoding) with the
layer-graph registry.  ``repro.models.graph.get_backend`` imports it
lazily, so ``backend="fixed"`` works without an explicit import.
"""
from repro.fixed import backend as _backend  # noqa: F401  (registers "fixed")
from repro.fixed.encoder import (
    fixed_encode_batch,
    fixed_encode_frames,
    fixed_sigma_delta_encode,
)
from repro.fixed.golden import GoldenNet, build_golden, golden_encode_frames
from repro.fixed.quantize import (
    FIXED_DEFAULT_BITS,
    FixedLIF,
    FixedQuantFn,
    QuantizedLayer,
    assignment_uses_fixed,
    calibrate_step,
    derive_fixed_layer,
    fixed_logit_scale,
    lif_to_fixed,
    quantize_codes,
    serving_quant_fn,
)

__all__ = [
    "FIXED_DEFAULT_BITS",
    "FixedLIF",
    "FixedQuantFn",
    "QuantizedLayer",
    "GoldenNet",
    "assignment_uses_fixed",
    "build_golden",
    "calibrate_step",
    "derive_fixed_layer",
    "fixed_encode_batch",
    "fixed_encode_frames",
    "fixed_logit_scale",
    "fixed_sigma_delta_encode",
    "golden_encode_frames",
    "lif_to_fixed",
    "quantize_codes",
    "serving_quant_fn",
]
