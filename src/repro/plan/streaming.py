"""Fused single-scan inter-layer executor (the paper's streaming pipeline).

The accelerator streams spikes through all layers concurrently with zero
inter-layer buffering of whole timestep sequences (paper §III, Fig. 6).
The jax analogue: instead of one ``lax.scan`` per layer materializing the
full (T, ...) activation sequence before the next layer starts
(``BoundProgram.run``), :func:`run_streaming` threads *every* layer's
carried state — conv/FC membrane potentials, stream-counter accumulators,
the readout sum — through a **single** scan over timesteps.  Per timestep
each frame flows through the whole cell chain, so no intermediate
sequence is ever materialized.

Because every cell is causal per timestep (layer *l*'s output at *t*
depends only on its state and its input at *t*), the fusion is exact:
logits agree with the layer-by-layer path for every backend (validated at
atol <= 1e-5 in ``tests/test_plan.py``), and the ``stream`` backend's
Tables I/III counters come out identical.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax

from repro.models.graph import KIND_READOUT, LayerCell, timestep_template

__all__ = ["init_stream_states", "run_streaming", "profile_layer_steps"]


def init_stream_states(cells: Sequence[LayerCell], x0) -> Tuple:
    """Initial carried state of every cell, chained through the graph.

    ``x0`` is the per-timestep input template of the *first* layer; each
    subsequent layer's template is inferred by abstract evaluation of the
    previous cell's ``step`` (no FLOPs run here).
    """
    states = []
    x = x0
    for cell in cells:
        state = cell.init_state(x)
        states.append(state)
        _, x = jax.eval_shape(cell.step, state, x)
    return tuple(states)


def run_streaming(plan, frames: jax.Array):
    """Execute an ExecutionPlan in one fused scan over timesteps.

    frames: (T, IC0, W) binary spike frames.  Returns ``(logits,
    counters)`` with the same contract as ``BoundProgram.run``: counters
    carries the per-conv-layer iteration counts when the ``stream``
    backend is assigned (empty otherwise).

    When every weighted layer is assigned ``pallas_fused`` the scan
    collapses into one multi-layer Pallas kernel launch with all LIF
    state in VMEM (:mod:`repro.kernels.stream_fused`); its counters are
    the same Tables I/III quantities, computed in-kernel.
    """
    from repro.kernels.stream_fused import (
        fused_counters,
        fused_stack_of,
        stream_fused_forward,
    )

    stack = fused_stack_of(plan)
    if stack is not None:
        logits, accs = stream_fused_forward(stack, frames[None])
        return logits[0], fused_counters(stack, accs[0])

    cells = [lp.cell for lp in plan.layers]
    states0 = init_stream_states(cells, timestep_template(frames))

    def step(states, frame_t):
        x = frame_t
        new_states = []
        for cell, state in zip(cells, states):
            state, x = cell.step(state, x)
            new_states.append(state)
        return tuple(new_states), x

    states, ys = jax.lax.scan(step, states0, frames)

    logits = None
    counters = {}
    for lp, state in zip(plan.layers, states):
        if lp.cell.finalize is None:
            continue
        out = lp.cell.finalize(state)
        if lp.spec.kind == KIND_READOUT:
            logits = out
        else:
            counters[lp.spec.name] = out
    return (logits if logits is not None else ys), counters


def profile_layer_steps(plan, frames: jax.Array, reps: int = 3,
                        registry=None) -> Dict[str, float]:
    """Wall-time each layer's jitted per-timestep step in isolation (ms).

    An *offline* observability hook — never on the serving path (the
    fused scan has no per-layer boundaries to time).  Each cell's
    ``step`` is jitted and timed standalone on the real state/input
    templates this plan would stream through it: one warm-up call pays
    compilation, then the best of ``reps`` timed loops over T timesteps
    is attributed to the layer.  Results land in the
    ``repro_plan_layer_step_ms{layer,backend}`` gauge (per-layer cost
    split — where the streaming milliseconds actually go) and come back
    as ``{layer_name: ms_per_T_timesteps}``.
    """
    import time

    from repro.obs.metrics import MetricsRegistry, default_registry

    reg: Optional[MetricsRegistry]
    reg = registry if registry is not None else default_registry()
    gauge = reg.gauge(
        "repro_plan_layer_step_ms",
        "Isolated jitted per-layer step time over T timesteps (ms)",
        ("layer", "backend"))

    cells = [lp.cell for lp in plan.layers]
    states0 = init_stream_states(cells, timestep_template(frames))

    out: Dict[str, float] = {}
    # concrete zero input (templates are abstract ShapeDtypeStructs; a
    # jitted call needs real arrays) — layer l+1's template is layer l's
    # actual output, so shapes chain exactly as run_streaming's would
    x = jax.tree_util.tree_map(
        lambda s: jax.numpy.zeros(s.shape, s.dtype),
        timestep_template(frames))
    for lp, state0 in zip(plan.layers, states0):
        step = jax.jit(lp.cell.step)
        state, y = step(state0, x)          # warm-up: compile + templates
        jax.block_until_ready(y)
        best = float("inf")
        for _ in range(max(1, reps)):
            state = state0
            t0 = time.perf_counter()
            for t in range(frames.shape[0]):
                state, y = step(state, x)
            jax.block_until_ready(y)
            best = min(best, time.perf_counter() - t0)
        ms = best * 1e3
        out[lp.spec.name] = ms
        gauge.labels(layer=lp.spec.name, backend=lp.backend).set(ms)
        x = y                               # next layer's input template
    return out
