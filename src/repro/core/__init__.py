"""Core SAOCDS algorithms: sparse formats, GOAP conv, streaming dataflow,
LIF dynamics, sigma-delta encoding and the fetch/cycle/power cost models."""

from .sparse_format import (
    CooKernel,
    coo_from_dense,
    coo_to_dense,
    coo_bit_widths,
    coo_storage_bits,
    dense_storage_bits,
    break_even_density,
    Schedule,
    build_schedule,
    WeightMask,
    weight_mask_from_dense,
    BlockSparseKernel,
    block_sparse_from_dense,
    block_sparse_to_dense,
)
from .goap import (
    conv1d_dense_oracle,
    build_shift_buffer,
    goap_conv_nnz,
    goap_conv_reference,
)
from .lif import LIFParams, init_lif_params, spike, lif_step, lif_unroll
from .encoder import (
    normalize_iq,
    sigma_delta_encode,
    sigma_delta_decode,
    encode_frames,
)
from .saocds import (
    pad_same,
    max_pool_spikes,
    saocds_conv_step,
    saocds_conv_layer,
    sw_conv_layer,
    wm_fc_step,
    wm_fc_layer,
    schedule_interpreter,
)
from .cost_model import (
    ConvCounts,
    sw_conv_counts,
    goap_conv_counts,
    fc_traditional_counts,
    fc_wm_counts,
    bits_fetched,
    CycleModel,
    PowerModel,
    PAPER_TABLE5,
    PAPER_BASELINE,
    fom,
)
