import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and extract the roofline terms from the compiled
artifact.

The two lines above MUST precede any other import (jax locks the device
count on first init); only this entry point sees 512 placeholder devices
— tests and benches keep the 1-CPU view.

Usage:
    # one cell (this is what the sweep spawns)
    python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
    # full sweep (subprocess per cell, resumable)
    python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time
import traceback

__all__ = ["run_cell", "main"]

_RESULTS_DEFAULT = "experiments/dryrun"


def _json_default(o):
    if dataclasses.is_dataclass(o):
        return dataclasses.asdict(o)
    return str(o)


def run_cell(arch: str, shape: str, mesh_kind: str, save_hlo: str = "",
             kv_int8: bool = False) -> dict:
    """Lower + compile one cell on one mesh; return the result record."""
    import jax

    from repro.configs.registry import SHAPES, cell_applicable, get_config
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.launch.mesh import make_production_mesh
    from repro.launch.roofline import roofline_terms
    from repro.launch.steps import build_cell

    rec: dict = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "jax": jax.__version__, "ok": False,
    }
    ok, why = cell_applicable(arch, shape)
    if not ok:
        rec.update(skipped=True, reason=why, ok=True)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = len(mesh.devices.flat)
    rec["chips"] = chips
    rec["mesh_shape"] = dict(mesh.shape)

    t0 = time.perf_counter()
    rec["kv_int8"] = kv_int8
    plan = build_cell(arch, shape, mesh, kv_int8=kv_int8)
    with mesh:
        lowered = plan.lower()
        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

    # ---- memory: proves the per-device program fits HBM ----
    try:
        ma = compiled.memory_analysis()
        print(ma)
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        }
        live = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        rec["memory"]["peak_live_bytes"] = live
        rec["memory"]["fits_16g_hbm"] = bool(live < 16 * 1024**3)
    except Exception as e:  # pragma: no cover - backend specific
        rec["memory"] = {"error": repr(e)}

    # ---- XLA's own cost analysis (per-device; while bodies counted once) ----
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
        rec["xla_cost"] = {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
        }
    except Exception as e:  # pragma: no cover
        rec["xla_cost"] = {"error": repr(e)}

    # ---- trip-count-aware HLO analysis + roofline ----
    t2 = time.perf_counter()
    text = compiled.as_text()
    rec["hlo_chars"] = len(text)
    analysis = analyze_hlo(text)
    rec["analyze_s"] = round(time.perf_counter() - t2, 2)
    rec["hlo"] = analysis.summary()
    rec["roofline"] = roofline_terms(
        get_config(arch), SHAPES[shape], analysis, chips
    )
    if save_hlo:
        pathlib.Path(save_hlo).write_text(text)
    rec["ok"] = True
    return rec


def _cell_path(out: pathlib.Path, mesh: str, arch: str, shape: str) -> pathlib.Path:
    return out / mesh / f"{arch}__{shape}.json"


def _sweep(out: pathlib.Path, meshes, timeout: int, force: bool) -> int:
    from repro.configs.registry import all_cells

    failures = 0
    todo = []
    for mesh in meshes:
        for arch, shape, ok, why in all_cells():
            todo.append((mesh, arch, shape, ok, why))
    print(f"sweep: {len(todo)} cells -> {out}")
    for i, (mesh, arch, shape, ok, why) in enumerate(todo):
        path = _cell_path(out, mesh, arch, shape)
        path.parent.mkdir(parents=True, exist_ok=True)
        if path.exists() and not force:
            continue
        if not ok:
            path.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "ok": True, "skipped": True, "reason": why,
            }, indent=1))
            continue
        print(f"[{i + 1}/{len(todo)}] {mesh:6s} {arch} x {shape} ...",
              flush=True)
        t0 = time.perf_counter()
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", arch, "--shape", shape, "--mesh", mesh,
             "--out", str(out)],
            capture_output=True, text=True, timeout=timeout,
            env={**os.environ, "PYTHONPATH": "src"},
        )
        dt = time.perf_counter() - t0
        if proc.returncode != 0 or not path.exists():
            failures += 1
            path.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh, "ok": False,
                "error": proc.stderr[-4000:], "wall_s": round(dt, 1),
            }, indent=1))
            print(f"    FAILED ({dt:.0f}s): {proc.stderr.strip().splitlines()[-1][:200] if proc.stderr.strip() else 'no stderr'}")
        else:
            rec = json.loads(path.read_text())
            r = rec.get("roofline", {})
            print(f"    ok ({dt:.0f}s) bottleneck={r.get('bottleneck')} "
                  f"terms={ {k: f'{v:.2e}' for k, v in r.get('terms_s', {}).items()} }")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="sweep every cell in subprocesses")
    ap.add_argument("--out", default=_RESULTS_DEFAULT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--kv-int8", action="store_true",
                    help="decode cells: int8-quantized KV cache")
    args = ap.parse_args(argv)

    out = pathlib.Path(args.out)
    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        return 1 if _sweep(out, meshes, args.timeout, args.force) else 0

    assert args.arch and args.shape and args.mesh in ("single", "multi")
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, save_hlo=args.save_hlo,
                       kv_int8=args.kv_int8)
    except Exception:
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "ok": False, "error": traceback.format_exc()[-4000:],
        }
    path = _cell_path(out, args.mesh, args.arch, args.shape)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=1, default=_json_default))
    print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "ok") if k in rec}))
    if not rec.get("ok"):
        print(rec.get("error", ""), file=sys.stderr)
    return 0 if rec.get("ok") else 1


if __name__ == "__main__":
    sys.exit(main())
