"""The paper's 5-layer SNN classifier (Fig. 7, shapes fixed by Table II).

    input (T, 2, 128) binary sigma-delta frames
      Conv1 k=11,  2->16, same pad  + LIF -> MaxPool2
      Conv2 k=11, 16->32, same pad  + LIF -> MaxPool2
      Conv3 k=5,  32->64, same pad  + LIF -> MaxPool2
      FC1   1024 -> 128 (weight-mask method) + LIF
      FC2    128 -> 11
    readout: sum over T of FC2 input currents ("current_sum", default) or
             FC2 LIF spike counts ("spike_count").

Execution now lives in the unified layer-graph API
(:mod:`repro.models.graph` / :mod:`repro.api`): ``compile_snn(cfg)``
produces an ``SNNProgram`` whose ``apply(params, frames, backend=...)``
dispatches per layer to the registered ``dense`` / ``goap`` / ``pallas`` /
``stream`` backends.  The legacy entry points below are kept as thin
deprecated wrappers:

* ``snn_forward``        -> ``program.apply(..., backend="dense")``
* ``snn_forward_batch``  -> ``program.apply_batch(..., backend="dense")``
* ``snn_forward_sparse`` -> ``program.apply(..., backend="goap")``

All LIF parameters (alpha, theta, v_th) are trainable: per-channel for conv
layers, per-neuron for FC layers (paper §IV-B).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.lif import init_lif_params
from repro.core.sparse_format import coo_from_dense

__all__ = ["SNNConfig", "init_snn", "snn_forward", "snn_forward_batch",
           "snn_forward_sparse", "sparsify_params", "param_count",
           "density_report"]


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    """Paper model by default; reducible for smoke tests."""

    conv_specs: Tuple[Tuple[int, int, int], ...] = ((11, 2, 16), (11, 16, 32), (5, 32, 64))
    pool: int = 2
    fc_specs: Tuple[Tuple[int, int], ...] = ((1024, 128), (128, 11))
    input_width: int = 128
    timesteps: int = 8           # = sigma-delta OSR
    n_classes: int = 11
    readout: str = "current_sum"  # or "spike_count"
    lif_alpha: float = 0.9
    lif_theta: float = 1.0
    lif_v_th: float = 1.0

    def feature_widths(self) -> List[int]:
        """Spatial width after each conv+pool stage."""
        w = self.input_width
        widths = []
        for _ in self.conv_specs:
            w = w // self.pool
            widths.append(w)
        return widths

    def validate(self) -> "SNNConfig":
        w = self.input_width
        ic = self.conv_specs[0][1]
        for kw, c_in, c_out in self.conv_specs:
            assert c_in == ic, f"conv chain broken: {c_in} != {ic}"
            ic = c_out
            w = w // self.pool
        flat = ic * w
        assert self.fc_specs[0][0] == flat, (
            f"FC1 input {self.fc_specs[0][0]} != flattened conv output {flat}"
        )
        assert self.fc_specs[-1][1] == self.n_classes
        return self


def init_snn(key: jax.Array, cfg: SNNConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """He-style init; params is a plain nested dict pytree."""
    cfg.validate()
    params: Dict[str, Any] = {"conv": [], "fc": []}
    for kw, ic, oc in cfg.conv_specs:
        key, k1 = jax.random.split(key)
        fan_in = kw * ic
        w = jax.random.normal(k1, (kw, ic, oc), dtype) * jnp.sqrt(2.0 / fan_in)
        params["conv"].append({
            "w": w,
            "lif": init_lif_params((oc, 1), cfg.lif_alpha, cfg.lif_theta, cfg.lif_v_th, dtype),
        })
    for i, (din, dout) in enumerate(cfg.fc_specs):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (din, dout), dtype) * jnp.sqrt(2.0 / din)
        params["fc"].append({
            "w": w,
            "lif": init_lif_params((dout,), cfg.lif_alpha, cfg.lif_theta, cfg.lif_v_th, dtype),
        })
    return params


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def _masked(w: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    return w if mask is None else w * mask


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (see repro.api)",
        DeprecationWarning,
        stacklevel=3,
    )


def snn_forward(
    params: Dict[str, Any],
    frames: jax.Array,
    cfg: SNNConfig,
    masks: Optional[Dict[str, Any]] = None,
    quant_fn=None,
) -> jax.Array:
    """Deprecated: use ``compile_snn(cfg).apply(..., backend="dense")``."""
    from repro.models.graph import compile_snn

    _deprecated("snn_forward", 'SNNProgram.apply(..., backend="dense")')
    return compile_snn(cfg).apply(params, frames, "dense",
                                  masks=masks, quant_fn=quant_fn)


def snn_forward_batch(params, frames_b, cfg, masks=None, quant_fn=None):
    """Deprecated: use ``compile_snn(cfg).apply_batch(..., backend="dense")``."""
    from repro.models.graph import compile_snn

    _deprecated("snn_forward_batch", 'SNNProgram.apply_batch(..., backend="dense")')
    return compile_snn(cfg).apply_batch(params, frames_b, "dense",
                                        masks=masks, quant_fn=quant_fn)


# ---------------------------------------------------------------------------
# Sparse (inference) path.
# ---------------------------------------------------------------------------

def sparsify_params(params: Dict[str, Any], masks: Optional[Dict[str, Any]] = None):
    """Convert (optionally masked) dense params into the COO inference form."""
    sp = {"conv": [], "fc": []}
    for li, layer in enumerate(params["conv"]):
        w = np.asarray(_masked(layer["w"], masks["conv"][li] if masks else None))
        sp["conv"].append({"coo": coo_from_dense(w), "lif": layer["lif"]})
    for fi, layer in enumerate(params["fc"]):
        w = np.asarray(_masked(layer["w"], masks["fc"][fi] if masks else None))
        sp["fc"].append({"w": jnp.asarray(w), "lif": layer["lif"]})
    return sp


def density_report(params, masks=None) -> Dict[str, float]:
    out = {}
    for li, layer in enumerate(params["conv"]):
        w = np.asarray(_masked(layer["w"], masks["conv"][li] if masks else None))
        out[f"conv{li + 1}"] = float((w != 0).mean())
    for fi, layer in enumerate(params["fc"]):
        w = np.asarray(_masked(layer["w"], masks["fc"][fi] if masks else None))
        out[f"fc{fi + 1}"] = float((w != 0).mean())
    return out


def snn_forward_sparse(sparse_params, frames: jax.Array, cfg: SNNConfig) -> jax.Array:
    """Deprecated: use ``compile_snn(cfg).apply(..., backend="goap")``.

    Accepts the COO inference form produced by :func:`sparsify_params`
    (the goap backend also binds straight from dense params + masks).
    """
    from repro.models.graph import compile_snn

    _deprecated("snn_forward_sparse", 'SNNProgram.apply(..., backend="goap")')
    return compile_snn(cfg).apply(sparse_params, frames, "goap")
