"""mamba2-780m [ssm] — arXiv:2405.21060 (unverified).

48L d_model=1536, attention-free, vocab=50280, SSD state 128,
expand 2 (d_inner=3072, 48 heads of head_dim 64), conv width 4.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv=0, d_ff=0,
    vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4, ssm_chunk=256,
    tie_embeddings=True,
    notes="SSD (state-space duality) chunked scan; sub-quadratic decode",
)
