"""Hand-rolled pytree optimizers (AdamW, SGD+momentum) + gradient clipping.

No optax dependency: the container ships bare jax.  API mirrors the
(init_fn, update_fn) convention so the trainer and the LM train-steps share
optimizers.  All state is a pytree of the same structure as params, so the
distributed train steps can shard optimizer state like parameters
(ZeRO-style sharding falls out of pjit param shardings).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "sgd", "clip_by_global_norm", "apply_updates", "global_norm"]

Params = Any
Updates = Any


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: (p + u).astype(p.dtype), params, updates)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw(
    lr: float | Callable[[jax.Array], jax.Array],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Callable, Callable]:
    """Returns (init_fn, update_fn); update_fn(grads, state, params) ->
    (updates, state)."""

    def lr_at(step):
        return lr(step) if callable(lr) else lr

    def init_fn(params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update_fn(grads, state: AdamWState, params) -> Tuple[Updates, AdamWState]:
        step = state.step + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu,
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_at(step)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return init_fn, update_fn


class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any


def sgd(
    lr: float | Callable[[jax.Array], jax.Array],
    momentum: float = 0.9,
    nesterov: bool = False,
) -> Tuple[Callable, Callable]:
    def lr_at(step):
        return lr(step) if callable(lr) else lr

    def init_fn(params) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree_util.tree_map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
            ),
        )

    def update_fn(grads, state: SGDState, params=None) -> Tuple[Updates, SGDState]:
        step = state.step + 1
        buf = jax.tree_util.tree_map(
            lambda b, g: momentum * b + g.astype(jnp.float32), state.momentum, grads
        )
        lr_t = lr_at(step)
        if nesterov:
            updates = jax.tree_util.tree_map(
                lambda g, b: -lr_t * (g.astype(jnp.float32) + momentum * b), grads, buf
            )
        else:
            updates = jax.tree_util.tree_map(lambda b: -lr_t * b, buf)
        return updates, SGDState(step=step, momentum=buf)

    return init_fn, update_fn
