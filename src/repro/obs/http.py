"""Stdlib HTTP exposition endpoint: ``/metrics`` + ``/healthz`` (+ ``/trace``).

A :class:`MetricsServer` runs a ``ThreadingHTTPServer`` on a daemon
thread — the shape a scraper (Prometheus, a curl in CI) expects, with no
dependency beyond the standard library:

* ``GET /metrics``  — text exposition format 0.0.4 of the registry;
* ``GET /healthz``  — ``{"status": "ok", "uptime_s": ...}`` liveness;
* ``GET /trace``    — the active :class:`~repro.obs.trace.TraceLog`'s
  JSON dump (404 when tracing is disabled).

The registry and tracer are resolved **per request** (defaulting to the
process-wide ones), so a server started before ``enable_tracing`` still
serves traces, and a test swapping the default registry is immediately
visible on the next scrape.  ``port=0`` binds an ephemeral port
(``server.port`` reports it) — what the tests use.
"""
from __future__ import annotations

import http.server
import json
import threading
import time
from typing import Optional

from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.trace import get_tracer

__all__ = ["MetricsServer"]


class MetricsServer:
    """Background ``/metrics`` + ``/healthz`` + ``/trace`` HTTP endpoint."""

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 host: str = "127.0.0.1", port: int = 0):
        self._registry = registry
        self._t_started = time.perf_counter()
        outer = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def log_message(self, *args):  # noqa: D102 — silence stderr
                pass

            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):  # noqa: N802 — http.server API
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        reg = (outer._registry if outer._registry is not None
                               else default_registry())
                        self._send(
                            200, reg.to_prometheus().encode(),
                            "text/plain; version=0.0.4; charset=utf-8")
                    elif path == "/healthz":
                        body = json.dumps({
                            "status": "ok",
                            "uptime_s":
                                time.perf_counter() - outer._t_started,
                        }).encode()
                        self._send(200, body, "application/json")
                    elif path == "/trace":
                        tracer = get_tracer()
                        if tracer is None:
                            self._send(404, b'{"error": "tracing disabled"}',
                                       "application/json")
                        else:
                            self._send(200,
                                       json.dumps(tracer.dump()).encode(),
                                       "application/json")
                    else:
                        self._send(404, b"not found", "text/plain")
                except (BrokenPipeError, ConnectionResetError):
                    pass  # scraper went away mid-response

        self._server = http.server.ThreadingHTTPServer((host, port), Handler)
        self._server.daemon_threads = True
        self.host = host
        self.port = int(self._server.server_address[1])
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name=f"obs-metrics-{self.port}")
        self._thread.start()

    def url(self, path: str = "/metrics") -> str:
        return f"http://{self.host}:{self.port}{path}"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
