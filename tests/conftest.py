import numpy as np
import pytest
from hypothesis import settings

# Keep hypothesis fast and deterministic on CI-class CPU containers.
settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
