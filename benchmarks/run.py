"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one module per paper table/figure plus the kernel microbench and the
roofline report, prints each, and writes JSON records to
``experiments/bench/``.  ``--quick`` skips the training-based accuracy
sweep (several CPU-minutes); ``--only <name>`` runs one module.

``--check-regression`` is the perf gate: it reruns ``fusion_bench`` at
the committed batch size and exit-fails if any backend's
``fused_speedup`` or layered fps dropped more than ``--tolerance``
(default 20%) below the committed ``BENCH_fusion.json``.  It then runs
the observability gate (``obs_bench``): tracing overhead must stay under
its absolute bar and the live activity gauges must reproduce the
Tables I/III goldens exactly.  CI runs it on every push so a change that
silently slows the fused streaming path (or de-fuses it, or makes
tracing expensive) turns the build red.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
import traceback

OUT = pathlib.Path("experiments/bench")
HISTORY = pathlib.Path("BENCH_history.jsonl")

#: Per-bench headline extractors for the cumulative history log. Each
#: maps a module's ``run()`` result to the few scalars whose trajectory
#: matters; benches without an entry fall back to top-level scalars.
_HEADLINES = {
    "fusion_bench": lambda r: {
        f"{row['backend']}_{m}": row[m]
        for row in r.get("execution", ())
        for m in ("layered_fps", "fused_speedup")},
    "obs_bench": lambda r: {
        "best_throughput_overhead":
            r["overhead"]["best_throughput_overhead"],
        "spans_per_s": r["overhead"]["spans_per_s"],
        "drift_fired_after":
            r.get("alert_pipeline", {}).get("fired_after_samples"),
        "pass": r["pass"]},
    "serve_bench": lambda r: {
        "async_fps": r.get("async", {}).get("throughput_fps"),
        "speedup": r.get("speedup")},
}


def _headline(name: str, res: dict) -> dict:
    extract = _HEADLINES.get(name)
    if extract is not None:
        try:
            return extract(res)
        except (KeyError, TypeError):
            pass  # artifact shape changed: fall through to the generic cut
    return {k: v for k, v in res.items()
            if isinstance(v, (int, float, bool, str)) and k != "name"}


def append_history(name: str, res: dict, sha: str = "",
                   path: pathlib.Path = HISTORY) -> dict:
    """Append one bench invocation's headline to the cumulative log.

    One JSON line per run — the per-bench artifacts are overwritten each
    run, this file is only ever appended, so the perf *trajectory* stays
    reconstructable.  ``sha`` is stamped by the caller (``--sha`` or the
    ``GIT_SHA`` env var): no in-process timestamping or git calls.
    """
    record = {"bench": name, "sha": sha, "metrics": _headline(name, res)}
    with open(path, "a") as f:
        f.write(json.dumps(record, default=str) + "\n")
    return record


def _modules(quick: bool):
    from . import (
        accuracy_sweep,
        deploy_bench,
        fixed_bench,
        fleet_bench,
        fusion_bench,
        kernel_bench,
        obs_bench,
        robustness_bench,
        roofline,
        serve_bench,
        table1_goap_vs_sw,
        table2_coo_overhead,
        table3_accum_ratio,
        table45_perf_model,
    )

    mods = [table1_goap_vs_sw, table2_coo_overhead, table3_accum_ratio,
            table45_perf_model, kernel_bench, fusion_bench, roofline]
    if not quick:
        # several CPU-minutes each: training sweep, full 4096-frame serve
        # run, the hot-swap-under-load deployment bench, the
        # scenario-robustness sweep across all four backends, the
        # float-vs-fixed fidelity sweep of the integer tier, the
        # open-loop fleet load/autoscaling harness, and the observability
        # overhead gate
        mods.extend([accuracy_sweep, serve_bench, deploy_bench,
                     robustness_bench, fixed_bench, fleet_bench, obs_bench])
    return mods


def _gate_failures(base: dict, best: dict, tolerance: float):
    """Compare best-observed fresh metrics against the committed floors.

    ``fused_speedup`` is a within-run ratio, compared directly.
    ``layered_fps`` is absolute throughput, so its floor is rescaled by
    the dense backend's fresh/committed layered-fps ratio — dense is the
    machine-speed proxy, making the gate meaningful on hosts (CI runners)
    slower or faster than the one that committed the baseline.
    """
    base_rows = {r["backend"]: r for r in base["execution"]}
    dense_base = base_rows.get("dense", {}).get("layered_fps")
    dense_fresh = best.get("dense", {}).get("layered_fps")
    calib = (float(dense_fresh) / float(dense_base)
             if dense_base and dense_fresh else 1.0)
    failures, lines = [], [f"  machine-speed calibration (dense layered): "
                           f"x{calib:.2f}"]
    for br in base["execution"]:
        name = br["backend"]
        fr = best.get(name)
        if fr is None:
            failures.append(f"{name}: backend missing from fresh run")
            continue
        for metric in ("fused_speedup", "layered_fps"):
            scale = calib if metric == "layered_fps" else 1.0
            floor = float(br[metric]) * scale * (1.0 - tolerance)
            got = float(fr[metric])
            verdict = "ok" if got >= floor else "REGRESSED"
            lines.append(f"  {name:12s} {metric:13s} committed "
                         f"{float(br[metric]):10.2f}  best fresh "
                         f"{got:10.2f}  floor {floor:10.2f}  {verdict}")
            if got < floor:
                failures.append(
                    f"{name}.{metric}: {got:.2f} < floor {floor:.2f} "
                    f"(committed {float(br[metric]):.2f}, "
                    f"tolerance {tolerance:.0%})")
    return failures, lines


def check_regression(baseline: pathlib.Path, tolerance: float,
                     reps: int = 3, attempts: int = 3) -> int:
    """Rerun fusion_bench at the committed batch; fail on >tolerance drops.

    Gated metrics, per backend row present in the committed artifact:
    ``fused_speedup`` (within-run ratio — catches de-fusing) and
    ``layered_fps`` (throughput, machine-calibrated — catches backend
    slowdowns).  Wall-clock benchmarks on shared hosts are noisy, so the
    gate keeps the best value per metric over up to ``attempts`` fresh
    runs and only fails if a floor is still unmet after the last.
    """
    from . import fusion_bench, obs_bench

    base = json.loads(baseline.read_text())
    print(f"perf gate: baseline {baseline} "
          f"(batch {base['batch']}, {base['jax_backend']})")
    best: dict = {}
    failures, lines = ["no fresh run"], []
    for attempt in range(attempts):
        fresh = fusion_bench.run(batch=int(base["batch"]), reps=reps)
        print(f"-- attempt {attempt + 1}/{attempts}")
        print(fusion_bench.format_table(fresh))
        for r in fresh["execution"]:
            slot = best.setdefault(r["backend"], dict(r))
            for metric in ("fused_speedup", "layered_fps"):
                slot[metric] = max(float(slot[metric]), float(r[metric]))
        failures, lines = _gate_failures(base, best, tolerance)
        if not failures:
            break
    print("\n".join(lines))
    if failures:
        print("perf gate FAILED:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"perf gate OK ({len(base['execution'])} backends, "
          f"tolerance {tolerance:.0%})")

    # Observability gate: tracing overhead and activity-gauge fidelity are
    # within-run comparisons, so no committed baseline (and no machine
    # calibration) is needed — the bar is absolute.
    print("\nobs gate: traced-vs-untraced overhead + activity gauges")
    obs_res = obs_bench.run(n_frames=512, attempts=attempts)
    print(obs_bench.format_table(obs_res))
    obs_failures = obs_bench.check(obs_res)
    if obs_failures:
        print("obs gate FAILED:")
        for f in obs_failures:
            print(f"  - {f}")
        return 1
    print("obs gate OK")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--check-regression", action="store_true",
                    help="perf gate: rerun fusion_bench and compare "
                         "against the committed baseline")
    ap.add_argument("--baseline", default="BENCH_fusion.json",
                    help="committed artifact the perf gate diffs against")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop per gated metric")
    ap.add_argument("--sha", default=None,
                    help="git SHA to stamp into BENCH_history.jsonl "
                         "(default: the GIT_SHA env var)")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the BENCH_history.jsonl append")
    args = ap.parse_args(argv)
    sha = args.sha if args.sha is not None else os.environ.get("GIT_SHA", "")

    if args.check_regression:
        return check_regression(pathlib.Path(args.baseline), args.tolerance)

    OUT.mkdir(parents=True, exist_ok=True)
    failures = 0
    for mod in _modules(args.quick):
        if args.only and mod.NAME != args.only:
            continue
        print(f"\n=== {mod.NAME} " + "=" * max(0, 60 - len(mod.NAME)))
        t0 = time.perf_counter()
        try:
            res = mod.run()
            print(mod.format_table(res))
            (OUT / f"{mod.NAME}.json").write_text(
                json.dumps(res, indent=1, default=str))
            if not args.no_history:
                append_history(mod.NAME, res, sha=sha)
            print(f"[{mod.NAME}: {time.perf_counter() - t0:.1f}s]")
        except Exception:
            failures += 1
            print(f"[{mod.NAME}: FAILED]\n{traceback.format_exc()}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
