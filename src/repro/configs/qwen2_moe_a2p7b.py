"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B (verified: hf).

24L d_model=2048 16H (GQA kv=16) routed d_ff=1408, vocab=151936,
60 routed experts top-4 + 4 shared experts (Qwen1.5-MoE's shared expert is
4x the routed intermediate size == 4 routed-size shared experts).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, d_ff=1408,
    vocab=151936, head_dim=128,
    n_experts=60, top_k=4, n_shared=4,
    qkv_bias=True, rope_theta=1_000_000.0,
    notes="4 shared + 60 routed top-4; QKV bias per Qwen1.5 lineage",
)
