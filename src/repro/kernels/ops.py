"""Jit'd public wrappers around the Pallas kernels.

These are the entry points the model/serving layers use: they accept the
framework-level objects (``BlockSparseKernel``, dense IFMs, ``LIFParams``)
and handle the padding/layout plumbing around the raw kernels.

``interpret`` defaults to True because this container is CPU-only (TPU v5e
is the compile target); on real TPU hardware pass ``interpret=False``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.goap import build_shift_buffer
from repro.core.lif import LIFParams
from repro.core.sparse_format import BlockSparseKernel

from .goap_conv import goap_conv_block_sparse
from .lif_update import lif_update_fused
from .wm_fc import wm_fc_matmul

__all__ = ["goap_conv_op", "wm_fc_op", "lif_op"]


def goap_conv_op(
    ifm: jax.Array,            # (IC, WI) binary, pre-padded for the conv
    bs: BlockSparseKernel,
    *,
    block_oi: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Sparse conv currents (OC, OI) via the block-sparse GOAP kernel."""
    ic, wi = ifm.shape
    assert ic == bs.ic, (ic, bs.ic)
    oi = wi - bs.kw + 1
    x = build_shift_buffer(ifm, bs.kw).astype(jnp.float32)  # (K, OI)
    # pad K to the blocked reduction size and OI to the lane tile
    pad_k = bs.padded_k - x.shape[0]
    pad_oi = (-oi) % block_oi
    x = jnp.pad(x, ((0, pad_k), (0, pad_oi)))
    out = goap_conv_block_sparse(
        jnp.asarray(bs.blocks, jnp.float32),
        jnp.asarray(bs.block_cols),
        x,
        block_oc=bs.block_oc,
        block_k=bs.block_k,
        block_oi=block_oi,
        interpret=interpret,
    )
    return out[: bs.oc, :oi]


def wm_fc_op(
    spikes: jax.Array,   # (B, IN) or (IN,) binary
    weights: jax.Array,  # (IN, OUT) masked weights
    *,
    interpret: bool = True,
) -> jax.Array:
    squeeze = spikes.ndim == 1
    s = spikes[None] if squeeze else spikes
    out = wm_fc_matmul(s, weights, interpret=interpret)
    return out[0] if squeeze else out


def lif_op(
    currents: jax.Array,  # (T, ...) input currents
    params: LIFParams,
    v0: jax.Array | None = None,
    *,
    interpret: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Fused LIF over time for arbitrary neuron shape; returns (spikes, v_fin)."""
    t = currents.shape[0]
    neuron_shape = currents.shape[1:]
    cur = currents.reshape(t, -1)
    n = cur.shape[1]
    full = lambda p: jnp.broadcast_to(p, neuron_shape).reshape(-1)
    v0f = jnp.zeros((n,), cur.dtype) if v0 is None else v0.reshape(-1)
    spikes, v_fin = lif_update_fused(
        cur, v0f, full(params.alpha), full(params.theta), full(params.v_th),
        interpret=interpret,
    )
    return spikes.reshape((t,) + neuron_shape), v_fin.reshape(neuron_shape)
