"""Weight-masked FC layer as a tiled Pallas TPU matmul kernel.

Paper §III-B: FC weights carry a 1-bit mask; ``FM = IFM AND WM`` selects
the weights that are actually fetched/accumulated.  On TPU the mask is
folded into the stored weight matrix (zeros stay zero) and the binary spike
activations make every multiply a gate: the kernel is a standard
MXU-aligned tiled matmul whose *lhs is {0,1}* — the fetch-traffic win
(1-bit activations) is modeled by the cost layer, the compute win comes
from the batched formulation (B x IN) @ (IN x OUT) keeping the MXU busy.

Grid: (B-tiles, OUT-tiles, IN-tiles) with the reduction dimension minor so
each output tile accumulates in VMEM across IN-tiles (revisiting pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wm_fc_matmul"]


def _kernel(s_ref, w_ref, out_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        s_ref[...], w_ref[...], preferred_element_type=out_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_in", "block_out", "interpret")
)
def wm_fc_matmul(
    spikes: jax.Array,   # (B, IN) binary {0,1}
    weights: jax.Array,  # (IN, OUT) masked weights (zeros pruned)
    *,
    block_b: int = 8,
    block_in: int = 128,
    block_out: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, d_in = spikes.shape
    d_in2, d_out = weights.shape
    assert d_in == d_in2, (spikes.shape, weights.shape)

    pad_b = (-b) % block_b
    pad_in = (-d_in) % block_in
    pad_out = (-d_out) % block_out
    s = jnp.pad(spikes.astype(weights.dtype), ((0, pad_b), (0, pad_in)))
    w = jnp.pad(weights, ((0, pad_in), (0, pad_out)))

    grid = (s.shape[0] // block_b, w.shape[1] // block_out, s.shape[1] // block_in)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_in), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_in, block_out), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_out), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s.shape[0], w.shape[1]), weights.dtype),
        interpret=interpret,
        name="wm_fc_matmul",
    )(s, w)
    return out[:b, :d_out]
