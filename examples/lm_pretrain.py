"""LM pretraining example on an assigned architecture (reduced scale).

Exercises the same train-step the production mesh runs (AdamW, clipping,
chunked cross-entropy, remat'd scanned stacks) on CPU with a synthetic
Zipf token stream, with checkpoint/resume — then proves the resume is
bitwise identical, the fault-tolerance contract of the checkpoint layer.

Run:  PYTHONPATH=src python examples/lm_pretrain.py [--arch llama3-8b]
"""
import argparse
import tempfile

import numpy as np

import jax

from repro.configs.registry import ARCH_IDS
from repro.launch.train import LMTrainer
from repro.configs.registry import reduced_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    print(f"arch {args.arch} (reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"family={cfg.family}), {args.steps} steps")

    with tempfile.TemporaryDirectory() as d:
        tr = LMTrainer(cfg, lr=5e-3, batch=4, seq=32, ckpt_dir=d)
        hist = tr.run(args.steps // 2, log_every=10, ckpt_every=10)
        mid_params = jax.tree_util.tree_map(np.asarray, tr.params)

        # crash-restart: fresh trainer, resume from the checkpoint
        tr2 = LMTrainer(cfg, lr=5e-3, batch=4, seq=32, ckpt_dir=d)
        assert tr2.resume(), "resume failed"
        same = all(
            np.array_equal(a, b)
            for a, b in zip(jax.tree_util.tree_leaves(mid_params),
                            jax.tree_util.tree_leaves(
                                jax.tree_util.tree_map(np.asarray, tr2.params))))
        print(f"resumed at step {tr2.step}; params bitwise equal: {same}")

        # both trainers take the same next steps -> identical trajectories
        h1 = tr.run(args.steps // 2, log_every=max(1, args.steps // 2))
        h2 = tr2.run(args.steps // 2, log_every=max(1, args.steps // 2))
        print(f"post-resume losses: original {h1['loss'][-1]:.6f} "
              f"vs resumed {h2['loss'][-1]:.6f} "
              f"(identical: {h1['loss'][-1] == h2['loss'][-1]})")
        print(f"loss {hist['loss'][0]:.3f} -> {h1['loss'][-1]:.3f}")


if __name__ == "__main__":
    main()
