"""Model lifecycle example: publish -> serve -> canary -> promote ->
rollback, end to end.

The paper's deployment target is a long-lived cognitive-radio edge node;
this example walks the whole continual-update loop the deploy subsystem
supports:

1. train the paper model briefly and **publish** it to a versioned
   registry (``production`` alias);
2. train a little more and publish the update (``staging``);
3. serve production through the async tier, then bind staging as a
   **canary** taking a slice of the batches;
4. let the :class:`CanaryMonitor` shadow-evaluate both versions per SNR
   bucket (agreement scoring — no ground truth needed at the edge) and
   **auto-promote** the clean canary via the atomic hot-swap flip;
5. publish a deliberately-broken version and watch the monitor
   **auto-roll-back** the moment its per-SNR scores collapse.

Run:  PYTHONPATH=src python examples/amc_deploy.py [--registry DIR]
"""
import argparse
import tempfile

import numpy as np

import jax

from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.data.radioml import generate_batch
from repro.deploy import (
    CanaryMonitor,
    ModelRegistry,
    MonitorConfig,
    canary_router,
    publish_from_trainer,
)
from repro.serve import AsyncAMCServeEngine
from repro.train.trainer import SNNTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--registry", default=None,
                    help="registry directory (default: a temp dir)")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--canary-pct", type=float, default=25.0)
    args = ap.parse_args()

    tmp = None
    if args.registry is None:
        tmp = tempfile.TemporaryDirectory()
        args.registry = tmp.name
    registry = ModelRegistry(args.registry)

    # -- 1-2: train and publish two versions --------------------------------
    print(f"[1/5] training {args.train_steps} steps at density "
          f"{args.density}")
    trainer = SNNTrainer(SNN_CONFIG, TrainerConfig(
        total_steps=args.train_steps, batch_size=48, lr=2e-3,
        final_density=args.density, snr_db=10.0))
    trainer.run()
    v1 = publish_from_trainer(registry, "amc", trainer, alias="production",
                              metrics={"train_steps": trainer.step})
    print(f"      published {v1.spec} (digest {v1.digest[:12]}…, plan "
          f"{str(v1.plan_digest)[:12]}…) -> production")

    print(f"[2/5] continuing training {args.train_steps // 2} more steps")
    trainer.run(steps=max(1, args.train_steps // 2))
    v2 = publish_from_trainer(registry, "amc", trainer, alias="staging",
                              metrics={"train_steps": trainer.step})
    print(f"      published {v2.spec} -> staging")

    # -- 3: serve production, canary the update -----------------------------
    prod = registry.load("amc@production")
    engine = AsyncAMCServeEngine(prod.params, prod.cfg, masks=prod.masks,
                                 backend="auto", max_batch=32,
                                 version_label=v1.spec)
    print(f"[3/5] serving {v1.spec} on backend '{engine.backend}'")
    iq, labels, _ = generate_batch(seed=4242, batch=args.requests,
                                   snr_db=10.0)
    preds = engine.classify(iq)
    print(f"      production accuracy on {args.requests} frames: "
          f"{float((preds == labels).mean()):.3f}")

    staging = registry.load("amc@staging")
    engine.bind_version(v2.spec, staging.params, staging.masks)
    engine.set_router(canary_router(v1.spec, v2.spec, args.canary_pct))
    engine.classify(iq)  # traffic now splits across both versions

    # -- 4: monitor promotes the clean canary -------------------------------
    # labels scoring (the synthetic generator doubles as a labeled replay
    # buffer): the canary must stay within tolerance of the baseline's
    # per-SNR accuracy — a model trained longer clears this easily
    mon = CanaryMonitor(engine, baseline=v1.spec, canary=v2.spec,
                        config=MonitorConfig(
                            snr_bins=(0.0, 10.0), frames_per_bin=32,
                            score="labels", acc_drop_tol=0.3,
                            min_rounds=1, promote_after=2),
                        registry=registry, canary_spec=v2.spec)
    decision = mon.run(max_rounds=5)
    print(f"[4/5] monitor on {v2.spec}: {decision} ({mon.reason})")
    assert decision == "promote", "a healthy canary should promote"
    print(f"      primary is now {engine.active_version}; production "
          f"alias -> v{registry.resolve('amc')[1]}")
    engine.classify(iq)  # traffic now lands on the promoted version

    # -- 5: a broken update rolls back automatically ------------------------
    # fault injection: a "corrupted retrain" whose logit head is permuted
    # — every prediction lands one class off, a regression the agreement
    # score (no ground truth needed) catches deterministically
    broken = jax.tree_util.tree_map(np.asarray, staging.params)
    broken["fc"][-1] = dict(broken["fc"][-1],
                            w=np.roll(broken["fc"][-1]["w"], 1, axis=1))
    broken_masks = jax.tree_util.tree_map(np.asarray, staging.masks)
    broken_masks["fc"][-1] = np.roll(broken_masks["fc"][-1], 1, axis=1)
    v3 = registry.publish("amc", broken, SNN_CONFIG, masks=broken_masks,
                          metrics={"note": "fault-injection demo"})
    engine.bind_version(v3.spec, broken, broken_masks)
    engine.set_router(canary_router(v2.spec, v3.spec, args.canary_pct))
    mon = CanaryMonitor(engine, baseline=v2.spec, canary=v3.spec,
                        config=MonitorConfig(
                            snr_bins=(-10.0, 0.0, 10.0), frames_per_bin=16,
                            score="agreement", acc_drop_tol=0.5,
                            min_rounds=2),
                        registry=registry, canary_spec=v3.spec)
    decision = mon.run(max_rounds=5)
    print(f"[5/5] monitor on broken {v3.spec}: {decision} ({mon.reason})")
    assert decision == "rollback", "a broken canary should roll back"
    assert engine.active_version == v2.spec

    print("\nper-version serving stats:")
    for label, st in engine.version_stats().items():
        marker = "*" if label == engine.active_version else " "
        print(f"  {marker}{label:10s} requests={st.requests:5d} "
              f"batches={st.batches:4d} p99={st.p99_ms:.1f}ms")
    print(f"registry versions: {registry.versions('amc')}, aliases "
          f"{registry.aliases('amc')}")
    engine.close()
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()
