import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Minimal environments run without hypothesis: property tests skip via
    # the tests/_hyp.py shim and the profile setup below is a no-op.
    settings = None

if settings is not None:
    # Keep hypothesis fast and deterministic on CI-class CPU containers.
    settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
    settings.load_profile("ci")


@pytest.fixture(autouse=True, scope="session")
def _isolated_plan_cache():
    """Run the whole suite against a memory-only plan cache.

    The default cache's disk tier (~/.cache/repro/plans) must never leak
    into tests: stale pickled artifacts under an unchanged content hash
    would mask regressions in the artifact builders (the golden-counter
    tests are fully deterministic), and test runs must not write into the
    user's real cache directory.
    """
    from repro.plan import PlanCache, default_cache, set_default_cache

    old = default_cache()
    set_default_cache(PlanCache(disk_dir=""))
    yield
    set_default_cache(old)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
