"""Declarative SLOs evaluated by a multi-window burn-rate engine.

The serving tier emits raw counters (submitted, shed, expired) and a
latency histogram; an *SLO* turns them into one question — "are we
spending error budget faster than we can afford?" — using the
multi-window multi-burn-rate recipe from the Google SRE workbook:

* **burn rate** = observed error rate / budgeted error rate, so burn 1.0
  exhausts the budget exactly at the SLO period's end and burn 14.4
  exhausts a 30-day 99.9% budget in ~2 days;
* each :class:`BurnWindow` pairs a **long** window (the signal) with a
  **short** window (the reset: the alert clears quickly once the burn
  stops) and fires only when *both* exceed the window's factor — fast
  windows page, slow windows ticket;
* the default ladder is the issue's fast 5m/1h + slow 6h/3d pair, and
  :func:`scaled_windows` shrinks the whole ladder proportionally so a
  20-second bench run (or a fake-clock test) exercises the identical
  math.

Three SLO kinds cover the stack:

``ratio``
    bad-events / total-events from counter deltas — availability is
    ``1 - (shed + expired) / submitted``.
``latency``
    fraction of requests over a bound from windowed histogram-bucket
    deltas — "p99 under 50 ms" is "no more than 1% of requests above
    50 ms", i.e. objective 0.99 over the 50 ms bucket edge.
``gauge``
    a gauge that *is* a good-fraction (canary per-SNR window accuracy):
    burn = (1 - value) / (1 - objective).

Everything reads from a :class:`~repro.obs.timeseries.TimeSeriesRecorder`
— the engine never touches live registries, so evaluation is cheap,
deterministic under a fake clock, and works identically on fleet-merged
series.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.timeseries import Series, TimeSeriesRecorder

__all__ = ["SLO", "BurnWindow", "SLOStatus", "BurnRateEngine",
           "DEFAULT_BURN_WINDOWS", "scaled_windows", "parse_slo_spec",
           "default_serve_slos"]


@dataclass(frozen=True)
class BurnWindow:
    """One (long, short) window pair with its firing factor."""
    severity: str          # "page" | "ticket"
    long_s: float
    short_s: float
    factor: float          # fire when both windows burn faster than this


#: Google-SRE ladder for a 30-day budget: the fast pair (5m/1h) pages at
#: burn 14.4 (2% of budget per hour), the slow pair (6h/3d) files a
#: ticket at burn 1 (budget exactly on track to exhaust).
DEFAULT_BURN_WINDOWS: Tuple[BurnWindow, ...] = (
    BurnWindow("page", long_s=3600.0, short_s=300.0, factor=14.4),
    BurnWindow("ticket", long_s=3 * 86400.0, short_s=6 * 3600.0,
               factor=1.0),
)


def scaled_windows(scale: float,
                   windows: Sequence[BurnWindow] = DEFAULT_BURN_WINDOWS,
                   ) -> Tuple[BurnWindow, ...]:
    """Shrink every window by ``scale`` (factors unchanged) so short
    runs/tests exercise the production math at bench timescales."""
    if scale <= 0:
        raise ValueError(f"scale must be > 0, got {scale}")
    return tuple(
        BurnWindow(w.severity, w.long_s * scale, w.short_s * scale, w.factor)
        for w in windows)


@dataclass(frozen=True)
class SLO:
    """One declarative objective over recorded series.

    ``kind`` selects the error-rate computation (see module docstring);
    label filters select the child series (first match wins when empty).
    """
    name: str
    kind: str                               # "ratio" | "latency" | "gauge"
    objective: float                        # good fraction in (0, 1)
    # ratio:
    total_metric: str = ""
    bad_metrics: Tuple[str, ...] = ()
    # latency:
    latency_metric: str = ""
    bound_s: float = 0.0
    # gauge:
    gauge_metric: str = ""
    labels: Tuple[Tuple[str, str], ...] = ()

    def __post_init__(self):
        if self.kind not in ("ratio", "latency", "gauge"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if not (0.0 < self.objective < 1.0):
            raise ValueError(
                f"objective must be in (0, 1), got {self.objective}")
        if self.kind == "ratio" and not (self.total_metric
                                         and self.bad_metrics):
            raise ValueError("ratio SLO needs total_metric + bad_metrics")
        if self.kind == "latency" and not (self.latency_metric
                                           and self.bound_s > 0):
            raise ValueError("latency SLO needs latency_metric + bound_s")
        if self.kind == "gauge" and not self.gauge_metric:
            raise ValueError("gauge SLO needs gauge_metric")

    @property
    def budget(self) -> float:
        return 1.0 - self.objective


@dataclass
class SLOStatus:
    """Evaluation result for one SLO at one instant."""
    slo: SLO
    t: float
    # burn rate per window severity: {"page": (long, short), ...}
    burns: Dict[str, Tuple[Optional[float], Optional[float]]] = \
        field(default_factory=dict)
    firing: List[str] = field(default_factory=list)   # severities firing

    @property
    def ok(self) -> bool:
        return not self.firing

    def to_dict(self) -> Dict:
        return {
            "slo": self.slo.name,
            "kind": self.slo.kind,
            "objective": self.slo.objective,
            "t": self.t,
            "burns": {sev: [b for b in pair] for sev, pair in
                      self.burns.items()},
            "firing": list(self.firing),
        }


def _match(series: List[Series], name: str,
           labels: Tuple[Tuple[str, str], ...]) -> List[Series]:
    want = dict(labels)
    out = []
    for s in series:
        if s.name != name:
            continue
        have = dict(s.labels)
        if all(have.get(k) == v for k, v in want.items()):
            out.append(s)
    return out


class BurnRateEngine:
    """Evaluates SLOs against a recorder's series at each call.

    The clock is the *recorder's* clock — under a fake clock the engine
    asks windows relative to the newest sample, so tests can hand-drive
    time. ``evaluate`` is pure read: the status list is the only output,
    alerting lives in :mod:`repro.obs.anomaly`.
    """

    def __init__(self, recorder: TimeSeriesRecorder, slos: Sequence[SLO],
                 windows: Sequence[BurnWindow] = DEFAULT_BURN_WINDOWS):
        self.recorder = recorder
        self.slos = list(slos)
        self.windows = list(windows)

    # -- per-kind error rates over one trailing window -----------------------

    def _error_rate(self, slo: SLO, window_s: float,
                    now: Optional[float]) -> Optional[float]:
        series = self.recorder.series()
        if slo.kind == "ratio":
            totals = _match(series, slo.total_metric, slo.labels)
            if not totals:
                return None
            total = sum(s.delta(window_s, now) for s in totals)
            if total <= 0:
                return None
            bad = 0.0
            for metric in slo.bad_metrics:
                bad += sum(s.delta(window_s, now)
                           for s in _match(series, metric, slo.labels))
            return min(1.0, bad / total)
        if slo.kind == "latency":
            hists = _match(series, slo.latency_metric, slo.labels)
            fracs = []
            weights = []
            for s in hists:
                d = s._hist_delta(window_s, now)
                if d is None or d[2] <= 0:
                    continue
                frac = s.fraction_over(slo.bound_s, window_s, now)
                if frac is not None:
                    fracs.append(frac)
                    weights.append(d[2])
            if not fracs:
                return None
            total_w = sum(weights)
            return sum(f * w for f, w in zip(fracs, weights)) / total_w
        # gauge: average the latest windowed values (value is a good
        # fraction; error rate is its complement)
        gauges = _match(series, slo.gauge_metric, slo.labels)
        vals = []
        for s in gauges:
            w = s.window(window_s, now)
            if w:
                vals.append(sum(float(v) for _, v in w) / len(w))
        if not vals:
            return None
        return max(0.0, 1.0 - sum(vals) / len(vals))

    def burn_rate(self, slo: SLO, window_s: float,
                  now: Optional[float] = None) -> Optional[float]:
        """Observed error rate over the window divided by the budget."""
        err = self._error_rate(slo, window_s, now)
        if err is None:
            return None
        return err / slo.budget

    def evaluate(self, now: Optional[float] = None) -> List[SLOStatus]:
        t = self.recorder._clock() if now is None else now
        out = []
        for slo in self.slos:
            status = SLOStatus(slo=slo, t=t)
            for w in self.windows:
                b_long = self.burn_rate(slo, w.long_s, now)
                b_short = self.burn_rate(slo, w.short_s, now)
                status.burns[w.severity] = (b_long, b_short)
                if (b_long is not None and b_short is not None
                        and b_long > w.factor and b_short > w.factor):
                    status.firing.append(w.severity)
            out.append(status)
        return out


# -- CLI spec parsing (launch/serve.py --slo) --------------------------------

def default_serve_slos(engine: str = "engine") -> List[SLO]:
    """The serving tier's stock SLOs against its own metric names."""
    return [
        SLO(name="availability", kind="ratio", objective=0.999,
            total_metric="repro_fleet_submitted_total",
            bad_metrics=("repro_fleet_shed_total",
                         "repro_serve_expired_total")),
        # 250 ms: comfortably above this tier's healthy CPU-host
        # micro-batch queueing latency (p95 ~60 ms at batch 8) while far
        # below the shed/overload regime the fleet bench measures (~800 ms)
        SLO(name="latency", kind="latency", objective=0.99,
            latency_metric="repro_serve_request_latency_seconds",
            bound_s=0.250),
    ]


def parse_slo_spec(spec: str) -> List[SLO]:
    """Parse ``--slo`` CLI specs into SLO objects.

    Comma-separated clauses; each is ``name=value[@objective]``:

    * ``availability=0.999`` — ratio SLO over fleet shed+expired vs
      submitted with the given objective;
    * ``p99_ms=50`` or ``p99_ms=50@0.99`` — latency SLO: at most
      (1-objective) of requests above 50 ms (objective defaults 0.99);
    * ``accuracy=0.9`` or ``accuracy=0.9@0.95`` — gauge SLO over the
      canary per-SNR window-accuracy gauge, firing when accuracy sits
      below the target (value acts as the good fraction).
    * ``default`` — shorthand for the stock serving pair.
    """
    slos: List[SLO] = []
    for clause in [c.strip() for c in spec.split(",") if c.strip()]:
        if clause == "default":
            slos.extend(default_serve_slos())
            continue
        if "=" not in clause:
            raise ValueError(f"bad --slo clause {clause!r} (want name=value)")
        name, rhs = clause.split("=", 1)
        name = name.strip()
        if "@" in rhs:
            value_s, obj_s = rhs.split("@", 1)
            objective = float(obj_s)
        else:
            value_s, objective = rhs, None
        value = float(value_s)
        if name == "availability":
            slos.append(SLO(
                name="availability", kind="ratio",
                objective=value if objective is None else objective,
                total_metric="repro_fleet_submitted_total",
                bad_metrics=("repro_fleet_shed_total",
                             "repro_serve_expired_total")))
        elif name == "p99_ms":
            slos.append(SLO(
                name=f"latency_p99_{value:g}ms", kind="latency",
                objective=0.99 if objective is None else objective,
                latency_metric="repro_serve_request_latency_seconds",
                bound_s=value / 1000.0))
        elif name == "accuracy":
            slos.append(SLO(
                name="canary_accuracy", kind="gauge",
                objective=value if objective is None else objective,
                gauge_metric="repro_canary_window_accuracy"))
        else:
            raise ValueError(f"unknown --slo name {name!r} "
                             "(want availability | p99_ms | accuracy)")
    if not slos:
        raise ValueError(f"--slo spec {spec!r} parsed to no SLOs")
    return slos
