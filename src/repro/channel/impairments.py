"""JAX-traceable RF channel impairments (the GNU Radio dynamic-channel family).

RadioML 2016 frames are produced by GNU Radio's dynamic channel model:
AWGN, carrier frequency/phase offset, oscillator phase noise, sample-rate
(timing) offset, and — in the harder variants — selective multipath fading
with Doppler and co-channel interference.  This module implements each of
those impairments as a pure, seed-deterministic ``jax.numpy`` function on a
complex baseband frame, so a full channel realization can run **inside** a
jitted/vmapped serving or training step (no host callbacks) and is exactly
reproducible from a ``jax.random`` key.

Conventions shared by every impairment:

* signals are complex64 vectors ``(L,)`` at baseband; :func:`to_complex` /
  :func:`to_iq` convert to/from the repo's real ``(2, L)`` I/Q layout;
* frequencies are normalized to the sample rate (cycles/sample);
* **power discipline** — multiplicative and resampling impairments
  (offsets, phase noise, fading, IQ imbalance, timing) preserve the input's
  average power exactly (unitary rotations) or by explicit renormalization,
  so impairment *order* never silently changes the operating SNR.  Additive
  impairments (:func:`awgn`, :func:`interferer_tones`) first normalize the
  signal to unit power and then add energy at an analytically-known level
  (noise power ``10^(-snr/10)``, interference ``10^(-sir/10)``).

The legacy host-side channel that :mod:`repro.data.radioml` has always
applied (AWGN + random CFO/phase + phase noise, vectorized numpy) now lives
here as :func:`legacy_awgn_channel`; ``radioml._apply_channel`` is an alias,
so the ``static_awgn`` scenario and the dataset generator share one
implementation by construction.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "to_complex",
    "to_iq",
    "avg_power",
    "normalize_power",
    "awgn",
    "carrier_offset",
    "phase_noise",
    "timing_offset",
    "iq_imbalance",
    "multipath_fading",
    "interferer_tones",
    "legacy_awgn_channel",
]


# ---------------------------------------------------------------------------
# I/Q <-> complex plumbing.
# ---------------------------------------------------------------------------

def to_complex(iq: jax.Array) -> jax.Array:
    """(..., 2, L) real I/Q -> (..., L) complex64 baseband."""
    return (iq[..., 0, :] + 1j * iq[..., 1, :]).astype(jnp.complex64)


def to_iq(sig: jax.Array) -> jax.Array:
    """(..., L) complex baseband -> (..., 2, L) float32 I/Q."""
    return jnp.stack([sig.real, sig.imag], axis=-2).astype(jnp.float32)


def avg_power(sig: jax.Array) -> jax.Array:
    """Mean |x|^2 over the frame (the unit every impairment preserves)."""
    return jnp.mean(jnp.abs(sig) ** 2)


def normalize_power(sig: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Rescale to unit average power (the AWGN reference level)."""
    return sig / jnp.sqrt(avg_power(sig) + eps)


def _match_power(out: jax.Array, ref: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Rescale ``out`` so its average power equals ``ref``'s."""
    return out * jnp.sqrt((avg_power(ref) + eps) / (avg_power(out) + eps))


# ---------------------------------------------------------------------------
# Additive impairments.
# ---------------------------------------------------------------------------

def awgn(sig: jax.Array, key: jax.Array, snr_db: jax.Array,
         _noise: Optional[jax.Array] = None) -> jax.Array:
    """Unit-normalize the signal, then add complex white noise at ``snr_db``.

    Same math (and op order) as the noise step of
    :func:`legacy_awgn_channel`.  ``_noise`` injects a pre-drawn
    unit-variance complex noise vector (tests use it to compare the jax and
    numpy paths on identical randomness).
    """
    sig = normalize_power(sig)
    if _noise is None:
        kr, ki = jax.random.split(key)
        _noise = (jax.random.normal(kr, sig.shape)
                  + 1j * jax.random.normal(ki, sig.shape))
    p_noise = 10.0 ** (-jnp.asarray(snr_db, jnp.float32) / 10.0)
    return sig + _noise.astype(sig.dtype) * jnp.sqrt(p_noise / 2.0)


def interferer_tones(sig: jax.Array, key: jax.Array, sir_db: float,
                     f_min: float = 0.05, f_max: float = 0.45,
                     n_tones: int = 1) -> jax.Array:
    """Add co-channel interferer tone(s) at random adjacent offsets.

    Each tone sits at a random normalized frequency with ``|f|`` in
    ``[f_min, f_max]`` (random sign — the neighbor can be on either side),
    random phase, and total interference power ``10^(-sir_db/10)`` relative
    to the *current* signal power, split evenly across tones.
    """
    n = sig.shape[-1]
    kf, ks, kp = jax.random.split(key, 3)
    f = jax.random.uniform(kf, (n_tones,), minval=f_min, maxval=f_max)
    sign = jnp.where(jax.random.bernoulli(ks, 0.5, (n_tones,)), 1.0, -1.0)
    phi = jax.random.uniform(kp, (n_tones,), minval=0.0, maxval=2 * jnp.pi)
    t = jnp.arange(n, dtype=jnp.float32)
    tones = jnp.exp(1j * (2 * jnp.pi * (sign * f)[:, None] * t[None, :]
                          + phi[:, None]))
    p_int = avg_power(sig) * 10.0 ** (-sir_db / 10.0)
    amp = jnp.sqrt(p_int / n_tones)
    return sig + amp * tones.sum(axis=0).astype(sig.dtype)


# ---------------------------------------------------------------------------
# Multiplicative (power-preserving) impairments.
# ---------------------------------------------------------------------------

def carrier_offset(sig: jax.Array, key: jax.Array, max_cfo: float,
                   random_phase: bool = True) -> jax.Array:
    """Random carrier frequency offset (uniform in ±max_cfo) + phase.

    A unitary per-sample rotation: average power is preserved exactly.
    """
    kc, kp = jax.random.split(key)
    cfo = jax.random.uniform(kc, (), minval=-max_cfo, maxval=max_cfo)
    phi0 = jnp.where(random_phase,
                     jax.random.uniform(kp, (), minval=0.0,
                                        maxval=2 * jnp.pi), 0.0)
    n = jnp.arange(sig.shape[-1], dtype=jnp.float32)
    return sig * jnp.exp(1j * (2 * jnp.pi * cfo * n + phi0))


def phase_noise(sig: jax.Array, key: jax.Array, scale: float) -> jax.Array:
    """Wiener-process oscillator phase noise (random-walk phase).

    Matches the legacy channel's ``cumsum(normal * scale)`` model; unitary,
    so power-preserving.
    """
    pn = jnp.cumsum(jax.random.normal(key, sig.shape) * scale)
    return sig * jnp.exp(1j * pn)


def timing_offset(sig: jax.Array, key: jax.Array, max_sro: float,
                  max_jitter: float = 0.5) -> jax.Array:
    """Sample-rate offset + fractional timing via a Farrow resampler.

    Draws a relative rate offset ``sro`` uniform in ``±max_sro`` and an
    initial fractional delay uniform in ``[0, max_jitter]`` samples, then
    evaluates the signal at ``t_k = k * (1 + sro) + tau`` with the cubic
    Lagrange Farrow structure (four neighboring taps, polynomial in the
    fractional part — the standard software-radio fractional resampler).
    Edge samples clamp to the frame boundary; output power is renormalized
    to the input's.
    """
    n = sig.shape[-1]
    ks, kt = jax.random.split(key)
    sro = jax.random.uniform(ks, (), minval=-max_sro, maxval=max_sro)
    tau = jax.random.uniform(kt, (), minval=0.0, maxval=max_jitter)
    t = jnp.arange(n, dtype=jnp.float32) * (1.0 + sro) + tau
    base = jnp.floor(t)
    mu = t - base                      # fractional part in [0, 1)
    i0 = base.astype(jnp.int32) - 1    # taps at i0 .. i0+3
    idx = jnp.clip(i0[None, :] + jnp.arange(4)[:, None], 0, n - 1)
    x = sig[idx]                       # (4, L) neighbor taps
    # cubic Lagrange basis in mu (Farrow branch polynomials)
    c0 = -mu * (mu - 1.0) * (mu - 2.0) / 6.0
    c1 = (mu + 1.0) * (mu - 1.0) * (mu - 2.0) / 2.0
    c2 = -(mu + 1.0) * mu * (mu - 2.0) / 2.0
    c3 = (mu + 1.0) * mu * (mu - 1.0) / 6.0
    out = (c0 * x[0] + c1 * x[1] + c2 * x[2] + c3 * x[3]).astype(sig.dtype)
    return _match_power(out, sig)


def iq_imbalance(sig: jax.Array, key: jax.Array, max_amp_db: float,
                 max_phase_deg: float) -> jax.Array:
    """Receiver I/Q gain + phase mismatch: ``y = mu*x + nu*conj(x)``.

    Draws a gain mismatch uniform in ``±max_amp_db`` and a phase mismatch
    uniform in ``±max_phase_deg`` and applies the standard baseband model
    ``mu = (1 + g e^{j phi})/2``, ``nu = (1 - g e^{j phi})/2`` (the image
    term ``nu`` is what makes IQ imbalance visible to a classifier).
    Output power is renormalized to the input's.
    """
    kg, kp = jax.random.split(key)
    g_db = jax.random.uniform(kg, (), minval=-max_amp_db, maxval=max_amp_db)
    phi = jnp.deg2rad(jax.random.uniform(kp, (), minval=-max_phase_deg,
                                         maxval=max_phase_deg))
    g = 10.0 ** (g_db / 20.0)
    rot = g * jnp.exp(1j * phi)
    mu = 0.5 * (1.0 + rot)
    nu = 0.5 * (1.0 - rot)
    out = (mu * sig + nu * jnp.conj(sig)).astype(sig.dtype)
    return _match_power(out, sig)


def multipath_fading(sig: jax.Array, key: jax.Array,
                     path_delays: Sequence[int] = (0, 1, 3),
                     path_powers: Sequence[float] = (1.0, 0.5, 0.25),
                     doppler: float = 0.01, rician_k: float = 0.0,
                     n_sinusoids: int = 8) -> jax.Array:
    """Time-varying Rayleigh/Rician multipath with Doppler.

    Each discrete-delay path carries an independent Jakes sum-of-sinusoids
    tap process: ``h_p(t) = sum_k exp(j(2 pi f_d t cos(a_k) + phi_k)) /
    sqrt(K)`` with random arrival angles ``a_k`` and phases ``phi_k`` —
    seed-deterministic, fully traceable, and time-*selective* when
    ``doppler`` (max Doppler shift, cycles/sample) is nonzero.  With
    ``rician_k > 0`` the first path gets a constant line-of-sight component
    at K-factor ``rician_k`` (Rician fading); ``rician_k = 0`` is pure
    Rayleigh.  ``path_powers`` (the power-delay profile) are normalized to
    sum to one and delays are static sample shifts (frame-edge zero fill).
    Output power is renormalized to the input's, so fading reshapes the
    frame without moving the operating SNR.
    """
    delays = tuple(int(d) for d in path_delays)
    powers = np.asarray(path_powers, np.float32)
    powers = powers / powers.sum()
    n = sig.shape[-1]
    t = jnp.arange(n, dtype=jnp.float32)
    out = jnp.zeros_like(sig)
    keys = jax.random.split(key, len(delays))
    for p, (d, kp) in enumerate(zip(delays, keys)):
        ka, kf, kl = jax.random.split(kp, 3)
        angles = jax.random.uniform(ka, (n_sinusoids,), minval=0.0,
                                    maxval=2 * jnp.pi)
        phases = jax.random.uniform(kf, (n_sinusoids,), minval=0.0,
                                    maxval=2 * jnp.pi)
        osc = jnp.exp(1j * (2 * jnp.pi * doppler
                            * jnp.cos(angles)[:, None] * t[None, :]
                            + phases[:, None]))
        h = osc.sum(axis=0) / jnp.sqrt(jnp.float32(n_sinusoids))
        if p == 0 and rician_k > 0.0:
            theta = jax.random.uniform(kl, (), minval=0.0, maxval=2 * jnp.pi)
            los = jnp.sqrt(rician_k / (rician_k + 1.0)) * jnp.exp(1j * theta)
            h = los + h * jnp.sqrt(1.0 / (rician_k + 1.0))
        delayed = sig if d == 0 else jnp.concatenate(
            [jnp.zeros((d,), sig.dtype), sig[..., :-d]], axis=-1)
        out = out + jnp.sqrt(powers[p]) * h.astype(sig.dtype) * delayed
    return _match_power(out, sig)


# ---------------------------------------------------------------------------
# The legacy host-side channel (moved verbatim from repro.data.radioml).
# ---------------------------------------------------------------------------

def legacy_awgn_channel(
    rng: np.random.Generator, sig: np.ndarray, snr_db: float,
    max_cfo: float = 0.01, phase_noise: bool = True,
) -> np.ndarray:
    """The dataset generator's channel: AWGN + random CFO/phase (+ phase
    noise), vectorized numpy, deterministic in the ``rng`` state.

    This is the original ``repro.data.radioml._apply_channel`` — it lives
    here so the ``static_awgn`` scenario and the dataset share one
    implementation; ``radioml._apply_channel`` aliases it (bit-equal by
    construction, pinned by tests).
    """
    n = len(sig)
    # random carrier frequency + phase offset
    cfo = rng.uniform(-max_cfo, max_cfo)
    phi0 = rng.uniform(0, 2 * np.pi)
    sig = sig * np.exp(1j * (2 * np.pi * cfo * np.arange(n) + phi0))
    if phase_noise:
        pn = np.cumsum(rng.normal(scale=2e-3, size=n))
        sig = sig * np.exp(1j * pn)
    # normalize signal power then add AWGN at requested SNR
    p_sig = np.mean(np.abs(sig) ** 2) + 1e-12
    sig = sig / np.sqrt(p_sig)
    p_noise = 10 ** (-snr_db / 10)
    noise = (rng.normal(size=n) + 1j * rng.normal(size=n)) * np.sqrt(p_noise / 2)
    return sig + noise
