"""RF channel-impairment simulation: the scenario-diversity leg of the stack.

JAX-traceable, seed-deterministic impairments (:mod:`.impairments`)
composed into declarative named :class:`ChannelScenario` stacks
(:mod:`.scenario`) with one vmapped/jitted entry point,
:func:`apply_scenario` — usable host-side in the data pipeline and inside
compiled serving/training steps — plus :func:`make_frame_source`, the
adapter that lets :class:`repro.deploy.CanaryMonitor` shadow-evaluate
under injected channel drift.
"""

from .impairments import (
    avg_power,
    awgn,
    carrier_offset,
    interferer_tones,
    iq_imbalance,
    legacy_awgn_channel,
    multipath_fading,
    normalize_power,
    phase_noise,
    timing_offset,
    to_complex,
    to_iq,
)
from .scenario import (
    SCENARIOS,
    SUITES,
    ChannelScenario,
    apply_scenario,
    apply_scenario_np,
    get_scenario,
    make_frame_source,
    scenario_fn,
    stable_seed,
    suite_scenarios,
)

__all__ = [
    "ChannelScenario",
    "SCENARIOS",
    "SUITES",
    "get_scenario",
    "suite_scenarios",
    "apply_scenario",
    "apply_scenario_np",
    "scenario_fn",
    "stable_seed",
    "make_frame_source",
    "to_complex",
    "to_iq",
    "avg_power",
    "normalize_power",
    "awgn",
    "carrier_offset",
    "phase_noise",
    "timing_offset",
    "iq_imbalance",
    "multipath_fading",
    "interferer_tones",
    "legacy_awgn_channel",
]
