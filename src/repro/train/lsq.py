"""Learned Step Size Quantization (LSQ) for 16-bit fixed-point weights.

Paper §IV-C.2: weights are quantized to 16-bit fixed point for the FPGA;
LSQ treats the quantization step size as a trainable parameter optimized by
backprop through straight-through estimators.  Forward/backward simulate
the quantization; full-precision master weights receive the gradients.

Implementation follows Esser et al. (LSQ, ICLR 2020): the step-size
gradient is scaled by 1/sqrt(N * Q_max) for stable joint training.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

__all__ = ["lsq_fake_quant", "init_lsq_scales", "make_serving_quant_fn",
           "quantize_to_int", "dequantize", "STEP_FLOOR"]


def _round_ste(x: jax.Array) -> jax.Array:
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def lsq_fake_quant(w: jax.Array, step: jax.Array, bits: int = 16) -> jax.Array:
    """Fake-quantize w with trainable step size (per-tensor).

    Gradients: straight-through to w inside the clip range; LSQ gradient to
    ``step`` (including the grad-scale trick).
    """
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    grad_scale = 1.0 / jnp.sqrt(jnp.asarray(w.size, jnp.float32) * qmax)
    # grad-scale trick: value of `step`, gradient scaled by grad_scale
    s = step * grad_scale + jax.lax.stop_gradient(step * (1.0 - grad_scale))
    s = jnp.maximum(s, 1e-12)
    w_div = w / s
    w_clip = jnp.clip(w_div, qmin, qmax)
    w_q = _round_ste(w_clip)
    return w_q * s


STEP_FLOOR = 1e-8  # minimum usable step: keeps w/s finite for all-zero layers


def init_lsq_scales(params: Dict, bits: int = 16) -> Dict:
    """Per-layer initial step size: 2*mean|w| / sqrt(Q_max) (LSQ init).

    Floored at :data:`STEP_FLOOR` so a fully-pruned / all-zero layer gets
    a tiny-but-usable step instead of zero (the downstream ``w / s`` guard
    only clamps at 1e-12 after the grad-scale trick, which explodes the
    quotient instead of quantizing to zero codes).
    """
    qmax = 2 ** (bits - 1) - 1

    def init_one(w):
        s = 2.0 * jnp.mean(jnp.abs(w)) / jnp.sqrt(jnp.asarray(qmax, jnp.float32))
        return jnp.maximum(s, STEP_FLOOR)

    return {
        "conv": [init_one(l["w"]) for l in params["conv"]],
        "fc": [init_one(l["w"]) for l in params["fc"]],
    }


def make_serving_quant_fn(lsq_scales: Dict, bits: int = 16):
    """Per-layer fake-quant closure for bind/compile paths.

    Mirrors the trainer's ``_loss_fn`` threading: the bind walks the
    weighted layers in graph order (conv then fc), so a stateful index
    hands each layer its own trained step size.  Returns a **fresh**
    closure — callers must not share one across compiles (the index
    would drift if a compile aborts partway).
    """
    flat = list(lsq_scales["conv"]) + list(lsq_scales["fc"])
    idx = {"i": 0}

    def quant_fn(w: jax.Array) -> jax.Array:
        s = flat[idx["i"] % len(flat)]
        idx["i"] += 1
        return lsq_fake_quant(w, s, bits)

    return quant_fn


def quantize_to_int(w: jax.Array, step: jax.Array, bits: int = 16) -> jax.Array:
    """Final conversion to integer codes (deployment form)."""
    qmax = 2 ** (bits - 1) - 1
    qmin = -(2 ** (bits - 1))
    codes = jnp.clip(jnp.round(w / step), qmin, qmax)
    dtype = jnp.int16 if bits <= 16 else jnp.int32
    return codes.astype(dtype)


def dequantize(codes: jax.Array, step: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * step
