"""Anomaly detection over recorded series, with a firing/resolved
alert lifecycle that drives the existing control loops.

Detection is deliberately simple and dependency-free: an
:class:`EwmaDetector` keeps an exponentially-weighted mean and variance
(West 1979 incremental form) and flags a sample whose z-score against
that baseline exceeds a threshold — after a warmup count so the baseline
is learned from the stream itself, not configured.  That is enough for
every signal the issue names, because they are all *level* signals:

* ``repro_activity_effective_density{layer}`` — the paper's sparsity
  operating point; a sustained shift means the input distribution moved
  (the drift-detect half of the ROADMAP's continual-learning loop);
* ``repro_activity_events_per_frame{layer}`` and
  ``repro_activity_accum_ratio_vs_dense{layer}`` — the Tables I/III
  workload counters, drifting with the same cause;
* ``repro_canary_window_accuracy`` / per-SNR canary accuracy — the
  model-quality signal.

Alerts flow through one :class:`AlertManager`:

* dedup by ``(name, labels)`` — repeated anomalous samples refresh one
  firing alert instead of flooding;
* explicit ``firing -> resolved`` transitions, each pushed to pluggable
  sinks and mirrored in the ``repro_alerts_firing{alert}`` gauge so the
  alert state itself is scrapeable (and recordable, and SLO-able);
* :func:`autoscaler_sink` converts a firing page-severity latency alert
  into scale-up pressure on the existing :class:`~repro.fleet.autoscaler.
  Autoscaler`; :func:`canary_shadow_sink` converts a firing sparsity-
  drift alert into a :class:`~repro.deploy.monitor.CanaryMonitor`
  shadow-evaluation step.  Detection drives the loops that already know
  how to act.

:class:`SeriesWatcher` ties it together: recorder series -> detectors ->
manager, one ``step()`` per recorder sweep.  :class:`BurnRateWatcher`
does the same for :class:`~repro.obs.slo.BurnRateEngine` statuses.
"""
from __future__ import annotations

import json
import math
import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import MetricsRegistry, default_registry
from repro.obs.slo import BurnRateEngine, SLOStatus
from repro.obs.timeseries import TimeSeriesRecorder

__all__ = ["EwmaDetector", "Alert", "AlertManager", "WatchSpec",
           "default_drift_watches", "SeriesWatcher", "BurnRateWatcher",
           "autoscaler_sink", "canary_shadow_sink", "log_file_sink",
           "set_default_alert_manager", "get_default_alert_manager"]


class EwmaDetector:
    """EWMA mean/variance z-score detector for one scalar stream.

    ``alpha`` is the smoothing factor (higher = faster-moving baseline);
    ``threshold`` the |z| that flags; ``min_samples`` the warmup before
    any sample can flag (the baseline must be learned first);
    ``direction`` restricts to drops (``"down"``), rises (``"up"``), or
    both.  While a sample is anomalous the baseline is *frozen* — a
    sustained shift keeps flagging instead of being absorbed, and the
    alert resolves only when the signal returns to the learned band.
    """

    def __init__(self, *, alpha: float = 0.1, threshold: float = 4.0,
                 min_samples: int = 8, direction: str = "both",
                 min_std: float = 1e-6):
        if direction not in ("both", "up", "down"):
            raise ValueError(f"bad direction {direction!r}")
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self.direction = direction
        self.min_std = min_std
        self.mean = 0.0
        self.var = 0.0          # variance once warm (from Welford M2)
        self._m2 = 0.0          # Welford sum of squared deviations
        self.n = 0

    def update(self, x: float) -> Tuple[bool, float]:
        """Feed one sample; returns (is_anomaly, z_score)."""
        x = float(x)
        if self.n < self.min_samples:
            # warmup: Welford incremental mean/variance
            self.n += 1
            d = x - self.mean
            self.mean += d / self.n
            self._m2 += d * (x - self.mean)
            if self.n == self.min_samples:
                self.var = self._m2 / max(1, self.n - 1)
            return False, 0.0
        std = max(self.min_std, math.sqrt(max(0.0, self.var)))
        z = (x - self.mean) / std
        anomalous = ((self.direction in ("both", "up") and
                      z > self.threshold)
                     or (self.direction in ("both", "down") and
                         z < -self.threshold))
        if not anomalous:
            # EWMA update of mean and variance (frozen while anomalous)
            d = x - self.mean
            incr = self.alpha * d
            self.mean += incr
            self.var = (1 - self.alpha) * (self.var + d * incr)
            self.n += 1
        return anomalous, z


@dataclass
class Alert:
    """One alert instance, dedup-keyed by (name, labels)."""
    name: str
    labels: Tuple[Tuple[str, str], ...] = ()
    severity: str = "page"              # "page" | "ticket"
    state: str = "firing"               # "firing" | "resolved"
    value: float = 0.0
    threshold: float = 0.0
    reason: str = ""
    t_fired: float = 0.0
    t_resolved: Optional[float] = None
    n_refires: int = 0                  # re-triggers while already firing

    @property
    def key(self) -> Tuple:
        return (self.name, self.labels)

    def to_dict(self) -> Dict:
        return {
            "name": self.name, "labels": dict(self.labels),
            "severity": self.severity, "state": self.state,
            "value": self.value, "threshold": self.threshold,
            "reason": self.reason, "t_fired": self.t_fired,
            "t_resolved": self.t_resolved, "n_refires": self.n_refires,
        }


#: sink(alert, transition) where transition is "fire" | "resolve"
AlertSink = Callable[[Alert, str], None]


class AlertManager:
    """Dedup + lifecycle + fan-out for alerts.

    ``fire`` on an already-firing key refreshes it (value/reason update,
    refire count) without re-notifying sinks; ``resolve`` on a firing
    key transitions it and notifies.  The ``repro_alerts_firing{alert}``
    gauge mirrors the firing set so the alerting plane is itself
    observable.  Sink exceptions are swallowed into ``sink_errors`` —
    one broken consumer must not take down detection.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 *, clock: Optional[Callable[[], float]] = None):
        self._registry = registry
        self._clock = clock
        self._lock = threading.Lock()
        self._alerts: Dict[Tuple, Alert] = {}
        self._sinks: List[AlertSink] = []
        self.history: List[Alert] = []
        self.sink_errors = 0

    def _reg(self) -> MetricsRegistry:
        return self._registry if self._registry is not None \
            else default_registry()

    def _gauge(self, alert: Alert) -> None:
        # several label sets can share one alert name (e.g. page+ticket
        # burns): the gauge is the count still firing under that name
        with self._lock:
            n = sum(1 for a in self._alerts.values()
                    if a.name == alert.name and a.state == "firing")
        self._reg().gauge(
            "repro_alerts_firing",
            "Number of firing alert instances under the named alert.",
            labelnames=("alert",)).labels(alert=alert.name).set(float(n))

    def add_sink(self, sink: AlertSink) -> None:
        with self._lock:
            self._sinks.append(sink)

    def _notify(self, alert: Alert, transition: str) -> None:
        with self._lock:
            sinks = list(self._sinks)
        for sink in sinks:
            try:
                sink(alert, transition)
            except Exception:
                with self._lock:
                    self.sink_errors += 1

    # -- lifecycle -----------------------------------------------------------

    def fire(self, name: str, *, labels: Dict[str, str] = None,
             severity: str = "page", value: float = 0.0,
             threshold: float = 0.0, reason: str = "",
             t: Optional[float] = None) -> Alert:
        key = (name, tuple(sorted((labels or {}).items())))
        now = t if t is not None else (self._clock() if self._clock
                                       else 0.0)
        with self._lock:
            existing = self._alerts.get(key)
            if existing is not None and existing.state == "firing":
                existing.value = value
                existing.reason = reason or existing.reason
                existing.n_refires += 1
                return existing
            alert = Alert(name=name, labels=key[1], severity=severity,
                          state="firing", value=value, threshold=threshold,
                          reason=reason, t_fired=now)
            self._alerts[key] = alert
            self.history.append(alert)
        self._gauge(alert)
        self._notify(alert, "fire")
        return alert

    def resolve(self, name: str, *, labels: Dict[str, str] = None,
                t: Optional[float] = None) -> Optional[Alert]:
        key = (name, tuple(sorted((labels or {}).items())))
        now = t if t is not None else (self._clock() if self._clock
                                       else 0.0)
        with self._lock:
            alert = self._alerts.get(key)
            if alert is None or alert.state != "firing":
                return None
            alert.state = "resolved"
            alert.t_resolved = now
        self._gauge(alert)
        self._notify(alert, "resolve")
        return alert

    # -- queries -------------------------------------------------------------

    def firing(self, severity: Optional[str] = None) -> List[Alert]:
        with self._lock:
            out = [a for a in self._alerts.values() if a.state == "firing"]
        if severity is not None:
            out = [a for a in out if a.severity == severity]
        return sorted(out, key=lambda a: a.key)

    def all_alerts(self) -> List[Alert]:
        with self._lock:
            return sorted(self._alerts.values(), key=lambda a: a.key)

    def to_json(self) -> Dict:
        return {
            "firing": [a.to_dict() for a in self.firing()],
            "alerts": [a.to_dict() for a in self.all_alerts()],
            "n_history": len(self.history),
            "sink_errors": self.sink_errors,
        }


# -- watchers: series/SLO statuses -> alerts ---------------------------------

@dataclass
class WatchSpec:
    """One watched (metric, labels) pattern with its detector factory."""
    metric: str
    labels: Tuple[Tuple[str, str], ...] = ()
    alert_name: str = ""
    severity: str = "ticket"
    detector: Callable[[], EwmaDetector] = field(
        default_factory=lambda: (lambda: EwmaDetector()))


#: Stock drift watches over the live activity gauges (sparsity drift is
#: a *drop or rise* in effective density / events-per-frame).
def default_drift_watches() -> List[WatchSpec]:
    mk = lambda: EwmaDetector(alpha=0.15, threshold=4.0, min_samples=8)
    return [
        WatchSpec("repro_activity_effective_density",
                  alert_name="sparsity_drift", severity="ticket",
                  detector=mk),
        WatchSpec("repro_activity_events_per_frame",
                  alert_name="events_per_frame_drift", severity="ticket",
                  detector=mk),
        WatchSpec("repro_activity_accum_ratio_vs_dense",
                  alert_name="accum_ratio_drift", severity="ticket",
                  detector=mk),
        WatchSpec("repro_canary_window_accuracy",
                  alert_name="canary_accuracy_drift", severity="page",
                  detector=lambda: EwmaDetector(
                      alpha=0.15, threshold=4.0, min_samples=8,
                      direction="down")),
    ]


class SeriesWatcher:
    """Feeds new recorder samples through per-series detectors.

    ``step()`` walks each watched series' points appended since the last
    step and updates that series' own detector (one baseline per label
    set — conv1's density does not pollute conv3's).  A flagged sample
    fires the alert; a clean sample on a firing alert resolves it.
    """

    def __init__(self, recorder: TimeSeriesRecorder, manager: AlertManager,
                 watches: Optional[Sequence[WatchSpec]] = None):
        self.recorder = recorder
        self.manager = manager
        self.watches = list(watches if watches is not None
                            else default_drift_watches())
        self._detectors: Dict[Tuple, EwmaDetector] = {}
        self._cursor: Dict[Tuple, float] = {}   # last consumed timestamp

    def step(self) -> List[Alert]:
        fired: List[Alert] = []
        series = self.recorder.series()
        for w in self.watches:
            want = dict(w.labels)
            for s in series:
                if s.name != w.metric or s.kind == "histogram":
                    continue
                have = dict(s.labels)
                if not all(have.get(k) == v for k, v in want.items()):
                    continue
                skey = (w.metric, s.labels)
                det = self._detectors.get(skey)
                if det is None:
                    det = self._detectors[skey] = w.detector()
                last_t = self._cursor.get(skey, float("-inf"))
                alert_name = w.alert_name or f"{w.metric}_anomaly"
                labels = dict(s.labels)
                for t, v in s.points():
                    if t <= last_t:
                        continue
                    last_t = t
                    anomalous, z = det.update(float(v))
                    if anomalous:
                        fired.append(self.manager.fire(
                            alert_name, labels=labels, severity=w.severity,
                            value=float(v), threshold=det.threshold,
                            reason=(f"{w.metric} z={z:+.1f} vs EWMA "
                                    f"mean={det.mean:.4g}"),
                            t=t))
                    else:
                        self.manager.resolve(alert_name, labels=labels, t=t)
                self._cursor[skey] = last_t
        return fired


class BurnRateWatcher:
    """Turns :class:`BurnRateEngine` statuses into burn-rate alerts.

    One alert per (SLO, severity): fires while that window pair breaches
    its factor, resolves when it stops.  Alert names are
    ``slo_burn:<slo>`` with a ``severity`` label, so the autoscaler sink
    can key on page-severity latency burns specifically.
    """

    def __init__(self, engine: BurnRateEngine, manager: AlertManager):
        self.engine = engine
        self.manager = manager

    def step(self, now: Optional[float] = None) -> List[SLOStatus]:
        statuses = self.engine.evaluate(now)
        for st in statuses:
            for w in self.engine.windows:
                labels = {"severity": w.severity}
                name = f"slo_burn:{st.slo.name}"
                if w.severity in st.firing:
                    b_long, b_short = st.burns[w.severity]
                    self.manager.fire(
                        name, labels=labels, severity=w.severity,
                        value=float(b_long or 0.0), threshold=w.factor,
                        reason=(f"burn {b_long:.1f}x/{b_short:.1f}x over "
                                f"{w.long_s:g}s/{w.short_s:g}s windows"),
                        t=st.t)
                else:
                    self.manager.resolve(name, labels=labels, t=st.t)
        return statuses


# -- sinks into the existing control loops -----------------------------------

def autoscaler_sink(autoscaler) -> AlertSink:
    """Firing page-severity burn/latency alerts press the autoscaler up.

    The :class:`~repro.fleet.autoscaler.Autoscaler` exposes
    ``alert_pressure`` (PR 10): while set, its next ``step()`` treats the
    fleet as overloaded regardless of instantaneous p99 — an SLO burn is
    a longer-horizon signal than one tick's latency sample.
    """
    def sink(alert: Alert, transition: str) -> None:
        if alert.severity != "page":
            return
        relevant = (alert.name.startswith("slo_burn:latency")
                    or alert.name.startswith("slo_burn:availability")
                    or "p99" in alert.name)
        if not relevant:
            return
        if transition == "fire":
            autoscaler.set_alert_pressure(alert.name)
        else:
            autoscaler.clear_alert_pressure(alert.name)
    return sink


def canary_shadow_sink(monitor) -> AlertSink:
    """Firing sparsity-drift alerts trigger a canary shadow evaluation.

    Drift in effective density means the input distribution moved; the
    :class:`~repro.deploy.monitor.CanaryMonitor` already knows how to
    shadow-evaluate a candidate under the live distribution — this sink
    just makes detection call it (while a decision is still pending).
    """
    drift_names = ("sparsity_drift", "events_per_frame_drift",
                   "accum_ratio_drift")
    lock = threading.Lock()

    def sink(alert: Alert, transition: str) -> None:
        if transition != "fire" or alert.name not in drift_names:
            return
        with lock:
            if getattr(monitor, "decision", "pending") != "pending":
                return
            monitor.step()
    return sink


def log_file_sink(path: str) -> AlertSink:
    """Append one JSON line per alert transition to ``path``."""
    lock = threading.Lock()

    def sink(alert: Alert, transition: str) -> None:
        line = json.dumps({"transition": transition, **alert.to_dict()},
                          sort_keys=True)
        with lock:
            with open(path, "a") as f:
                f.write(line + "\n")
    return sink


# -- process-wide manager (what the /alerts endpoint serves) -----------------

_manager: Optional[AlertManager] = None
_manager_lock = threading.Lock()


def set_default_alert_manager(
        manager: Optional[AlertManager]) -> Optional[AlertManager]:
    """Install the process-wide alert manager; returns the previous."""
    global _manager
    with _manager_lock:
        old, _manager = _manager, manager
        return old


def get_default_alert_manager() -> Optional[AlertManager]:
    with _manager_lock:
        return _manager
