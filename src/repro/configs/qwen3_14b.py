"""qwen3-14b [dense] — hf:Qwen/Qwen3 lineage (verified: hf).

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936; per-head q/k RMS
norm (qk_norm), no QKV bias (Qwen3 dropped it).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv=8, d_ff=17408,
    vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0,
)
