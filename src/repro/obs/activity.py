"""Live sparsity/activity telemetry: the paper's Tables I/III, per batch.

The paper's central claim is activity-proportional cost: GOAP computes
only the non-zero input x weight intersections, so the iteration schedule
(Table I — reps/compute/extra/empty per conv layer, fixed by the masked
weights) and the gated accumulation counts (Table III — input-dependent)
*are* the cost model.  The ``stream`` and ``pallas_fused`` backends
already produce those counters in-graph; this module surfaces them on
the serving path as live per-batch gauges:

* ``repro_activity_schedule{layer,counter}`` — the static Table I
  geometry (input-independent, set once at bind time);
* ``repro_activity_accumulations_total{engine,layer}`` — cumulative
  gated accumulations over real (non-padded) served frames;
* ``repro_activity_events_per_frame{engine,layer}`` — mean accumulations
  per frame in the last batch;
* ``repro_activity_accum_ratio_vs_dense{engine,layer}`` — last-batch
  events/frame over the dense MAC count (kw*ic*oc*W*T): the
  sparsity-proportionality readout (Table III's ratio);
* ``repro_activity_effective_density{engine,layer}`` — events/frame over
  nnz*W*T: the effective input-activity fraction the schedule saw.

Exactness: counters are carried in float32 on-device; every pinned
golden value (max 437602) is far below 2**24, so the live gauges agree
*bit-exactly* with ``tests/test_stream_golden.py`` literals on the paper
config (asserted in ``tests/test_obs.py``).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional

import numpy as np

from repro.obs.metrics import MetricsRegistry, default_registry

__all__ = ["SCHEDULE_KEYS", "ActivityObserver", "static_schedule_counts"]

#: Table I keys: fixed by the masked weights, independent of the input.
SCHEDULE_KEYS = ("reps_per_timestep", "compute_iters", "extra_iters",
                 "empty_iters")


def static_schedule_counts(plan) -> Dict[str, Dict[str, int]]:
    """Per-conv-layer Table I schedule geometry of a counter-capable plan.

    The fused multi-layer kernel precomputes these at stack build time;
    the ``stream`` assignment carries them in its schedule interpreter,
    so one eager pass over an all-zero frame (zero input activity — the
    accumulation counters stay 0, the schedule counters are constants)
    reads them out without touching any serving state.
    """
    from repro.kernels.stream_fused import FusedConv

    stack = plan.fused_stack()
    if stack is not None:
        return {layer.name: dict(layer.static_counts)
                for layer in stack.layers if isinstance(layer, FusedConv)}
    import jax.numpy as jnp

    cfg = plan.cfg
    zeros = jnp.zeros((cfg.timesteps, cfg.conv_specs[0][1],
                       cfg.input_width), jnp.float32)
    _, counters = plan.run_streaming(zeros)
    return {name: {k: int(np.asarray(c[k])) for k in SCHEDULE_KEYS}
            for name, c in counters.items()}


def _conv_geometry(plan) -> List[Dict[str, float]]:
    """Per conv layer: name, nnz, input width, T — the gauge denominators.

    Widths walk the layer graph: ``pad_same`` convs preserve width, each
    pool divides it, so layer order (not just conv_specs) decides.
    """
    from repro.models.graph import KIND_CONV, KIND_POOL

    cfg = plan.cfg
    width = cfg.input_width
    out = []
    for lp in plan.layers:
        if lp.spec.kind == KIND_CONV:
            nnz = int(lp.cost.get("nnz", lp.spec.kw * lp.spec.ic * lp.spec.oc))
            out.append({
                "name": lp.spec.name,
                "nnz": nnz,
                "width": width,
                "dense_macs_per_frame":
                    float(lp.spec.kw * lp.spec.ic * lp.spec.oc
                          * width * cfg.timesteps),
                "sparse_macs_per_frame": float(nnz * width * cfg.timesteps),
            })
        elif lp.spec.kind == KIND_POOL:
            width = width // max(1, lp.spec.pool)
    return out


class ActivityObserver:
    """Records one plan's per-batch activity counters into the registry.

    Built once per bound version (bind time, off the hot path); per batch
    the serving worker calls :meth:`observe` with the counter dict the
    plan's ``batch_counters`` step returned — a handful of guarded float
    adds, no device work.
    """

    def __init__(self, plan, registry: Optional[MetricsRegistry] = None,
                 engine: str = "engine"):
        reg = registry if registry is not None else default_registry()
        self.engine = engine
        self.geometry = _conv_geometry(plan)
        self.timesteps = int(plan.cfg.timesteps)

        sched = reg.gauge(
            "repro_activity_schedule",
            "Table I static schedule geometry per conv layer "
            "(reps_per_timestep/compute_iters/extra_iters/empty_iters)",
            ("layer", "counter"))
        for name, counts in static_schedule_counts(plan).items():
            for key, val in counts.items():
                sched.labels(layer=name, counter=key).set(val)

        self._frames = reg.counter(
            "repro_activity_frames_total",
            "Real (non-padded) frames whose activity was counted",
            ("engine",)).labels(engine=engine)
        fam_acc = reg.counter(
            "repro_activity_accumulations_total",
            "Cumulative gated accumulations (Table III) over served frames",
            ("engine", "layer"))
        fam_epf = reg.gauge(
            "repro_activity_events_per_frame",
            "Mean gated accumulations per frame in the last served batch",
            ("engine", "layer"))
        fam_ratio = reg.gauge(
            "repro_activity_accum_ratio_vs_dense",
            "Last-batch events/frame over the dense MAC count "
            "(kw*ic*oc*W*T): the sparsity-proportionality readout",
            ("engine", "layer"))
        fam_dens = reg.gauge(
            "repro_activity_effective_density",
            "Last-batch events/frame over nnz*W*T: effective input-"
            "activity fraction", ("engine", "layer"))
        self._per_layer = {
            g["name"]: {
                "geom": g,
                "acc": fam_acc.labels(engine=engine, layer=g["name"]),
                "epf": fam_epf.labels(engine=engine, layer=g["name"]),
                "ratio": fam_ratio.labels(engine=engine, layer=g["name"]),
                "density": fam_dens.labels(engine=engine, layer=g["name"]),
            }
            for g in self.geometry
        }

    def observe(self, accumulations: Mapping[str, np.ndarray],
                n_real: int) -> None:
        """Account one served batch.

        ``accumulations``: per-conv-layer ``(B,)`` gated accumulation
        counts from the plan's counter-returning batch step.  Only the
        first ``n_real`` rows are real — the batcher pads the tail, and
        padded rows must never leak into activity stats (their all-zero
        frames contribute zero accumulations, but counting their frames
        would still dilute the per-frame gauges).
        """
        if n_real <= 0:
            return
        self._frames.inc(n_real)
        for name, handles in self._per_layer.items():
            acc = accumulations.get(name)
            if acc is None:
                continue
            total = float(np.asarray(acc)[:n_real].sum())
            per_frame = total / n_real
            g = handles["geom"]
            handles["acc"].inc(total)
            handles["epf"].set(per_frame)
            handles["ratio"].set(per_frame / g["dense_macs_per_frame"])
            handles["density"].set(per_frame / g["sparse_macs_per_frame"])
