"""Cross-backend equivalence for the unified layer-graph execution API.

The paper's central claim made executable: one model definition
(``SNNConfig`` -> ``LayerSpec`` graph) produces identical logits through
every registered execution dataflow — dense sliding-window oracle, COO
GOAP, block-sparse Pallas (interpret mode on CPU), and the faithful
Algorithm-2 streaming emulator.
"""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    SNNConfig,
    available_backends,
    build_layer_graph,
    compile_snn,
    get_backend,
    init_snn,
    register_backend,
    stream_totals,
)
from repro.models.snn import (
    snn_forward,
    snn_forward_batch,
    snn_forward_sparse,
    sparsify_params,
)
from repro.train.pruning import make_mask_pytree

# Reduced config: same topology as the paper's model, smoke-test sized.
CFG = SNNConfig(
    conv_specs=((3, 2, 4), (3, 4, 8)),
    pool=2,
    fc_specs=((32, 16), (16, 5)),
    input_width=16,
    timesteps=3,
    n_classes=5,
)

ALL_BACKENDS = ("dense", "goap", "pallas", "stream")


def _frames(seed=0, density=0.5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        (rng.random((CFG.timesteps, CFG.conv_specs[0][1], CFG.input_width))
         < density).astype(np.float32))


@pytest.fixture(scope="module")
def setup():
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, 0.5)
    return compile_snn(CFG), params, masks


# ---------------------------------------------------------------------------
# graph structure
# ---------------------------------------------------------------------------

def test_layer_graph_shape():
    layers = build_layer_graph(CFG)
    kinds = [s.kind for s in layers]
    assert kinds == ["conv_lif", "maxpool", "conv_lif", "maxpool",
                     "fc_lif", "fc_lif", "readout"]
    assert layers[-1].mode == CFG.readout


def test_registry_knows_all_builtin_backends():
    assert set(ALL_BACKENDS) <= set(available_backends())


def test_unknown_backend_raises_value_error(setup):
    program, params, _ = setup
    with pytest.raises(ValueError, match="unknown backend 'warp'"):
        program.apply(params, _frames(), "warp")
    with pytest.raises(ValueError, match="registered backends"):
        get_backend("warp", "conv_lif")


def test_register_backend_plugs_in(setup):
    from repro.models import graph

    program, params, masks = setup
    snapshot = dict(graph._REGISTRY)
    try:
        register_backend("dense-alias", "conv_lif", get_backend("dense", "conv_lif"))
        register_backend("dense-alias", "fc_lif", get_backend("dense", "fc_lif"))
        ref = program.apply(params, _frames(), "dense", masks=masks)
        out = program.apply(params, _frames(), "dense-alias", masks=masks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    finally:
        graph._REGISTRY.clear()
        graph._REGISTRY.update(snapshot)


# ---------------------------------------------------------------------------
# cross-backend equivalence (the acceptance criterion: atol <= 1e-5)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ALL_BACKENDS[1:])
@pytest.mark.parametrize("density", [1.0, 0.5, 0.1])
def test_backend_matches_dense_oracle(setup, backend, density):
    program, params, _ = setup
    masks = None if density == 1.0 else make_mask_pytree(params, density)
    frames = _frames(seed=int(density * 10))
    ref = program.apply(params, frames, "dense", masks=masks)
    out = program.apply(params, frames, backend, masks=masks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_batch_equivalence(setup):
    program, params, masks = setup
    frames_b = jnp.stack([_frames(seed=s) for s in range(3)])
    ref = program.apply_batch(params, frames_b, "dense", masks=masks)
    for backend in ("goap", "pallas"):
        out = program.apply_batch(params, frames_b, backend, masks=masks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_dense_backend_is_differentiable(setup):
    program, params, masks = setup
    g = jax.grad(
        lambda p: program.apply(p, _frames(), "dense", masks=masks).sum()
    )(params)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree_util.tree_leaves(g))
    assert np.isfinite(total) and total > 0


# ---------------------------------------------------------------------------
# stream backend: the Tables I/III iteration counters
# ---------------------------------------------------------------------------

def test_stream_returns_iteration_counters(setup):
    program, params, masks = setup
    logits, counters = program.apply(
        params, _frames(), "stream", masks=masks, return_counters=True)
    assert set(counters) == {"conv1", "conv2"}
    for counts in counters.values():
        for key in ("compute_iters", "extra_iters", "empty_iters",
                    "reps_per_timestep", "accumulations", "timesteps"):
            assert key in counts
        assert (counts["compute_iters"] + counts["extra_iters"]
                + counts["empty_iters"] == counts["reps_per_timestep"])
    totals = stream_totals(counters)
    assert totals["compute_iters"] > 0
    assert float(totals["accumulations"]) > 0


def test_other_backends_return_empty_counters(setup):
    program, params, masks = setup
    for backend in ("dense", "goap", "pallas"):
        _, counters = program.apply(
            params, _frames(), backend, masks=masks, return_counters=True)
        assert counters == {}


# ---------------------------------------------------------------------------
# pre-sparsified params and graph slicing
# ---------------------------------------------------------------------------

def test_goap_accepts_presparsified_params(setup):
    program, params, masks = setup
    sparse = sparsify_params(params, masks)
    ref = program.apply(params, _frames(), "dense", masks=masks)
    out = program.apply(sparse, _frames(), "goap")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_run_layers_slices_compose_to_full_forward(setup):
    program, params, masks = setup
    frames = _frames()
    x = frames
    for i in range(len(CFG.conv_specs)):
        x = program.run_layers(program.conv_block(i), params, x, masks=masks)
    logits = program.run_layers(program.head_layers(), params, x, masks=masks)
    ref = program.apply(params, frames, "dense", masks=masks)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# deprecated wrappers
# ---------------------------------------------------------------------------

def test_legacy_wrappers_warn_and_agree(setup):
    program, params, masks = setup
    frames = _frames()
    ref = program.apply(params, frames, "dense", masks=masks)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out_fwd = snn_forward(params, frames, CFG, masks)
        out_b = snn_forward_batch(params, frames[None], CFG, masks)
        out_sp = snn_forward_sparse(sparsify_params(params, masks), frames, CFG)
    assert sum(issubclass(w.category, DeprecationWarning) for w in caught) >= 3
    np.testing.assert_allclose(np.asarray(out_fwd), np.asarray(ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_b[0]), np.asarray(ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_sp), np.asarray(ref), atol=1e-5)
