"""L1-unstructured (fine-grained) pruning with the paper's 3-phase schedule.

Paper §IV-C.1: over 100 epochs, the first 20 % train densely, the middle
60 % iteratively prune the smallest-magnitude weights toward the target
density, the final 20 % fine-tune with the mask frozen.  Per-layer target
densities are supported (Table V's "25-20-15-20-25" style configurations).

The sparsity ramp inside the pruning phase follows the cubic schedule of
Zhu & Gupta (2017), the standard "prune during training" ramp.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

import jax
import jax.numpy as jnp

__all__ = [
    "target_density_at",
    "magnitude_masks",
    "block_magnitude_masks",
    "make_mask_pytree",
    "mask_density",
]


def target_density_at(
    step: int,
    total_steps: int,
    final_density: float,
    phases: Sequence[float] = (0.2, 0.6, 0.2),
) -> float:
    """Current target density under the 20/60/20 three-phase schedule."""
    warm = phases[0] * total_steps
    prune_end = (phases[0] + phases[1]) * total_steps
    if step < warm:
        return 1.0
    if step >= prune_end:
        return final_density
    # cubic sparsity ramp: s(t) = s_f * (1 - (1 - t_norm)^3)
    t_norm = (step - warm) / max(1.0, prune_end - warm)
    s_final = 1.0 - final_density
    sparsity = s_final * (1.0 - (1.0 - t_norm) ** 3)
    return 1.0 - sparsity


def magnitude_masks(w: jax.Array, density: float) -> jax.Array:
    """Keep the top-|density| fraction of |w| (L1 unstructured pruning)."""
    if density >= 1.0:
        return jnp.ones_like(w, dtype=jnp.float32)
    n = w.size
    k = max(1, int(round(n * density)))
    flat = jnp.abs(w).reshape(-1)
    # threshold = k-th largest magnitude
    thresh = jnp.sort(flat)[n - k]
    return (jnp.abs(w) >= thresh).astype(jnp.float32)


def block_magnitude_masks(
    w: jax.Array, density: float, block_oc: int = 8, block_k: int = 128
) -> jax.Array:
    """TPU co-design variant (beyond paper): prune at MXU-tile granularity.

    The paper's L1-unstructured sparsity gives per-weight skips on the FPGA,
    but on a TPU the compute unit is a 128x128 MXU tile: unstructured zeros
    leave every (block_oc x block_k) tile non-empty, so the block-sparse
    GOAP kernel skips nothing (measured: tile density ~=1.0 at 30 % weight
    density).  Pruning whole tiles by their L1 norm makes tile density ==
    weight density, converting sparsity into skipped MXU work.

    w is a conv kernel (KW, IC, OC); tiles are formed over the flattened
    (OC, IC*KW) matmul operand — the same layout the kernel executes.
    """
    if density >= 1.0:
        return jnp.ones_like(w, dtype=jnp.float32)
    kw, ic, oc = w.shape
    flat = jnp.transpose(w, (2, 1, 0)).reshape(oc, ic * kw)
    pad_oc = (-oc) % block_oc
    pad_k = (-ic * kw) % block_k
    f = jnp.pad(flat, ((0, pad_oc), (0, pad_k)))
    r, c = f.shape[0] // block_oc, f.shape[1] // block_k
    tiles = f.reshape(r, block_oc, c, block_k)
    tile_score = jnp.abs(tiles).sum(axis=(1, 3))  # (r, c) L1 per tile
    n_tiles = r * c
    k = max(1, int(round(n_tiles * density)))
    thresh = jnp.sort(tile_score.reshape(-1))[n_tiles - k]
    tile_mask = (tile_score >= thresh).astype(jnp.float32)  # (r, c)
    m = jnp.broadcast_to(tile_mask[:, None, :, None], (r, block_oc, c, block_k))
    m = m.reshape(f.shape)[: oc, : ic * kw]
    return m.reshape(oc, ic, kw).transpose(2, 1, 0)


def make_mask_pytree(
    params: Dict, densities: Dict[str, float] | float
) -> Dict:
    """Masks for the SNN param structure {'conv': [{'w',...}], 'fc': [...]}.

    ``densities`` is either a scalar (uniform) or a dict with keys
    'conv1'... 'conv3', 'fc1', 'fc2' (per-layer, Table V style).
    """
    def dens(name: str) -> float:
        if isinstance(densities, dict):
            return float(densities[name])
        return float(densities)

    masks = {"conv": [], "fc": []}
    for i, layer in enumerate(params["conv"]):
        masks["conv"].append(magnitude_masks(layer["w"], dens(f"conv{i + 1}")))
    for i, layer in enumerate(params["fc"]):
        masks["fc"].append(magnitude_masks(layer["w"], dens(f"fc{i + 1}")))
    return masks


def mask_density(masks: Dict) -> Dict[str, float]:
    out = {}
    for i, m in enumerate(masks["conv"]):
        out[f"conv{i + 1}"] = float(np.asarray(m).mean())
    for i, m in enumerate(masks["fc"]):
        out[f"fc{i + 1}"] = float(np.asarray(m).mean())
    return out
