"""Quickstart: the paper's pipeline through the unified SNNProgram API.

1. generate synthetic RadioML I/Q frames,
2. Σ-Δ encode them into binary spike frames,
3. compile the SNNConfig into an ``SNNProgram`` (one model definition),
4. run it through interchangeable execution backends — ``dense`` (training
   oracle), ``goap`` (the accelerator's sparsity-aware dataflow), and
   ``stream`` (the faithful Algorithm-2 emulator with the paper's
   iteration counters),
5. verify all backends agree and report the paper's event counts.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.api import compile_plan, compile_snn, init_snn, stream_totals
from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.core.cost_model import bits_fetched, goap_conv_counts, sw_conv_counts
from repro.core.saocds import pad_same
from repro.core.sparse_format import coo_from_dense
from repro.data.pipeline import sigma_delta_encode_np
from repro.data.radioml import MODULATIONS, generate_batch
from repro.train.pruning import make_mask_pytree


def main():
    cfg = SNN_CONFIG
    program = compile_snn(cfg)
    print(f"SNN: convs {cfg.conv_specs}, FCs {cfg.fc_specs}, "
          f"T={cfg.timesteps} timesteps, {len(MODULATIONS)} classes")
    print("layer graph:", " -> ".join(s.name for s in program.layers))

    # 1-2. data -> spikes
    iq, labels, snrs = generate_batch(seed=0, batch=8, snr_db=10.0)
    frames = sigma_delta_encode_np(iq, cfg.timesteps)     # (B, T, 2, 128)
    print(f"I/Q {iq.shape} -> spike frames {frames.shape} "
          f"(density {frames.mean():.2f})")

    # 3. dense forward (the differentiable training backend)
    params = init_snn(jax.random.PRNGKey(0), cfg)
    dense_logits = program.apply_batch(params, jnp.asarray(frames), "dense")

    # 4. prune to 50%; the same program now runs the accelerator dataflow
    masks = make_mask_pytree(params, 0.5)
    masked_logits = program.apply_batch(
        params, jnp.asarray(frames), "dense", masks=masks)
    goap_logits = program.apply_batch(
        params, jnp.asarray(frames), "goap", masks=masks)

    # 5. every backend computes exactly the masked dense result
    err = float(jnp.abs(goap_logits - masked_logits).max())
    print(f"GOAP backend == masked dense backend: max err {err:.2e}")
    assert err < 1e-3

    # the streaming emulator returns the paper's Tables I/III counters
    _, counters = program.apply(params, jnp.asarray(frames[0]), "stream",
                                masks=masks, return_counters=True)
    totals = stream_totals(counters)
    print(f"stream schedule: {totals['compute_iters']} compute + "
          f"{totals['extra_iters']} extra + {totals['empty_iters']} empty "
          f"iterations/timestep, {float(totals['accumulations']):.0f} gated "
          f"accumulations for one sample")

    # 6. the plan compiler precomputes every bind-time artifact (COO
    # kernels, schedules, cost priors) once into a content-hashed,
    # disk-cached ExecutionPlan; its fused streaming executor threads all
    # layers through a single scan over timesteps — the software form of
    # the paper's control-free inter-layer pipeline (§III-C.4).  Layers
    # can even mix backends per layer:
    plan = compile_plan(program, params, masks=masks,
                        assignment={"conv1": "goap"}, default_backend="dense")
    fused_logits, _ = plan.run_streaming(jnp.asarray(frames[0]))
    err = float(jnp.abs(fused_logits - goap_logits[0]).max())
    print(f"fused streaming plan {plan.digest[:12]}… "
          f"(assignment {plan.assignment}): max err vs layer-by-layer "
          f"{err:.2e}")
    assert compile_plan(program, params, masks=masks,
                        assignment={"conv1": "goap"},
                        default_backend="dense") is plan  # cache hit

    # paper Table I-style counts on this batch's first conv layer
    kw, ic, oc = cfg.conv_specs[0]
    coo = coo_from_dense(np.asarray(params["conv"][0]["w"] * masks["conv"][0]))
    f0 = np.asarray(pad_same(jnp.asarray(frames[0]), coo.kw))
    sw = sw_conv_counts(f0, (kw, ic, oc))
    gp = goap_conv_counts(f0, coo)
    print(f"layer-1 events for one sample: SW accum={sw.accumulations} "
          f"bits={bits_fetched(sw)}  vs  GOAP accum={gp.accumulations} "
          f"bits={bits_fetched(gp)} "
          f"({bits_fetched(gp) / bits_fetched(sw) * 100:.1f}% traffic)")
    print("predictions:", np.asarray(goap_logits.argmax(-1)))


if __name__ == "__main__":
    main()
