"""Serving-tier benchmark: async micro-batched engine vs per-chunk loop.

Drives both engines over the same pile of synthetic I/Q frames (paper
config, 50% density) and records a throughput/latency trajectory point to
``BENCH_serve.json``:

* **baseline** — the pre-tier synchronous loop (``AMCServeEngine``: fixed
  32-frame chunks, host-side numpy Σ-Δ encode, pinned ``goap`` backend);
* **async tier** — ``AsyncAMCServeEngine``: request queue -> dynamic
  micro-batcher (fixed bucket shapes) -> worker loop running the
  autotuned backend with encoding fused into the compiled step.

Both report p50/p95/p99 request latency.  The acceptance bar for the tier
is ``speedup >= 1.5x`` on 4096 frames; on CPU hosts the autotuner's
dense-over-goap pick plus fused encoding clears it with a wide margin.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

import jax

from repro.api import compile_snn, init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.serve import AMCServeEngine, AsyncAMCServeEngine
from repro.train.pruning import make_mask_pytree

NAME = "serve_bench"

DENSITY = 0.5
BASE_BATCH = 32          # the pre-tier engine's fixed chunk size
ASYNC_MAX_BATCH = 128
ASYNC_MAX_DELAY_MS = 2.0


def _synthetic_frames(n: int) -> np.ndarray:
    """(N, 2, 128) unit-power gaussian I/Q — shape/throughput stand-in."""
    rng = np.random.default_rng(0)
    iq = rng.normal(size=(n, 2, CFG.input_width)).astype(np.float32)
    return iq / np.sqrt(np.mean(iq**2, axis=(-2, -1), keepdims=True))


def run(n_frames: int = 4096, workers: int = 1) -> dict:
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    iq = _synthetic_frames(n_frames)

    # -- baseline: the per-chunk synchronous loop ---------------------------
    base = AMCServeEngine(params, CFG, masks=masks, batch_size=BASE_BATCH,
                          count_activity=False, backend="goap")
    base.classify(iq[:BASE_BATCH])           # compile outside the clock
    base.stats = type(base.stats)(backend=base.backend)
    base.classify(iq)
    base_stats = base.stats

    # -- async tier ---------------------------------------------------------
    t0 = time.perf_counter()
    engine = AsyncAMCServeEngine(
        params, CFG, masks=masks, backend="auto",
        max_batch=ASYNC_MAX_BATCH, max_delay_ms=ASYNC_MAX_DELAY_MS,
        workers=workers, count_activity=False)
    bind_s = time.perf_counter() - t0        # autotune + per-bucket warmup
    engine.classify(iq)
    async_stats = engine.stats
    engine.close()

    speedup = (async_stats.throughput_fps() / base_stats.throughput_fps()
               if base_stats.throughput_fps() else float("inf"))
    return {
        "n_frames": n_frames,
        "density": DENSITY,
        "jax_backend": jax.default_backend(),
        "n_devices": jax.local_device_count(),
        "baseline": {"engine": "sync-per-chunk", "batch_size": BASE_BATCH,
                     **base_stats.summary()},
        "async": {"engine": "async-micro-batched",
                  "max_batch": ASYNC_MAX_BATCH,
                  "max_delay_ms": ASYNC_MAX_DELAY_MS,
                  "workers": workers,
                  "bind_s": bind_s,
                  "autotune": engine.autotune.summary(),
                  **async_stats.summary()},
        "speedup": speedup,
    }


def format_table(res: dict) -> str:
    lines = [f"Serve bench: {res['n_frames']} frames, density "
             f"{res['density']}, {res['n_devices']} {res['jax_backend']} "
             f"device(s)"]
    for key in ("baseline", "async"):
        r = res[key]
        lines.append(
            f"  {r['engine']:20s} backend={r['backend']:6s} "
            f"{r['throughput_fps']:8.1f} frames/s  "
            f"p50 {r['p50_ms']:7.1f}ms  p95 {r['p95_ms']:7.1f}ms  "
            f"p99 {r['p99_ms']:7.1f}ms  batches {r['batches']}")
    lines.append(f"  speedup (async/baseline): {res['speedup']:.2f}x "
                 f"(acceptance bar 1.5x)")
    tuned = res["async"]["autotune"]
    raced = ", ".join(f"{k} {v:.1f}ms" for k, v in tuned["timings_ms"].items())
    lines.append(f"  autotune raced [{raced}] -> {tuned['choice']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced frame count for CI smoke runs")
    ap.add_argument("--frames", type=int, default=None)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    n = args.frames if args.frames else (256 if args.smoke else 4096)
    res = run(n_frames=n, workers=args.workers)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    if not args.smoke and res["speedup"] < 1.5:
        print("FAIL: async tier below the 1.5x acceptance bar")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
