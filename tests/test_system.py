"""System-level invariants: the 40-cell grid, config exactness, padding."""
import jax
import pytest

from repro.configs.registry import ARCH_IDS, SHAPES, all_cells, get_config


def test_grid_is_40_cells():
    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    # long_500k runs only for the sub-quadratic archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s, ok, _ in skipped)
    assert {a for a, s, ok, _ in cells if s == "long_500k" and ok} == {
        "mamba2-780m", "recurrentgemma-9b"}


def test_every_arch_importable_and_padded():
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 128 == 0
        assert cfg.padded_vocab - cfg.vocab < 128
        if cfg.n_experts >= 16:
            assert cfg.padded_experts % 16 == 0
        assert cfg.param_count() > 0


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_production_mesh_axes():
    """The assigned mesh layouts (AbstractMesh: no device init)."""
    from repro.compat import abstract_mesh

    single = abstract_mesh((16, 16), ("data", "model"))
    multi = abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    assert dict(single.shape) == {"data": 16, "model": 16}
    assert dict(multi.shape) == {"pod": 2, "data": 16, "model": 16}
