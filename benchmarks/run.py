"""Benchmark aggregator: ``PYTHONPATH=src python -m benchmarks.run``.

Runs one module per paper table/figure plus the kernel microbench and the
roofline report, prints each, and writes JSON records to
``experiments/bench/``.  ``--quick`` skips the training-based accuracy
sweep (several CPU-minutes); ``--only <name>`` runs one module.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
import traceback

OUT = pathlib.Path("experiments/bench")


def _modules(quick: bool):
    from . import (
        accuracy_sweep,
        deploy_bench,
        fixed_bench,
        fleet_bench,
        fusion_bench,
        kernel_bench,
        robustness_bench,
        roofline,
        serve_bench,
        table1_goap_vs_sw,
        table2_coo_overhead,
        table3_accum_ratio,
        table45_perf_model,
    )

    mods = [table1_goap_vs_sw, table2_coo_overhead, table3_accum_ratio,
            table45_perf_model, kernel_bench, fusion_bench, roofline]
    if not quick:
        # several CPU-minutes each: training sweep, full 4096-frame serve
        # run, the hot-swap-under-load deployment bench, the
        # scenario-robustness sweep across all four backends, the
        # float-vs-fixed fidelity sweep of the integer tier, and the
        # open-loop fleet load/autoscaling harness
        mods.extend([accuracy_sweep, serve_bench, deploy_bench,
                     robustness_bench, fixed_bench, fleet_bench])
    return mods


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    OUT.mkdir(parents=True, exist_ok=True)
    failures = 0
    for mod in _modules(args.quick):
        if args.only and mod.NAME != args.only:
            continue
        print(f"\n=== {mod.NAME} " + "=" * max(0, 60 - len(mod.NAME)))
        t0 = time.perf_counter()
        try:
            res = mod.run()
            print(mod.format_table(res))
            (OUT / f"{mod.NAME}.json").write_text(
                json.dumps(res, indent=1, default=str))
            print(f"[{mod.NAME}: {time.perf_counter() - t0:.1f}s]")
        except Exception:
            failures += 1
            print(f"[{mod.NAME}: FAILED]\n{traceback.format_exc()}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
