"""Distribution layer tests: sharding rules, pipeline, compression, dryrun.

Multi-device tests run in subprocesses (jax locks the host device count at
first init, and the main pytest process must keep its 1-CPU view).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh

from repro.configs.registry import get_config
from repro.distributed.compression import (
    compression_ratio,
    dequantize_int8,
    ef_compress,
    quantize_int8,
)
from repro.launch.steps import WHISPER_S_ENC  # noqa: F401 (import check)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_py(code: str, devices: int = 8, timeout: int = 600):
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={devices}",
           "PYTHONPATH": os.path.join(REPO, "src")}
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env, cwd=REPO)


# ---------------------------------------------------------------------------
# sharding rules (AbstractMesh: no devices needed)
# ---------------------------------------------------------------------------

def _mesh():
    return abstract_mesh((16, 16), ("data", "model"))


def test_partition_rules_tp_and_fsdp():
    from repro.distributed.sharding import partition_params
    from repro.models.lm import init_lm

    cfg = get_config("llama3-8b")
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    specs = partition_params(shapes, _mesh(), n_experts=cfg.n_experts)
    stack = specs["stacks"][0]
    # TP: attention projections column-sharded, out-proj row-sharded
    assert stack["attn"]["wq"][-1] == "model"
    assert stack["attn"]["wo"][-2] == "model"
    assert stack["mlp"]["wg"][-1] == "model"
    assert stack["mlp"]["wd"][-2] == "model"
    # FSDP: the other big dim carries the data axis; scan dim never sharded
    assert "data" in tuple(stack["attn"]["wq"])
    assert tuple(stack["attn"]["wq"])[0] is None
    # embeddings vocab-parallel (padded vocab)
    assert specs["emb"]["tok"][0] == "model"
    # stacked norms: scan dim unsharded (FSDP may take the feature dim)
    assert stack["norm1"][0] is None


def test_partition_rules_moe_ep_vs_tp():
    from repro.distributed.sharding import partition_params
    from repro.models.lm import init_lm

    # llama4-scout: 16 experts % 16 == 0 -> expert parallelism
    cfg = get_config("llama4-scout-17b-a16e")
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    specs = partition_params(shapes, _mesh(), n_experts=cfg.n_experts)
    moe = specs["stacks"][0]["moe"]
    assert moe["wg"][1] == "model", "16 experts should be EP-sharded"

    # qwen2-moe: 60 experts % 16 != 0 -> TP inside experts
    cfg = get_config("qwen2-moe-a2.7b")
    shapes = jax.eval_shape(lambda k: init_lm(k, cfg), jax.random.PRNGKey(0))
    specs = partition_params(shapes, _mesh(), n_experts=cfg.n_experts)
    moe = specs["stacks"][0]["moe"]
    assert moe["wg"][1] is None and "model" in tuple(moe["wg"])


def test_divisibility_fallback_never_invalid():
    from repro.distributed.sharding import partition_params
    from repro.models.lm import init_lm

    mesh = _mesh()
    for arch in ("mamba2-780m", "recurrentgemma-9b", "internvl2-1b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda k: init_lm(k, cfg),
                                jax.random.PRNGKey(0))
        specs = partition_params(shapes, mesh, n_experts=cfg.n_experts)

        def check(path, spec, leaf):
            for ax, dim in zip(tuple(spec), leaf.shape):
                if ax is not None:
                    n = mesh.shape[ax] if isinstance(ax, str) else int(
                        np.prod([mesh.shape[a] for a in ax]))
                    assert dim % n == 0, (arch, path, spec, leaf.shape)

        jax.tree_util.tree_map_with_path(
            check, specs, shapes, is_leaf=lambda x: isinstance(x, P))


def test_decode_state_specs_shard_ctx():
    from repro.distributed.sharding import decode_state_specs
    from repro.models.lm import init_decode_state

    cfg = get_config("llama3-8b")
    state = jax.eval_shape(lambda: init_decode_state(cfg, 128, 32768))
    specs = decode_state_specs(state, _mesh(), 128)
    kv = specs[0]
    assert kv["k"][2] == "model" and kv["k"][1] in ("data", ("data",))
    assert kv["len"] == P()


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(7,)).astype(np.float32) * 10)}
    q, s = quantize_int8(tree)
    deq = dequantize_int8(q, s)
    for k in tree:
        step = float(jnp.max(jnp.abs(tree[k]))) / 127.0
        assert float(jnp.max(jnp.abs(deq[k] - tree[k]))) <= step * 0.5 + 1e-7
    assert compression_ratio(tree) > 3.9


def test_error_feedback_accumulates_residual():
    """EF invariant: sum of dequantized transmissions + residual == sum of
    raw gradients (no information lost over steps)."""
    rng = np.random.default_rng(1)
    ef = {"w": jnp.zeros((32,), jnp.float32)}
    total_sent = jnp.zeros((32,))
    total_grads = jnp.zeros((32,))
    for i in range(8):
        g = {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32) * 1e-3)}
        q, s, ef = ef_compress(g, ef)
        total_sent = total_sent + dequantize_int8(q, s)["w"]
        total_grads = total_grads + g["w"]
    np.testing.assert_allclose(
        np.asarray(total_sent + ef["w"]), np.asarray(total_grads),
        rtol=1e-5, atol=1e-6)


def test_compressed_psum_two_workers():
    res = _run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.compat import AxisType, make_mesh, shard_map
        from repro.distributed.compression import compressed_psum
        mesh = make_mesh((2,), ("dp",), axis_types=(AxisType.Auto,))
        @functools.partial(shard_map, mesh=mesh, in_specs=(P("dp"), P("dp")),
                           out_specs=(P("dp"), P("dp")), check_vma=False)
        def step(g, ef):
            g0 = {"w": g[0]}
            mean, new_ef = compressed_psum(g0, {"w": ef[0]}, "dp")
            return mean["w"][None], new_ef["w"][None]
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
        ef = jnp.zeros((2, 64), jnp.float32)
        mean, ef2 = step(g, ef)
        ref = g.mean(0)
        # both workers agree and approximate the true mean
        np.testing.assert_allclose(np.asarray(mean[0]), np.asarray(mean[1]), atol=0)
        err = float(jnp.abs(mean[0] - ref).max())
        scale = float(jnp.abs(g).max()) / 127.0
        assert err <= scale + 1e-6, (err, scale)
        print("OK")
    """, devices=2)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr[-2000:]


# ---------------------------------------------------------------------------
# pipeline runner (the paper's inter-layer streaming)
# ---------------------------------------------------------------------------

def test_spmd_pipeline_equals_sequential():
    res = _run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import AxisType, make_mesh
        from repro.distributed.pipeline import spmd_pipeline
        mesh = make_mesh((4,), ("stage",), axis_types=(AxisType.Auto,))
        rng = np.random.default_rng(0)
        ws = jnp.asarray(rng.normal(size=(4, 16, 16)).astype(np.float32) * 0.3)
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        mbs = jnp.asarray(rng.normal(size=(6, 8, 16)).astype(np.float32))
        out = spmd_pipeline(stage_fn, ws, mbs, mesh)
        ref = mbs
        for i in range(4):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        print("OK")
    """, devices=4)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr[-2000:]


# ---------------------------------------------------------------------------
# elastic checkpoint reshard: save on mesh A, restore on mesh B
# ---------------------------------------------------------------------------

def test_elastic_checkpoint_reshard(tmp_path):
    res = _run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import AxisType, make_mesh
        from repro.train.checkpoint import CheckpointManager
        meshA = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        meshB = make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xa = jax.device_put(x, NamedSharding(meshA, P("data", "model")))
        ck = CheckpointManager(r"{tmp_path}", keep=2)
        ck.save(1, {{"w": xa}}, extra={{"step": 1}})
        ck.wait()
        # restore onto a DIFFERENT mesh layout
        xb_target = jax.device_put(jnp.zeros((8, 8)), NamedSharding(meshB, P("model", "data")))
        tree, manifest = ck.restore({{"w": xb_target}})
        got = np.asarray(tree["w"])
        np.testing.assert_array_equal(got, np.asarray(x))
        print("OK", manifest["extra"]["step"])
    """, devices=8)
    assert res.returncode == 0 and "OK" in res.stdout, res.stderr[-2000:]


# ---------------------------------------------------------------------------
# dryrun integration (one fast cell on the real 512-device path)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_dryrun_one_cell(tmp_path):
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-780m",
         "--shape", "long_500k", "--mesh", "multi", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, res.stderr[-2000:]
    rec = json.loads(
        (tmp_path / "multi" / "mamba2-780m__long_500k.json").read_text())
    assert rec["ok"] and rec["chips"] == 512
    assert rec["roofline"]["terms_s"]["compute"] > 0
    assert rec["memory"]["fits_16g_hbm"]
