"""Serving tier: micro-batched streaming AMC inference engines."""

from .autotune import AutotuneReport, autotune_backend, default_candidates
from .batcher import MicroBatch, MicroBatcher, Request, ServeFuture
from .engine import AMCServeEngine, AsyncAMCServeEngine, ServeStats

__all__ = [
    "AMCServeEngine",
    "AsyncAMCServeEngine",
    "ServeStats",
    "MicroBatcher",
    "MicroBatch",
    "Request",
    "ServeFuture",
    "AutotuneReport",
    "autotune_backend",
    "default_candidates",
]
