"""Whisper-style encoder-decoder backbone (assigned arch: whisper-large-v3).

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d) — log-mel + the two strided
convs happen off-model.  Faithful to Whisper elsewhere: LayerNorm (with
bias), GELU MLPs (with bias), sinusoidal encoder positions, learned decoder
positions, MHA, causal decoder self-attention + cross-attention into the
encoder output.  Attention reuses the query-chunked implementation from
``layers.py`` (required for the 32k shapes); deviations: a zero-init k-proj
bias exists (Whisper omits it) and the out-proj bias is dropped — both are
numerically absorbable and documented here.

Decode uses a self-attention KV cache plus cross K/V projected once from
the encoder output.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain_acts, constrain_head, constrain_logits

from .config import ArchConfig
from .layers import (
    _dense_init,
    attention,
    init_attention,
    mask_vocab_pad,
    softmax_cross_entropy,
)

__all__ = [
    "init_whisper",
    "whisper_forward",
    "whisper_loss",
    "whisper_encode",
    "whisper_prefill",
    "init_whisper_decode_state",
    "whisper_decode_step",
    "precompute_cross_kv",
]

Params = Dict[str, Any]


def _layer_norm(x, p, eps):
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.var(x.astype(jnp.float32), axis=-1, keepdims=True)
    y = (x.astype(jnp.float32) - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["g"] + p["b"]).astype(x.dtype)


def _ln_init(d, dtype):
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def _init_mlp(key, d, ff, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w1": _dense_init(k1, (d, ff), dtype=dtype),
        "b1": jnp.zeros((ff,), dtype),
        "w2": _dense_init(k2, (ff, d), dtype=dtype),
        "b2": jnp.zeros((d,), dtype),
    }


def _mlp(p, x):
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _sinusoid(s, d):
    pos = jnp.arange(s)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10_000 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_whisper(key, cfg: ArchConfig, max_dec_pos: int = 65_536, dtype=jnp.float32) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    k_enc, k_dec, k_emb, k_pos = jax.random.split(key, 4)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "ln1": _ln_init(d, dtype), "attn": init_attention(k1, cfg, dtype=dtype),
            "ln2": _ln_init(d, dtype), "mlp": _init_mlp(k2, d, ff, dtype),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "ln1": _ln_init(d, dtype), "self": init_attention(k1, cfg, dtype=dtype),
            "ln2": _ln_init(d, dtype), "cross": init_attention(k2, cfg, dtype=dtype),
            "ln3": _ln_init(d, dtype), "mlp": _init_mlp(k3, d, ff, dtype),
        }

    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "enc": jax.vmap(enc_layer)(jax.random.split(k_enc, n_enc)),
        "dec": jax.vmap(dec_layer)(jax.random.split(k_dec, cfg.n_layers)),
        "tok": _dense_init(k_emb, (cfg.padded_vocab, d), scale=0.02, dtype=dtype),
        "dec_pos": _dense_init(k_pos, (max_dec_pos, d), scale=0.02, dtype=dtype),
        "ln_enc": _ln_init(d, dtype),
        "ln_dec": _ln_init(d, dtype),
    }


def whisper_encode(params: Params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder output."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = constrain_acts(x)

    def body(h, p):
        hn = _layer_norm(h, p["ln1"], cfg.norm_eps)
        a, _ = attention(p["attn"], hn, cfg, causal=False)
        h = h + a
        h = h + _mlp(p["mlp"], _layer_norm(h, p["ln2"], cfg.norm_eps))
        return constrain_acts(h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc"])
    return _layer_norm(x, params["ln_enc"], cfg.norm_eps)


def _decoder_hidden(params: Params, frames: jax.Array, tokens: jax.Array,
                    cfg: ArchConfig) -> jax.Array:
    enc = whisper_encode(params, frames, cfg)
    b, s = tokens.shape
    x = constrain_acts(params["tok"][tokens] + params["dec_pos"][:s])

    def body(h, p):
        hn = _layer_norm(h, p["ln1"], cfg.norm_eps)
        a, _ = attention(p["self"], hn, cfg, causal=True)
        h = h + a
        c, _ = attention(
            p["cross"], _layer_norm(h, p["ln2"], cfg.norm_eps), cfg,
            kv_x=enc, causal=False,
        )
        h = h + c
        h = h + _mlp(p["mlp"], _layer_norm(h, p["ln3"], cfg.norm_eps))
        return constrain_acts(h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
    return constrain_head(_layer_norm(x, params["ln_dec"], cfg.norm_eps))


def whisper_forward(
    params: Params, frames: jax.Array, tokens: jax.Array, cfg: ArchConfig
) -> jax.Array:
    """Teacher-forced training forward -> decoder logits (B, S_dec, V)."""
    x = _decoder_hidden(params, frames, tokens, cfg)
    # tied unembedding (Whisper ties)
    return constrain_logits(mask_vocab_pad(x @ params["tok"].T, cfg))


def whisper_loss(params, frames, tokens, labels, cfg,
                 ce_chunk: int = 256) -> jax.Array:
    """Chunked CE over decoder positions (see lm.lm_loss)."""
    x = _decoder_hidden(params, frames, tokens, cfg)
    b, s, d = x.shape
    chunk = ce_chunk if (ce_chunk and s % ce_chunk == 0) else s
    nc = s // chunk

    def body(acc, inp):
        xc, lc = inp
        logits = mask_vocab_pad(xc @ params["tok"].T, cfg)
        return acc + softmax_cross_entropy(logits, lc).sum(), None

    xcs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lcs = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (xcs, lcs))
    return total / (b * s)


def whisper_prefill(params: Params, frames: jax.Array, tokens: jax.Array,
                    cfg: ArchConfig):
    """Encode + teacher-forced decoder pass that materializes decode state.

    Returns ``(last_logits (B, 1, V), state)`` with ``state`` shaped like
    :func:`init_whisper_decode_state` (self-KV holds the prompt, cross-KV
    is projected once from the encoder output).
    """
    enc = whisper_encode(params, frames, cfg)
    b, s = tokens.shape
    x = constrain_acts(params["tok"][tokens] + params["dec_pos"][:s])

    def body(h, p):
        hn = _layer_norm(h, p["ln1"], cfg.norm_eps)
        a, cache = attention(p["self"], hn, cfg, causal=True, build_cache=True)
        h = h + a
        c, _ = attention(
            p["cross"], _layer_norm(h, p["ln2"], cfg.norm_eps), cfg,
            kv_x=enc, causal=False,
        )
        h = h + c
        h = h + _mlp(p["mlp"], _layer_norm(h, p["ln3"], cfg.norm_eps))
        return constrain_acts(h), (cache["k"], cache["v"])

    x, (sk, sv) = jax.lax.scan(jax.checkpoint(body), x, params["dec"])
    ck, cv = precompute_cross_kv(params, enc, cfg)
    x = constrain_head(_layer_norm(x[:, -1:], params["ln_dec"], cfg.norm_eps))
    logits = mask_vocab_pad(x @ params["tok"].T, cfg)
    state = {
        "self_k": sk, "self_v": sv, "cross_k": ck, "cross_v": cv,
        "len": jnp.asarray(s, jnp.int32),
    }
    return logits, state


def precompute_cross_kv(params: Params, enc: jax.Array, cfg: ArchConfig):
    """Project the encoder output once: (L, B, S_enc, H, hd) k/v caches."""
    b, s_enc, d = enc.shape
    nh, hd = cfg.n_heads, cfg.hd

    def proj(p):
        k = (enc @ p["cross"]["wk"] + p["cross"]["bk"]).reshape(b, s_enc, nh, hd)
        v = (enc @ p["cross"]["wv"] + p["cross"]["bv"]).reshape(b, s_enc, nh, hd)
        return k, v

    return jax.vmap(proj)(params["dec"])


def init_whisper_decode_state(cfg: ArchConfig, batch: int, ctx: int, s_enc: int, dtype=jnp.bfloat16):
    nh, hd = cfg.n_heads, cfg.hd
    n_dec = cfg.n_layers
    return {
        "self_k": jnp.zeros((n_dec, batch, ctx, nh, hd), dtype),
        "self_v": jnp.zeros((n_dec, batch, ctx, nh, hd), dtype),
        "cross_k": jnp.zeros((n_dec, batch, s_enc, nh, hd), dtype),
        "cross_v": jnp.zeros((n_dec, batch, s_enc, nh, hd), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


def _cross_decode(p, x, ck, cv, cfg):
    """q-len-1 cross attention against precomputed (B, S_enc, H, hd) k/v."""
    b, s, d = x.shape
    nh, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"] + p["bq"]).reshape(b, s, nh, hd)
    scores = jnp.einsum("bsnh,bcnh->bnsc", q, ck.astype(q.dtype)) / math.sqrt(hd)
    probs = jax.nn.softmax(scores.astype(jnp.float32), -1).astype(q.dtype)
    out = jnp.einsum("bnsc,bcnh->bsnh", probs, cv.astype(q.dtype)).reshape(b, s, d)
    return out @ p["wo"]


def whisper_decode_step(params: Params, state, token: jax.Array, cfg: ArchConfig):
    """One decoder step with self-KV cache + precomputed cross K/V."""
    b, s = token.shape
    pos = state["len"]
    x = constrain_acts(params["tok"][token] + jax.lax.dynamic_slice(
        params["dec_pos"], (pos, 0), (s, cfg.d_model)
    ))

    def body(h, xs):
        p, sk, sv, ck, cv = xs
        cache = {"k": sk, "v": sv, "len": pos}
        hn = _layer_norm(h, p["ln1"], cfg.norm_eps)
        a, new_cache = attention(p["self"], hn, cfg, cache=cache,
                                 positions=pos + jnp.arange(s)[None, :])
        h = h + a
        h = h + _cross_decode(
            p["cross"], _layer_norm(h, p["ln2"], cfg.norm_eps), ck, cv, cfg
        )
        h = h + _mlp(p["mlp"], _layer_norm(h, p["ln3"], cfg.norm_eps))
        return h, (new_cache["k"], new_cache["v"])

    x, (nk, nv) = jax.lax.scan(
        body, x,
        (params["dec"], state["self_k"], state["self_v"],
         state["cross_k"], state["cross_v"]),
    )
    x = constrain_head(_layer_norm(x, params["ln_dec"], cfg.norm_eps))
    logits = constrain_logits(mask_vocab_pad(x @ params["tok"].T, cfg))
    new_state = {**state, "self_k": nk, "self_v": nv, "len": pos + s}
    return logits, new_state
