"""Per-request tracing: lightweight span timelines through the serving path.

One request's life is a sequence of timestamped events::

    submit -> admit -> enqueue -> dequeue -> batch-form
           -> jit-step-start -> jit-step-end -> complete

with the failure terminals ``shed`` (admission refused at the fleet
door), ``reject`` (single-engine queue bound), ``expired`` (deadline
passed while queued), ``cancelled``, and ``error``.  Spans are the gaps
between consecutive events — :meth:`RequestTrace.spans` derives them, so
queueing delay vs batch-forming delay vs jitted-step time are separable
per request, fleet-wide.

Cost model: tracing is **off by default** and the hot path pays one
module-global read per request when disabled.  When enabled
(:func:`enable_tracing`), the deterministic ``sample_every`` knob traces
every Nth submission; completed traces land in a bounded ring buffer
(:class:`TraceLog`) whose JSON ``dump()`` is the ``--trace-dump``
artifact.  Traces ride on the request itself (``Request.trace`` /
``ServeFuture.trace``), so no global lookup happens per event — an
untraced request carries ``None`` and every instrumentation site is a
single ``is not None`` check.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "TraceEvent",
    "RequestTrace",
    "TraceLog",
    "TERMINAL_EVENTS",
    "enable_tracing",
    "disable_tracing",
    "get_tracer",
    "begin_trace",
    "tadd",
    "tfinish",
]

#: Event names that end a request's timeline.
TERMINAL_EVENTS = frozenset(
    {"complete", "expired", "cancelled", "shed", "reject", "error"})


@dataclasses.dataclass
class TraceEvent:
    name: str
    t: float                      # perf_counter timestamp
    attrs: Dict[str, Any]


class RequestTrace:
    """Event timeline of one request (appended to by whoever holds it).

    Events are appended in processing order by the producer thread, the
    batcher consumer, and the worker — which hand the request off through
    a queue, so appends never race.  ``finish`` routes the completed
    trace back to its :class:`TraceLog` (idempotent: losing a
    cancel-vs-complete race records the first terminal only).
    """

    __slots__ = ("request_id", "events", "_log", "_done")

    def __init__(self, request_id: int, log: "TraceLog"):
        self.request_id = request_id
        self.events: List[TraceEvent] = []
        self._log = log
        self._done = False

    def add(self, name: str, t: Optional[float] = None, **attrs) -> None:
        self.events.append(
            TraceEvent(name=name, t=time.perf_counter() if t is None else t,
                       attrs=attrs))

    def finish(self) -> None:
        self._log._finish(self)

    def terminal(self) -> Optional[str]:
        for ev in reversed(self.events):
            if ev.name in TERMINAL_EVENTS:
                return ev.name
        return None

    def spans(self) -> List[Dict[str, Any]]:
        """Gaps between consecutive events: the per-phase latency split."""
        out = []
        for a, b in zip(self.events, self.events[1:]):
            out.append({"from": a.name, "to": b.name,
                        "seconds": b.t - a.t})
        return out

    def to_dict(self) -> Dict[str, Any]:
        t0 = self.events[0].t if self.events else 0.0
        return {
            "request_id": self.request_id,
            "terminal": self.terminal(),
            # t0 anchors the per-event relative times on the shared
            # perf_counter axis so dumps stay orderable across requests
            # (the Perfetto exporter needs this)
            "t0": t0,
            "events": [{"name": ev.name, "t_rel_s": ev.t - t0, **ev.attrs}
                       for ev in self.events],
            "spans": self.spans(),
            "total_s": (self.events[-1].t - t0) if self.events else 0.0,
        }


class TraceLog:
    """Bounded ring buffer of completed traces + the sampling decision.

    ``sample_every=N`` traces every Nth submission (deterministic — no
    RNG, so tests and benches see exactly ``ceil(n/N)`` traces).
    """

    def __init__(self, capacity: int = 2048, sample_every: int = 1):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if sample_every < 1:
            raise ValueError(
                f"sample_every must be >= 1, got {sample_every}")
        self.capacity = capacity
        self.sample_every = sample_every
        self._lock = threading.Lock()
        self._ring: "collections.deque[RequestTrace]" = collections.deque(
            maxlen=capacity)
        self._ids = itertools.count()
        self.n_seen = 0        # submissions observed (sampled or not)
        self.n_started = 0     # traces begun
        self.n_completed = 0   # traces finished (terminal reached)

    def begin(self) -> Optional[RequestTrace]:
        with self._lock:
            seen = self.n_seen
            self.n_seen += 1
            if seen % self.sample_every:
                return None
            self.n_started += 1
            return RequestTrace(next(self._ids), self)

    def _finish(self, trace: RequestTrace) -> None:
        with self._lock:
            if trace._done:
                return
            trace._done = True
            self.n_completed += 1
            self._ring.append(trace)

    def completed(self) -> List[RequestTrace]:
        with self._lock:
            return list(self._ring)

    def dump(self, limit: Optional[int] = None) -> Dict[str, Any]:
        """JSON-ready artifact (the ``--trace-dump`` file).

        ``limit`` keeps only the *newest* N traces (the ring is oldest
        first) — what ``/trace?limit=N`` serves.
        """
        traces = self.completed()
        if limit is not None:
            if limit < 0:
                raise ValueError(f"limit must be >= 0, got {limit}")
            traces = traces[len(traces) - limit:] if limit else []
        with self._lock:
            head = {"n_seen": self.n_seen, "n_started": self.n_started,
                    "n_completed": self.n_completed,
                    "sample_every": self.sample_every,
                    "capacity": self.capacity}
        return {**head, "traces": [tr.to_dict() for tr in traces]}


# -- module-level tracer (the single global the hot path reads) --------------

_tracer: Optional[TraceLog] = None


def enable_tracing(sample_every: int = 1, capacity: int = 2048) -> TraceLog:
    """Install (and return) a fresh process-wide :class:`TraceLog`."""
    global _tracer
    _tracer = TraceLog(capacity=capacity, sample_every=sample_every)
    return _tracer


def disable_tracing() -> None:
    global _tracer
    _tracer = None


def get_tracer() -> Optional[TraceLog]:
    return _tracer


def begin_trace() -> Optional[RequestTrace]:
    """One new request timeline — None when tracing is off / not sampled."""
    tracer = _tracer
    return tracer.begin() if tracer is not None else None


def tadd(trace: Optional[RequestTrace], name: str,
         t: Optional[float] = None, **attrs) -> None:
    """Event append tolerant of untraced (None) requests."""
    if trace is not None:
        trace.add(name, t=t, **attrs)


def tfinish(trace: Optional[RequestTrace]) -> None:
    if trace is not None:
        trace.finish()
