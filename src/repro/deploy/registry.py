"""Content-addressed, versioned model registry for the AMC serving tier.

A long-lived cognitive-radio edge node must update its model (new SNR
regimes, retrained sparsity masks) without losing track of what is
deployed.  The registry is the system of record: every published model is
an immutable, content-hashed **version** — params + pruning masks + LSQ
quantization state + the config that shapes them — written atomically to
disk next to the :class:`~repro.plan.cache.PlanCache` tier, with named
**aliases** (``production``, ``staging``) that the serving tier resolves
at bind time.

Layout (one directory per version, atomic ``os.replace`` publish)::

    <root>/<name>/v0001/{arrays.npz, manifest.json}
    <root>/<name>/v0002/...
    <root>/<name>/aliases.json

Content addressing: the digest covers the config plus every param / mask /
LSQ leaf, so re-publishing identical content returns the *existing*
version instead of minting a new one — an idempotent deploy pipeline by
construction.  Publishing also compiles the version's
:class:`~repro.plan.compile.ExecutionPlan` (recording its digest in the
manifest and warming the shared plan cache), so a later hot-swap finds the
expensive COO/schedule artifacts already on disk.

``publish_from_checkpoint`` bridges from :mod:`repro.train.checkpoint`:
restore a trainer checkpoint (params + masks + LSQ scales + step) and
publish it as a registry version in one call.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import re
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax

from repro.models.snn import SNNConfig, init_snn
from repro.plan.cache import default_store_root

__all__ = [
    "ModelVersion",
    "LoadedModel",
    "ModelRegistry",
    "publish_from_trainer",
    "publish_from_checkpoint",
]

ENV_DIR = "REPRO_REGISTRY_DIR"

_VERSION_RE = re.compile(r"^v(\d{4,})$")
_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]*$")

# Manifest format version: bump on incompatible layout changes.
_FORMAT = 1


def _default_dir() -> pathlib.Path:
    env = os.environ.get(ENV_DIR)
    if env:
        return pathlib.Path(env).expanduser()
    return default_store_root() / "registry"


# ---------------------------------------------------------------------------
# (De)serialization helpers.
# ---------------------------------------------------------------------------

def _cfg_to_json(cfg: SNNConfig) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def _cfg_from_json(d: Dict[str, Any]) -> SNNConfig:
    # JSON turns the nested spec tuples into lists; restore them.
    d = dict(d)
    d["conv_specs"] = tuple(tuple(s) for s in d["conv_specs"])
    d["fc_specs"] = tuple(tuple(s) for s in d["fc_specs"])
    return SNNConfig(**d)


def _flatten_group(group: str, tree) -> Dict[str, np.ndarray]:
    leaves = jax.tree_util.tree_leaves(tree)
    return {f"{group}_{i:05d}": np.asarray(l) for i, l in enumerate(leaves)}


def _unflatten_group(group: str, data, like) -> Any:
    """Rebuild a pytree shaped ``like`` from npz entries ``group_NNNNN``."""
    leaves, treedef = jax.tree_util.tree_flatten(like)
    keys = sorted(k for k in data.files if k.startswith(f"{group}_"))
    if len(keys) != len(leaves):
        raise ValueError(
            f"registry entry has {len(keys)} '{group}' leaves, expected "
            f"{len(leaves)} (config drift?)")
    restored = []
    for key, leaf in zip(keys, leaves):
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"leaf {key}: shape {arr.shape} != expected {np.shape(leaf)}")
        restored.append(arr)
    return jax.tree_util.tree_unflatten(treedef, restored)


def _content_digest(cfg: SNNConfig, groups: Dict[str, Dict[str, np.ndarray]],
                    quant_bits: Optional[int] = None) -> str:
    h = hashlib.sha256(b"repro-registry-v1|")
    h.update(repr(cfg).encode())
    if quant_bits is not None:
        h.update(f"|bits={quant_bits}|".encode())
    for group in sorted(groups):
        h.update(f"|{group}|".encode())
        arrays = groups[group]
        for key in sorted(arrays):
            a = np.ascontiguousarray(arrays[key])
            h.update(key.encode())
            h.update(str(a.shape).encode())
            h.update(str(a.dtype).encode())
            h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Records.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelVersion:
    """Immutable metadata for one published version (manifest mirror)."""

    name: str
    version: int
    digest: str
    created_at: float
    cfg: SNNConfig
    has_masks: bool
    has_lsq: bool
    quant_bits: int               # LSQ bit width (meaningful when has_lsq)
    assignment: Any               # backend name or {layer: backend}
    plan_digest: Optional[str]
    metrics: Dict[str, Any]
    path: str

    @property
    def spec(self) -> str:
        return f"{self.name}@{self.version}"


@dataclasses.dataclass(frozen=True)
class LoadedModel:
    """A fully-materialized registry version, ready to bind or serve."""

    params: Any
    masks: Optional[Any]
    lsq_scales: Optional[Any]
    cfg: SNNConfig
    version: ModelVersion


# ---------------------------------------------------------------------------
# The registry.
# ---------------------------------------------------------------------------

class ModelRegistry:
    """Directory-backed versioned model store with named aliases.

    All writes are atomic (tmp dir/file + ``os.replace``): a publisher
    killed mid-write can never leave a half-written version that a serving
    node would load.  In-process access is thread-safe; cross-process
    publishing relies on the atomic renames (last alias write wins).
    """

    def __init__(self, root: Optional[str] = None):
        self.root = pathlib.Path(root).expanduser() if root else _default_dir()
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.RLock()

    # -- paths --------------------------------------------------------------

    def _model_dir(self, name: str) -> pathlib.Path:
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid model name {name!r}")
        return self.root / name

    def _version_dir(self, name: str, version: int) -> pathlib.Path:
        return self._model_dir(name) / f"v{version:04d}"

    # -- enumeration --------------------------------------------------------

    def models(self) -> List[str]:
        return sorted(p.name for p in self.root.iterdir()
                      if p.is_dir() and _NAME_RE.match(p.name))

    def versions(self, name: str) -> List[int]:
        mdir = self._model_dir(name)
        if not mdir.exists():
            return []
        out = []
        for p in mdir.iterdir():
            m = _VERSION_RE.match(p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self, name: str) -> Optional[int]:
        vs = self.versions(name)
        return vs[-1] if vs else None

    # -- aliases ------------------------------------------------------------

    def aliases(self, name: str) -> Dict[str, int]:
        path = self._model_dir(name) / "aliases.json"
        if not path.exists():
            return {}
        try:
            return {str(k): int(v) for k, v in
                    json.loads(path.read_text()).items()}
        except Exception:  # noqa: BLE001 — treat a corrupt map as empty
            return {}

    def set_alias(self, name: str, alias: str, version: int) -> None:
        # numeric and v<digits> forms are version references in resolve();
        # allowing them as aliases would silently shadow real versions
        if (not _NAME_RE.match(alias) or alias.isdigit()
                or re.fullmatch(r"v\d+", alias)):
            raise ValueError(f"invalid alias {alias!r}")
        with self._lock:
            if version not in self.versions(name):
                raise KeyError(f"{name} has no version {version}")
            amap = self.aliases(name)
            amap[alias] = int(version)
            self._write_aliases(name, amap)

    def drop_alias(self, name: str, alias: str) -> None:
        with self._lock:
            amap = self.aliases(name)
            amap.pop(alias, None)
            self._write_aliases(name, amap)

    def _write_aliases(self, name: str, amap: Dict[str, int]) -> None:
        mdir = self._model_dir(name)
        mdir.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=mdir, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(amap, f, indent=1)
        os.replace(tmp, mdir / "aliases.json")

    # -- resolve ------------------------------------------------------------

    def resolve(self, spec: str) -> Tuple[str, int]:
        """``name[@version|@alias]`` -> (name, version).

        A bare ``name`` resolves through the ``production`` alias when set,
        else to the latest version.
        """
        name, _, ref = spec.partition("@")
        if not ref:
            amap = self.aliases(name)
            if "production" in amap:
                return name, amap["production"]
            latest = self.latest(name)
            if latest is None:
                raise KeyError(f"registry has no versions of {name!r}")
            return name, latest
        if ref.lstrip("v").isdigit():
            version = int(ref.lstrip("v"))
        else:
            amap = self.aliases(name)
            if ref not in amap:
                raise KeyError(
                    f"{name!r} has no alias {ref!r} (aliases: "
                    f"{sorted(amap) or 'none'})")
            version = amap[ref]
        if version not in self.versions(name):
            raise KeyError(f"{name} has no version {version}")
        return name, version

    # -- publish ------------------------------------------------------------

    def publish(
        self,
        name: str,
        params,
        cfg: SNNConfig,
        *,
        masks=None,
        lsq_scales=None,
        quant_bits: int = 16,
        assignment: Any = "goap",
        metrics: Optional[Dict[str, Any]] = None,
        alias: Optional[str] = None,
        compile_plan_artifacts: bool = True,
    ) -> ModelVersion:
        """Publish one model version; idempotent on identical content.

        ``assignment`` is the backend (or per-layer map) recorded for
        serving; when ``compile_plan_artifacts`` is set the version's
        :class:`ExecutionPlan` is compiled through the shared plan cache —
        its digest lands in the manifest and the expensive COO/schedule
        artifacts land on disk, so the serving node's hot-swap bind is a
        cache hit.
        """
        groups = {"params": _flatten_group("params", params)}
        if masks is not None:
            groups["masks"] = _flatten_group("masks", masks)
        if lsq_scales is not None:
            groups["lsq"] = _flatten_group("lsq", lsq_scales)
        digest = _content_digest(cfg, groups,
                                 quant_bits if lsq_scales is not None
                                 else None)

        # everything expensive — the plan compile (cached, lock-free by
        # construction) and the full-model array serialization — happens
        # before the registry lock, so concurrent publishes, alias flips
        # and resolves only ever wait on the version-number allocation,
        # the manifest write, and the atomic rename
        plan_digest = None
        if compile_plan_artifacts:
            plan_digest = self._compile_plan_digest(
                params, cfg, masks, lsq_scales, quant_bits, assignment)

        mdir = self._model_dir(name)
        mdir.mkdir(parents=True, exist_ok=True)
        tmp = pathlib.Path(tempfile.mkdtemp(dir=mdir, prefix=".tmp-pub-"))
        try:
            arrays = {k: v for g in groups.values() for k, v in g.items()}
            np.savez(tmp / "arrays.npz", **arrays)

            with self._lock:
                existing = self.find_digest(name, digest)
                if existing is not None:
                    if alias:
                        self.set_alias(name, alias, existing.version)
                    return existing
                version = (self.latest(name) or 0) + 1

                manifest = {
                    "format": _FORMAT,
                    "name": name,
                    "version": version,
                    "digest": digest,
                    "created_at": time.time(),
                    "cfg": _cfg_to_json(cfg),
                    "has_masks": masks is not None,
                    "has_lsq": lsq_scales is not None,
                    "quant_bits": int(quant_bits),
                    "assignment": assignment,
                    "plan_digest": plan_digest,
                    "metrics": dict(metrics or {}),
                }
                (tmp / "manifest.json").write_text(json.dumps(manifest,
                                                              indent=1))
                final = self._version_dir(name, version)
                os.replace(tmp, final)  # atomic publish
                if alias:
                    self.set_alias(name, alias, version)
                return self._version_from_manifest(manifest, final)
        finally:
            if tmp.exists():
                import shutil

                shutil.rmtree(tmp, ignore_errors=True)

    @staticmethod
    def _compile_plan_digest(params, cfg, masks, lsq_scales, quant_bits,
                             assignment) -> Optional[str]:
        """Compile the version's plan (warming the shared cache)."""
        try:
            from repro.models.graph import compile_snn
            from repro.plan import compile_plan

            # same rule as the serve engines (repro.fixed.serving_quant_fn)
            # so the prewarmed plan digest matches what a fixed-assignment
            # bind_version will compile
            from repro.fixed import serving_quant_fn

            quant_fn = serving_quant_fn(lsq_scales, quant_bits,
                                        assignment=assignment)
            program = compile_snn(cfg)
            return compile_plan(program, params, masks=masks,
                                quant_fn=quant_fn,
                                assignment=assignment).digest
        except Exception:  # noqa: BLE001 — registry must publish even when
            # a backend cannot bind on this host (e.g. pallas assignment on
            # an unsupported platform); the manifest just lacks the digest
            return None

    def find_digest(self, name: str, digest: str) -> Optional[ModelVersion]:
        for v in reversed(self.versions(name)):
            mv = self.describe(name, v)
            if mv.digest == digest:
                return mv
        return None

    # -- load ---------------------------------------------------------------

    def describe(self, name: str, version: int) -> ModelVersion:
        vdir = self._version_dir(name, version)
        manifest = json.loads((vdir / "manifest.json").read_text())
        return self._version_from_manifest(manifest, vdir)

    @staticmethod
    def _version_from_manifest(manifest: Dict[str, Any],
                               vdir: pathlib.Path) -> ModelVersion:
        assignment = manifest["assignment"]
        if isinstance(assignment, dict):
            assignment = dict(assignment)
        return ModelVersion(
            name=manifest["name"], version=int(manifest["version"]),
            digest=manifest["digest"], created_at=manifest["created_at"],
            cfg=_cfg_from_json(manifest["cfg"]),
            has_masks=bool(manifest["has_masks"]),
            has_lsq=bool(manifest["has_lsq"]),
            quant_bits=int(manifest.get("quant_bits", 16)),
            assignment=assignment,
            plan_digest=manifest.get("plan_digest"),
            metrics=dict(manifest.get("metrics", {})),
            path=str(vdir))

    def load(self, spec: str) -> LoadedModel:
        """Materialize ``name[@version|@alias]`` into live pytrees.

        Tree *structures* are rebuilt from the version's own config (the
        registry stores flat leaves), so a load can never silently mix a
        new code structure with old bytes — shape drift raises.
        """
        name, version = self.resolve(spec)
        mv = self.describe(name, version)
        data = np.load(pathlib.Path(mv.path) / "arrays.npz")
        like_params = init_snn(jax.random.PRNGKey(0), mv.cfg)
        params = _unflatten_group("params", data, like_params)
        masks = None
        if mv.has_masks:
            like_masks = jax.tree_util.tree_map(np.ones_like, {
                "conv": [l["w"] for l in like_params["conv"]],
                "fc": [l["w"] for l in like_params["fc"]],
            })
            masks = _unflatten_group("masks", data, like_masks)
        lsq = None
        if mv.has_lsq:
            from repro.train.lsq import init_lsq_scales

            lsq = _unflatten_group("lsq", data, init_lsq_scales(like_params))
        return LoadedModel(params=params, masks=masks, lsq_scales=lsq,
                           cfg=mv.cfg, version=mv)


# ---------------------------------------------------------------------------
# Checkpoint bridge.
# ---------------------------------------------------------------------------

def publish_from_trainer(registry: ModelRegistry, name: str, trainer, *,
                         assignment: Any = "goap",
                         metrics: Optional[Dict[str, Any]] = None,
                         alias: Optional[str] = None) -> ModelVersion:
    """Publish a live :class:`~repro.train.trainer.SNNTrainer`'s state."""
    m = {"source_step": trainer.step, **(metrics or {})}
    return registry.publish(
        name, trainer.params, trainer.model_cfg, masks=trainer.masks,
        lsq_scales=trainer.lsq_scales, quant_bits=trainer.cfg.quant_bits,
        assignment=assignment, metrics=m, alias=alias)


def publish_from_checkpoint(
    registry: ModelRegistry,
    name: str,
    model_cfg: SNNConfig,
    trainer_cfg=None,
    *,
    ckpt_dir: Optional[str] = None,
    step: Optional[int] = None,
    assignment: Any = "goap",
    metrics: Optional[Dict[str, Any]] = None,
    alias: Optional[str] = None,
) -> ModelVersion:
    """Restore a trainer checkpoint and publish it as a registry version.

    ``trainer_cfg`` must match the run that wrote the checkpoint (it
    shapes the masks/LSQ state trees); ``ckpt_dir`` overrides its
    checkpoint directory.  ``step`` picks a specific checkpoint (default:
    latest).
    """
    import dataclasses as _dc

    from repro.train.trainer import SNNTrainer, TrainerConfig

    tcfg = trainer_cfg if trainer_cfg is not None else TrainerConfig()
    if ckpt_dir is not None:
        tcfg = _dc.replace(tcfg, ckpt_dir=ckpt_dir)
    if tcfg.ckpt_dir is None:
        raise ValueError("no checkpoint directory: pass ckpt_dir= or a "
                         "trainer_cfg with ckpt_dir set")
    trainer = SNNTrainer(model_cfg, tcfg)
    if not trainer.resume(step=step):
        raise FileNotFoundError(f"no checkpoint under {tcfg.ckpt_dir}")
    manifest = trainer.ckpt.read_manifest(trainer.step)
    m = {"checkpoint_dir": str(tcfg.ckpt_dir),
         **manifest.get("extra", {}), **(metrics or {})}
    return publish_from_trainer(registry, name, trainer,
                                assignment=assignment, metrics=m,
                                alias=alias)
