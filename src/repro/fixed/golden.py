"""Pure-NumPy golden reference for the fixed-point datapath.

This is the bit-exactness oracle: a tiny interpreter that executes the
FPGA datapath op-for-op — int32 gated accumulation, arithmetic-shift leak
and accumulator scaling, strict threshold compare, soft reset, saturating
int16 membrane write-back, integer Q0.15 Sigma-Delta front end — with no
JAX anywhere in the runtime.  The ``fixed`` backend's jnp cells
(:mod:`repro.fixed.backend`) must agree with this interpreter to the bit;
tests pin that on a grid of seeded configs at 8 and 16 bits.

Offline conversion (float -> codes/shifts/thresholds) is shared with the
backend via :mod:`repro.fixed.quantize` on purpose: a shared conversion
makes any disagreement a runtime *datapath* divergence, which is exactly
what the golden exists to catch.

All integer ops here use wrap-around int32 semantics identical to XLA's
(NumPy matmul of int32 operands accumulates in int32; ``>>`` on signed
ints is an arithmetic shift in both).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.fixed.encoder import ENC_HALF, ENC_ONE
from repro.fixed.quantize import (
    I16_MAX,
    I16_MIN,
    FixedLIF,
    derive_fixed_layer,
    fixed_logit_scale,
    lif_to_fixed,
)
from repro.models.graph import KIND_CONV, KIND_FC, KIND_POOL, KIND_READOUT, build_layer_graph

__all__ = ["GoldenNet", "build_golden", "golden_lif_step",
           "golden_normalize_iq", "golden_sigma_delta_encode",
           "golden_encode_frames"]


# ---------------------------------------------------------------------------
# Integer Sigma-Delta front end (mirrors repro.fixed.encoder bit-for-bit).
# ---------------------------------------------------------------------------

def golden_normalize_iq(iq: np.ndarray) -> np.ndarray:
    """float32 max-abs AGC, identical operation order to normalize_iq."""
    iq = np.asarray(iq, np.float32)
    peak = np.max(np.abs(iq), axis=(-2, -1), keepdims=True)
    return np.float32(0.5) * (iq / (peak + np.float32(1e-8)) + np.float32(1.0))


def golden_sigma_delta_encode(x: np.ndarray, osr: int) -> np.ndarray:
    """x (...,) float32 in [0, 1] -> bits (osr, ...) int32 in {0, 1}."""
    xq = np.round(np.asarray(x, np.float32) * np.float32(ENC_ONE)).astype(np.int32)
    integ = np.zeros_like(xq)
    y = np.zeros_like(xq)
    bits = np.empty((osr,) + xq.shape, np.int32)
    for t in range(osr):
        integ = integ + xq - y * np.int32(ENC_ONE)
        y = (integ >= ENC_HALF).astype(np.int32)
        bits[t] = y
    return bits


def golden_encode_frames(iq: np.ndarray, osr: int) -> np.ndarray:
    """(..., 2, L) float I/Q -> (T=osr, ..., 2, L) int32 spike frames."""
    return golden_sigma_delta_encode(golden_normalize_iq(iq), osr)


# ---------------------------------------------------------------------------
# Integer LIF + layer interpreter.
# ---------------------------------------------------------------------------

def golden_lif_step(v16: np.ndarray, acc32: np.ndarray, flif: FixedLIF):
    """One integer LIF update (NumPy mirror of backend.fixed_lif_step)."""
    v32 = v16.astype(np.int32)
    v_dec = v32 - (v32 >> flif.leak_shift)
    v_acc = v_dec + (acc32 >> np.int32(flif.acc_shift))
    s = (v_acc > flif.vth).astype(np.int32)
    v_next = np.clip(v_acc - flif.theta * s, I16_MIN, I16_MAX).astype(np.int16)
    return v_next, s


def _shift_buffer(ifm: np.ndarray, kw: int) -> np.ndarray:
    """(IC, WI) -> X'(IC*KW, OI), row ic*KW+ci holds I[ic] shifted by ci."""
    ic, wi = ifm.shape
    oi = wi - kw + 1
    idx = np.arange(kw)[:, None] + np.arange(oi)[None, :]
    return ifm[:, idx].reshape(ic * kw, oi)


def _pad_same(x: np.ndarray, kw: int) -> np.ndarray:
    left = (kw - 1) // 2
    return np.pad(x, [(0, 0)] * (x.ndim - 1) + [(left, kw - 1 - left)])


@dataclasses.dataclass
class _Layer:
    kind: str
    kw: int = 0
    pool: int = 0
    wmat: Optional[np.ndarray] = None   # conv: (OC, IC*KW); fc: (DIN, DOUT)
    oc: int = 0
    flif: Optional[FixedLIF] = None
    use_current: bool = False


@dataclasses.dataclass
class GoldenNet:
    """The built golden model: layers + the logit dequantization scale."""

    layers: List[_Layer]
    timesteps: int
    logit_scale: float

    def forward(self, frames: np.ndarray) -> np.ndarray:
        """(T, IC0, W) binary frames -> int32 logits."""
        frames = np.asarray(frames).astype(np.int32)
        states: List = []
        for layer in self.layers:
            states.append(None)
        acc = None
        for t in range(frames.shape[0]):
            x = frames[t]
            for i, layer in enumerate(self.layers):
                if layer.kind == KIND_CONV:
                    if states[i] is None:
                        states[i] = np.zeros((layer.oc, x.shape[-1]), np.int16)
                    cur = layer.wmat @ _shift_buffer(
                        _pad_same(x, layer.kw), layer.kw).astype(np.int32)
                    states[i], x = golden_lif_step(states[i], cur, layer.flif)
                elif layer.kind == KIND_POOL:
                    c, w = x.shape
                    w2 = (w // layer.pool) * layer.pool
                    x = x[:, :w2].reshape(c, w2 // layer.pool, layer.pool).max(axis=-1)
                elif layer.kind == KIND_FC:
                    if states[i] is None:
                        states[i] = np.zeros((layer.wmat.shape[1],), np.int16)
                    s_in = x[0] if isinstance(x, tuple) else x  # _spikes_of
                    s_in = s_in.reshape(-1).astype(np.int32)
                    cur = s_in @ layer.wmat
                    states[i], spikes = golden_lif_step(states[i], cur, layer.flif)
                    x = (spikes, cur)
                elif layer.kind == KIND_READOUT:
                    spikes_t, cur_t = x
                    inc = cur_t if layer.use_current else spikes_t
                    acc = inc.copy() if acc is None else acc + inc
                    x = spikes_t
        return np.asarray(acc, np.int32)

    def forward_iq(self, iq: np.ndarray) -> np.ndarray:
        """(2, L) float I/Q -> int32 logits via the integer encoder."""
        return self.forward(golden_encode_frames(iq, self.timesteps))


def build_golden(cfg, params, masks=None, quant_fn=None) -> GoldenNet:
    """Build the golden model from float params (+ optional masks/LSQ).

    Uses the same offline conversion as the fixed backend.  When
    ``quant_fn`` is a stateful fake-quant closure it is consumed in graph
    order exactly like a bind — pass a **fresh** FixedQuantFn, never one
    already used for a backend bind.
    """
    layers: List[_Layer] = []
    for spec in build_layer_graph(cfg):
        if spec.kind == KIND_CONV:
            lp = params["conv"][spec.index]
            m = masks["conv"][spec.index] if masks else None
            ql = derive_fixed_layer("conv", spec.index, lp["w"], mask=m,
                                    quant_fn=quant_fn)
            wmat = np.transpose(ql.codes, (2, 1, 0)).reshape(
                spec.oc, -1).astype(np.int32)
            layers.append(_Layer(kind=spec.kind, kw=spec.kw, oc=spec.oc,
                                 wmat=wmat,
                                 flif=lif_to_fixed(lp["lif"], ql.step)))
        elif spec.kind == KIND_POOL:
            layers.append(_Layer(kind=spec.kind, pool=spec.pool))
        elif spec.kind == KIND_FC:
            lp = params["fc"][spec.index]
            m = masks["fc"][spec.index] if masks else None
            ql = derive_fixed_layer("fc", spec.index, lp["w"], mask=m,
                                    quant_fn=quant_fn)
            layers.append(_Layer(kind=spec.kind,
                                 wmat=ql.codes.astype(np.int32),
                                 flif=lif_to_fixed(lp["lif"], ql.step)))
        elif spec.kind == KIND_READOUT:
            layers.append(_Layer(kind=spec.kind,
                                 use_current=spec.mode == "current_sum"))
    # note: scale uses the *stateless* step lookup, so it does not disturb
    # the quant_fn's layer-order index
    scale = fixed_logit_scale(
        params, cfg, masks=masks,
        quant_fn=quant_fn if hasattr(quant_fn, "step_for") else None)
    return GoldenNet(layers=layers, timesteps=cfg.timesteps, logit_scale=scale)
