import numpy as np
import pytest

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Minimal environments run without hypothesis: property tests skip via
    # the tests/_hyp.py shim and the profile setup below is a no-op.
    settings = None

if settings is not None:
    # Keep hypothesis fast and deterministic on CI-class CPU containers.
    settings.register_profile("ci", max_examples=25, deadline=None, derandomize=True)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
