"""Fusion benchmark: fused streaming executor vs layer-by-layer execution,
plus cold/memory/disk plan-compile cost, on the paper config.

Two questions, answered with wall-clock numbers in ``BENCH_fusion.json``:

* **Execution** — does threading all layers through one ``lax.scan``
  (``ExecutionPlan.batch``, the paper's inter-layer pipeline analogue)
  beat the layer-by-layer path (``plan.bound.batch``) that materializes
  every intermediate (T, C, W) sequence?  Measured per backend on the
  paper config at 50% density; the two paths are also asserted allclose.
* **Compilation** — what does ``compile_plan`` cost cold (artifacts
  derived from weights), warm in memory (same process rebind: trainer
  eval loops), and warm from disk (process restart: serve redeploys)?
  The artifact build counter is recorded alongside so "cached" provably
  means "nothing rebuilt".

Run:  PYTHONPATH=src python benchmarks/fusion_bench.py [--smoke] [--out p]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.api import compile_plan, compile_snn, init_snn
from repro.configs.saocds_amc import CONFIG as CFG
from repro.models.graph import artifact_build_count
from repro.plan import PlanCache
from repro.train.pruning import make_mask_pytree

NAME = "fusion_bench"

DENSITY = 0.5
EXEC_BACKENDS = ("dense", "goap")  # pallas interpret mode is CPU-meaningless


def _spike_frames(batch: int) -> jnp.ndarray:
    rng = np.random.default_rng(0)
    shape = (batch, CFG.timesteps, CFG.conv_specs[0][1], CFG.input_width)
    return jnp.asarray((rng.random(shape) < 0.5).astype(np.float32))


def _time(fn, *args, reps: int = 3) -> float:
    jax.block_until_ready(fn(*args))  # compile + warm up
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps


def run(batch: int = 32, reps: int = 3) -> dict:
    program = compile_snn(CFG)
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, DENSITY)
    frames = _spike_frames(batch)

    # -- plan compile: cold vs memory-cached vs disk-cached -----------------
    tmp = tempfile.mkdtemp(prefix="fusion-bench-plans-")
    try:
        cache = PlanCache(tmp)
        n0 = artifact_build_count()
        t0 = time.perf_counter()
        compile_plan(program, params, masks=masks, assignment="goap",
                     cache=cache)
        cold_s = time.perf_counter() - t0
        cold_builds = artifact_build_count() - n0

        t0 = time.perf_counter()
        compile_plan(program, params, masks=masks, assignment="goap",
                     cache=cache)
        memory_s = time.perf_counter() - t0
        memory_builds = artifact_build_count() - n0 - cold_builds

        cache2 = PlanCache(tmp)  # fresh memory over same disk dir = restart
        t0 = time.perf_counter()
        compile_plan(program, params, masks=masks, assignment="goap",
                     cache=cache2)
        disk_s = time.perf_counter() - t0
        disk_builds = (artifact_build_count() - n0 - cold_builds
                       - memory_builds)

        compile_row = {
            "cold_s": cold_s, "cold_artifact_builds": cold_builds,
            "memory_hit_s": memory_s,
            "memory_hit_artifact_builds": memory_builds,
            "disk_hit_s": disk_s, "disk_hit_artifact_builds": disk_builds,
            "cold_over_memory": cold_s / max(memory_s, 1e-9),
            "cold_over_disk": cold_s / max(disk_s, 1e-9),
        }

        # -- execution: fused single-scan vs layer-by-layer -----------------
        rows = []
        for backend in EXEC_BACKENDS:
            plan = compile_plan(program, params, masks=masks,
                                assignment=backend, cache=cache)
            layered = jax.jit(plan.bound.batch)
            fused = jax.jit(plan.batch)
            out_l = np.asarray(layered(frames))
            out_f = np.asarray(fused(frames))
            err = float(np.abs(out_l - out_f).max())
            t_layered = _time(layered, frames, reps=reps)
            t_fused = _time(fused, frames, reps=reps)
            rows.append({
                "backend": backend,
                "layered_ms": t_layered * 1e3,
                "fused_ms": t_fused * 1e3,
                "layered_fps": batch / t_layered,
                "fused_fps": batch / t_fused,
                "fused_speedup": t_layered / max(t_fused, 1e-9),
                "max_abs_err": err,
            })
            assert err <= 1e-5, f"fused != layered for {backend}: {err}"
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    return {
        "config": "saocds-amc (paper)",
        "density": DENSITY,
        "batch": batch,
        "jax_backend": jax.default_backend(),
        "compile": compile_row,
        "execution": rows,
    }


def format_table(res: dict) -> str:
    c = res["compile"]
    lines = [
        f"Fusion bench: paper config, density {res['density']}, batch "
        f"{res['batch']}, {res['jax_backend']}",
        f"  compile_plan  cold {c['cold_s'] * 1e3:8.1f} ms "
        f"({c['cold_artifact_builds']} artifact builds)   "
        f"memory hit {c['memory_hit_s'] * 1e3:6.2f} ms   "
        f"disk hit {c['disk_hit_s'] * 1e3:6.2f} ms "
        f"(both rebuild {c['memory_hit_artifact_builds']}/"
        f"{c['disk_hit_artifact_builds']} artifacts)",
    ]
    for r in res["execution"]:
        lines.append(
            f"  {r['backend']:6s} layered {r['layered_ms']:8.1f} ms "
            f"({r['layered_fps']:7.1f} fps)   fused {r['fused_ms']:8.1f} ms "
            f"({r['fused_fps']:7.1f} fps)   speedup {r['fused_speedup']:.2f}x"
            f"   err {r['max_abs_err']:.1e}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced batch/reps for CI smoke runs")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default="BENCH_fusion.json")
    args = ap.parse_args(argv)

    batch = args.batch if args.batch else (8 if args.smoke else 32)
    reps = args.reps if args.reps else (1 if args.smoke else 3)
    res = run(batch=batch, reps=reps)
    print(format_table(res))
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(res, indent=1, default=str))
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
