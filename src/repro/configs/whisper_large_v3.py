"""whisper-large-v3 [audio] — arXiv:2212.04356 (unverified).

32 encoder + 32 decoder layers, d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866.  Conv/log-mel frontend is a STUB: input_specs provides
precomputed frame embeddings (B, S_enc, d).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv=20,
    d_ff=5120, vocab=51866, head_dim=64,
    qkv_bias=True, rope_enabled=False,
    tie_embeddings=True,
    notes="enc-dec; conv frontend stubbed to frame embeddings; abs positions",
)
