"""Fleet tier: replica router, admission control, deadlines/priorities,
autoscaler control law, fleet-wide deploy operations.

Everything here is deterministic: seeded inputs, fake clocks for the
autoscaler, and saturation built from the batcher's ``pace_ms`` gate
(a capacity *configuration*, not a host-speed race).  Timing assertions
that do touch the wall clock use generous margins.
"""
import threading
import time

import numpy as np
import pytest

import jax

from repro.api import SNNConfig, init_snn
from repro.deploy import hot_swap
from repro.fleet import (
    Autoscaler,
    FleetRouter,
    ShedError,
    engine_factory,
    merge_stats,
)
from repro.serve import (
    AsyncAMCServeEngine,
    DeadlineExceeded,
    MicroBatcher,
    QueueFull,
    ServeStats,
)
from repro.train.pruning import make_mask_pytree

CFG = SNNConfig(
    conv_specs=((3, 2, 4), (3, 4, 8)),
    pool=2,
    fc_specs=((32, 16), (16, 5)),
    input_width=16,
    timesteps=3,
    n_classes=5,
)
FRAME_SHAPE = (2, CFG.input_width)


@pytest.fixture(scope="module")
def weights():
    params = init_snn(jax.random.PRNGKey(0), CFG)
    masks = make_mask_pytree(params, 0.5)
    return params, masks


def _iq(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n,) + FRAME_SHAPE).astype(np.float32)


def _factory(weights, **kw):
    params, masks = weights
    kw.setdefault("backend", "dense")
    kw.setdefault("buckets", [4])
    kw.setdefault("max_delay_ms", 5)
    return engine_factory(params, CFG, masks=masks, **kw)


# ---------------------------------------------------------------------------
# deadlines: expired requests fail fast and never reach the jitted step
# ---------------------------------------------------------------------------

def test_expired_request_never_reaches_step(weights):
    params, masks = weights
    eng = AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                              buckets=[4], max_delay_ms=5,
                              pace_ms=200.0, warmup=True)
    try:
        ver = eng.get_version("default")
        calls = {"n": 0, "batch_sizes": []}
        inner = ver.step

        def counting_step(frames):
            calls["n"] += 1
            calls["batch_sizes"].append(int(frames.shape[0]))
            return inner(frames)

        ver.step = counting_step
        # the pace gate spaces *consecutive* flushes 200 ms apart: serve a
        # plug request first, then a 5 ms deadline is guaranteed-expired
        # by the time the next flush dequeues
        plug = eng.submit(_iq(1)[0], deadline_ms=5_000.0)
        assert plug.result(timeout=10.0) is not None
        doomed = eng.submit(_iq(1)[0], deadline_ms=5.0)
        time.sleep(0.03)
        live = eng.submit(_iq(1, seed=1)[0], deadline_ms=5_000.0)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=5.0)
        assert live.result(timeout=10.0) is not None
        # the expired frame consumed zero jitted-step slots: exactly two
        # batches ran (plug, live) and only those two frames were served
        assert calls["n"] == 2
        assert eng.stats.requests == 2
        assert eng.batcher.n_expired == 1
    finally:
        eng.close()


def test_deadline_propagates_through_fleet(weights):
    fleet = FleetRouter(_factory(weights, pace_ms=200.0), replicas=1,
                        default_deadline_ms=5.0)
    try:
        fut = fleet.submit(_iq(1)[0])       # inherits the default deadline
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=5.0)
        assert fleet.batcher.n_expired == 1
        assert fleet.export_stats()["n_expired"] == 1
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# priorities: weighted dequeue order, realtime ahead of bulk
# ---------------------------------------------------------------------------

def test_priority_dequeue_order_deterministic():
    mb = MicroBatcher(FRAME_SHAPE, max_batch=1, max_delay_ms=1)
    frames = _iq(8)
    order = []
    for i in range(4):
        f = mb.submit(frames[i], priority="bulk")
        f.add_done_callback(lambda _f, i=i: order.append(("bulk", i)))
    for i in range(4):
        f = mb.submit(frames[4 + i], priority="realtime")
        f.add_done_callback(lambda _f, i=i: order.append(("rt", i)))
    # drain one-request batches by hand: the weighted round-robin
    # (realtime:8, bulk:1) must serve all four realtime first even though
    # every bulk request arrived earlier, and FIFO within each class
    for _ in range(8):
        batch = mb.get_batch(timeout=1.0)
        for req in batch.requests:
            req.future.set_result(0)
    mb.close()
    assert order[:4] == [("rt", 0), ("rt", 1), ("rt", 2), ("rt", 3)]
    assert order[4:] == [("bulk", 0), ("bulk", 1), ("bulk", 2), ("bulk", 3)]


def test_realtime_p99_beats_bulk_under_saturation(weights):
    """Saturate one paced replica; realtime tail must stay below bulk's."""
    params, masks = weights
    eng = AsyncAMCServeEngine(params, CFG, masks=masks, backend="dense",
                              buckets=[4], max_delay_ms=2,
                              pace_ms=25.0, warmup=True)
    lat = {"realtime": [], "bulk": []}
    lock = threading.Lock()
    try:
        rng = np.random.default_rng(7)
        frames = _iq(64, seed=3)
        futures = []
        # enqueue a standing backlog (mixed classes, seeded order) much
        # larger than one batch: dequeue order is then pure policy
        kinds = ["bulk" if rng.random() < 0.5 else "realtime"
                 for _ in range(64)]
        t0 = time.perf_counter()
        for i, kind in enumerate(kinds):
            fut = eng.submit(frames[i], priority=kind)

            def done(_f, kind=kind, t0=t0):
                with lock:
                    lat[kind].append(time.perf_counter() - t0)

            fut.add_done_callback(done)
            futures.append(fut)
        for fut in futures:
            fut.result(timeout=60.0)
        p99_rt = float(np.percentile(lat["realtime"], 99))
        p99_bulk = float(np.percentile(lat["bulk"], 99))
        assert p99_rt < p99_bulk, (
            f"realtime p99 {p99_rt*1e3:.1f}ms not below bulk "
            f"{p99_bulk*1e3:.1f}ms")
    finally:
        eng.close()


# ---------------------------------------------------------------------------
# admission control / shedding
# ---------------------------------------------------------------------------

def test_no_shed_below_saturation(weights):
    fleet = FleetRouter(_factory(weights, max_queue=64), replicas=1)
    try:
        out = fleet.classify(_iq(16), timeout=30.0)
        assert out.shape == (16,)
        assert fleet.n_shed == 0
        assert fleet.export_stats()["n_shed"] == 0
    finally:
        fleet.close()


def test_queue_bound_sheds_at_the_door(weights):
    # pace gate effectively freezes the workers; queues fill to max_queue
    fleet = FleetRouter(_factory(weights, max_queue=4, pace_ms=60_000.0),
                        replicas=2)
    try:
        frames = _iq(16, seed=2)
        admitted = []
        for i in range(8):      # 2 replicas x max_queue=4
            admitted.append(fleet.submit(frames[i]))
        with pytest.raises(ShedError) as exc:
            fleet.submit(frames[8])
        assert exc.value.reason == "queue"
        assert fleet.n_shed == 1
        assert fleet.shed_by_reason["queue"] == 1
        assert fleet.shed_by_priority["realtime"] == 1
        # JSQ spread the admitted load evenly across both replicas
        depths = [r.engine.batcher.qsize() for r in fleet._snapshot()]
        assert depths == [4, 4]
        for fut in admitted:
            fut.cancel()
    finally:
        fleet.close()


def test_p99_breach_sheds_bulk_only(weights):
    fleet = FleetRouter(_factory(weights), replicas=1, shed_p99_ms=0.5)
    try:
        # prime the latency window past the (absurdly low) threshold
        fleet.classify(_iq(8), timeout=30.0)
        assert fleet.recent_p99_ms() > 0.5
        with pytest.raises(ShedError) as exc:
            fleet.submit(_iq(1)[0], priority="bulk")
        assert exc.value.reason == "p99"
        # realtime still admitted during the breach
        fut = fleet.submit(_iq(1)[0], priority="realtime")
        assert fut.result(timeout=30.0) is not None
        assert fleet.shed_by_priority["bulk"] == 1
        assert fleet.shed_by_priority["realtime"] == 0
    finally:
        fleet.close()


def test_rejects_unknown_priority(weights):
    fleet = FleetRouter(_factory(weights), replicas=1)
    try:
        with pytest.raises(ValueError):
            fleet.submit(_iq(1)[0], priority="best-effort")
    finally:
        fleet.close()


def test_fenced_replica_takes_no_new_traffic(weights):
    """The scale_down retirement fence: a replica whose fence is up must
    be skipped by submit even while it still sits in a (stale) routing
    snapshot — the window in which a request could otherwise land behind
    the drain barrier and be dropped by the subsequent engine close."""
    fleet = FleetRouter(_factory(weights), replicas=2)
    try:
        fenced = fleet._snapshot()[1]
        with fenced.gate:       # exactly what scale_down does before draining
            fenced.fenced = True
        futures = [fleet.submit(_iq(4, seed=13)[i]) for i in range(4)]
        # all traffic routed around the fence (JSQ would otherwise have
        # spread it across both replicas)
        assert fenced.engine.batcher.qsize() == 0
        for f in futures:
            assert f.result(timeout=30.0) is not None
        assert fenced.engine.stats.requests == 0
        assert fleet.n_shed == 0
    finally:
        fleet.close()


def test_engine_fault_propagates_instead_of_shedding(weights):
    """Only EngineClosed/QueueFull reroute to the next replica; a genuine
    engine fault must propagate, not be miscounted as a queue shed."""
    fleet = FleetRouter(_factory(weights), replicas=1)
    try:
        rep = fleet._snapshot()[0]
        orig = rep.engine.submit

        def broken(*a, **kw):
            raise RuntimeError("worker fault")

        rep.engine.submit = broken
        with pytest.raises(RuntimeError, match="worker fault"):
            fleet.submit(_iq(1)[0])
        assert fleet.n_shed == 0
        rep.engine.submit = orig
        assert fleet.submit(_iq(1)[0]).result(timeout=30.0) is not None
    finally:
        fleet.close()


def test_scale_down_drains_reordered_priority_backlog(weights):
    """scale_down on a replica whose queue holds bulk requests *behind*
    already-served realtime ones: the drain barrier must wait for the
    low-seq bulk backlog (a max-seq watermark would release early and
    the close would fail the still-queued futures)."""
    fleet = FleetRouter(_factory(weights, pace_ms=40.0), replicas=2)
    try:
        rep = fleet._snapshot()[1]
        frames = _iq(10, seed=17)
        # enqueue directly into the doomed replica: bulk first (low seqs),
        # then realtime (high seqs) — WRR hands the realtime ones first
        futures = [rep.engine.submit(frames[i], priority="bulk")
                   for i in range(5)]
        futures += [rep.engine.submit(frames[5 + i], priority="realtime")
                    for i in range(5)]
        assert fleet.scale_down(drain_timeout=60.0) == rep.name
        # zero dropped requests: every future resolved with a prediction
        for f in futures:
            assert f.result(timeout=30.0) is not None
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# elasticity: scale up/down, lineage replay, merged stats
# ---------------------------------------------------------------------------

def test_scale_up_down_and_serve(weights):
    fleet = FleetRouter(_factory(weights), replicas=1, max_replicas=3)
    try:
        assert fleet.n_replicas == 1
        assert fleet.scale_up() == "replica-1"
        assert fleet.scale_up() == "replica-2"
        assert fleet.scale_up() is None          # at max
        out = fleet.classify(_iq(12, seed=5), timeout=30.0)
        assert out.shape == (12,)
        assert fleet.scale_down() == "replica-2"  # youngest first
        assert fleet.n_replicas == 2
        # retired replicas keep counting in the merged fleet stats
        assert fleet.stats.requests == 12
        assert fleet.export_stats()["retired"] == ["replica-2"]
        out = fleet.classify(_iq(4, seed=6), timeout=30.0)
        assert out.shape == (4,)
    finally:
        fleet.close()


def test_scale_up_replays_deploy_lineage(weights):
    params, masks = weights
    fleet = FleetRouter(_factory(weights), replicas=1, max_replicas=2)
    try:
        fleet.bind_version("v2", params, masks=masks, backend="dense",
                           warmup=False)
        fleet.swap_to("v2")
        name = fleet.scale_up()
        assert name is not None
        late = next(r.engine for r in fleet._snapshot() if r.name == name)
        # the late joiner serves the same version table and primary
        assert sorted(late.versions()) == ["default", "v2"]
        assert late.active_version == "v2"
        assert fleet.active_version == "v2"
    finally:
        fleet.close()


def test_fleet_wide_hot_swap_zero_failures(weights):
    """deploy.hot_swap on a 2-replica fleet: drains, flips everywhere."""
    params, masks = weights
    fleet = FleetRouter(_factory(weights), replicas=2)
    try:
        report = hot_swap(fleet, params, masks, label="v2",
                          backend="dense", warmup=False)
        assert report.drained
        assert report.old_label == "default" and report.new_label == "v2"
        for rep in fleet._snapshot():
            assert rep.engine.active_version == "v2"
        out = fleet.classify(_iq(8, seed=9), timeout=30.0)
        assert out.shape == (8,)
        stats = fleet.version_stats()
        assert stats["v2"].requests == 8
    finally:
        fleet.close()


def test_merge_stats_counters_and_window():
    a, b = ServeStats(backend="dense"), ServeStats(backend="dense")
    a.requests, b.requests = 3, 5
    a.batches, b.batches = 1, 2
    a.wall_s, b.wall_s = 0.5, 2.0
    a.record_latencies([0.010, 0.020])
    b.record_latencies([0.030])
    m = merge_stats([a, b])
    assert m.requests == 8 and m.batches == 3
    assert m.wall_s == 2.0                  # widest window, not the sum
    assert sorted(m.latencies_s) == [0.010, 0.020, 0.030]


def test_merge_stats_fair_window():
    """Full per-replica windows must merge fairly, not last-writer-wins.

    A slow replica and a fast replica each carry a full MAX_SAMPLES
    history.  Concatenate-then-trim would keep only the final replica's
    window, so the merged p99 would be whichever replica happened to be
    listed last.  The fair merge keeps an equal share of each, and the
    slow replica's tail must survive regardless of merge order.
    """
    cap = ServeStats.MAX_SAMPLES
    slow, fast = ServeStats(backend="dense"), ServeStats(backend="dense")
    slow.record_latencies([1.0] * cap)      # 1000 ms each
    fast.record_latencies([0.001] * cap)    # 1 ms each
    for order in ([slow, fast], [fast, slow]):
        m = merge_stats(order)
        assert len(m.latencies_s) <= cap
        lat = np.asarray(m.latencies_s)
        # both replicas contribute an equal share of the merged window
        assert np.isclose((lat == 1.0).mean(), 0.5)
        assert float(np.percentile(lat * 1e3, 99)) > 500.0

    # queue depths get the same treatment (and stay ints)
    slow.queue_depths = [9] * cap
    fast.queue_depths = [1] * cap
    m = merge_stats([fast, slow])
    assert set(m.queue_depths) == {1, 9}
    assert all(isinstance(d, int) for d in m.queue_depths)


def test_replica_bounds_validated(weights):
    with pytest.raises(ValueError):
        FleetRouter(_factory(weights), replicas=5, max_replicas=2)
    with pytest.raises(ValueError):
        FleetRouter(_factory(weights), replicas=1, min_replicas=0)


# ---------------------------------------------------------------------------
# autoscaler control law (fake fleet + fake clock: fully deterministic)
# ---------------------------------------------------------------------------

class FakeFleet:
    def __init__(self, clock):
        self.clock = clock
        self.n = 1
        self.max = 4
        self.min = 1
        self.p99_ms = 0.0
        self.queue_depth = 0
        self.busy_s = 0.0
        self.shed = 0
        self.expired = 0

    def signals(self):
        return {
            "t": self.clock(), "n_replicas": self.n,
            "queue_depth": self.queue_depth, "p99_ms": self.p99_ms,
            "requests": 0, "busy_s": self.busy_s, "workers": self.n,
            "shed": self.shed, "expired": self.expired, "rejected": 0,
        }

    def scale_up(self):
        if self.n >= self.max:
            return None
        self.n += 1
        return f"replica-{self.n - 1}"

    def scale_down(self):
        if self.n <= self.min:
            return None
        self.n -= 1
        return f"replica-{self.n}"


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _scaler(fleet, clock, **kw):
    kw.setdefault("target_p99_ms", 100.0)
    kw.setdefault("up_patience", 1)
    kw.setdefault("down_patience", 2)
    kw.setdefault("cooldown_ticks", 1)
    return Autoscaler(fleet, clock=clock, **kw)


def test_autoscaler_scales_up_on_p99_breach():
    clock = FakeClock()
    fleet = FakeFleet(clock)
    scaler = _scaler(fleet, clock)
    fleet.p99_ms = 250.0
    clock.advance(0.5)
    tick = scaler.step()
    assert tick.action == "scale-up" and fleet.n == 2
    assert "p99" in tick.reason


def test_autoscaler_scales_up_on_shedding_even_with_low_p99():
    clock = FakeClock()
    fleet = FakeFleet(clock)
    scaler = _scaler(fleet, clock)
    scaler.step()                       # baseline tick (deltas need one)
    fleet.p99_ms = 10.0
    fleet.shed = 7
    clock.advance(0.5)
    tick = scaler.step()
    assert tick.action == "scale-up" and tick.shed_delta == 7
    assert "shed" in tick.reason


def test_autoscaler_cooldown_blocks_consecutive_actions():
    clock = FakeClock()
    fleet = FakeFleet(clock)
    scaler = _scaler(fleet, clock, cooldown_ticks=2)
    fleet.p99_ms = 500.0
    for _ in range(4):
        clock.advance(0.5)
        scaler.step()
    actions = [t.action for t in scaler.trace]
    # breach every tick, but cooldown spaces the scale-ups 2 ticks apart
    assert actions == ["scale-up", "hold", "hold", "scale-up"]
    assert fleet.n == 3


def test_autoscaler_scales_down_after_patience_and_clamps_at_min():
    clock = FakeClock()
    fleet = FakeFleet(clock)
    fleet.n = 3
    scaler = _scaler(fleet, clock, down_patience=2, cooldown_ticks=0)
    fleet.p99_ms = 1.0                  # well under down_ratio * target
    ticks = []
    for _ in range(8):
        clock.advance(0.5)
        ticks.append(scaler.step())
    assert fleet.n == 1                 # never below min_replicas
    downs = [t for t in ticks if t.action == "scale-down"]
    assert len(downs) == 2
    # patience: the very first idle tick must not have acted
    assert ticks[0].action == "hold"
    assert ticks[-1].reason == "idle (at min replicas)"


def test_autoscaler_utilization_is_windowed():
    clock = FakeClock()
    fleet = FakeFleet(clock)
    fleet.n = 2
    scaler = _scaler(fleet, clock, target_p99_ms=1e9,
                     high_utilization=0.8, down_patience=10**6)
    scaler.step()
    # 0.9s of busy work across 2 workers in a 0.5s window -> util 0.9
    fleet.busy_s += 0.9
    clock.advance(0.5)
    tick = scaler.step()
    assert tick.utilization == pytest.approx(0.9, abs=1e-6)
    assert tick.action == "scale-up" and "util" in tick.reason


def test_autoscaler_holds_at_max_replicas():
    clock = FakeClock()
    fleet = FakeFleet(clock)
    fleet.n = fleet.max
    scaler = _scaler(fleet, clock, cooldown_ticks=0)
    fleet.p99_ms = 500.0
    clock.advance(0.5)
    tick = scaler.step()
    assert tick.action == "hold" and "at max replicas" in tick.reason
    assert fleet.n == fleet.max


def test_autoscaler_live_scale_up_lowers_latency(weights):
    """End-to-end: a real paced fleet under backlog; one control tick
    adds a replica and the next backlog clears measurably faster."""
    fleet = FleetRouter(_factory(weights, pace_ms=30.0, max_delay_ms=2),
                        replicas=1, max_replicas=2)
    scaler = Autoscaler(fleet, target_p99_ms=50.0, up_patience=1,
                        cooldown_ticks=0)
    try:
        frames = _iq(32, seed=11)

        def drain_time(n):
            t0 = time.perf_counter()
            futures = [fleet.submit(frames[i]) for i in range(n)]
            for f in futures:
                f.result(timeout=60.0)
            return time.perf_counter() - t0

        t_one = drain_time(32)          # 8 paced batches on one replica
        tick = scaler.step()            # p99 breach observed -> scale up
        assert tick.action == "scale-up"
        assert fleet.n_replicas == 2
        t_two = drain_time(32)          # 4 paced batches per replica
        assert t_two < t_one * 0.8, (
            f"2 replicas not faster: {t_one:.3f}s -> {t_two:.3f}s")
    finally:
        fleet.close()
