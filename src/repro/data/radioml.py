"""Synthetic RadioML 2016.10A-equivalent dataset (paper §IV-A).

The original dataset [13] is generated with GNU Radio: 11 modulation schemes
(8 digital, 3 analog), 128-sample complex baseband frames, AWGN SNRs from
-20 to 18 dB in 2 dB steps.  It is not redistributable here, so we implement
the generator: proper constellation mapping + root-raised-cosine pulse
shaping for linear digital schemes, Gaussian/continuous-phase frequency
modulation for (G/CP)FSK, an audio-like AR source for the analog schemes,
and a channel with AWGN, random carrier frequency/phase offset and timing
jitter — the same impairment family GNU Radio's dynamic channel model
applies.

All generation is vectorized numpy on the host; every sample is
deterministic in (seed, index).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = [
    "MODULATIONS",
    "N_CLASSES",
    "SNR_GRID",
    "generate_sample",
    "generate_batch",
    "RadioMLDataset",
]

MODULATIONS = (
    "BPSK", "QPSK", "8PSK", "PAM4", "QAM16", "QAM64", "GFSK", "CPFSK",  # digital
    "WBFM", "AM-DSB", "AM-SSB",                                         # analog
)
N_CLASSES = len(MODULATIONS)
SNR_GRID = tuple(range(-20, 20, 2))

FRAME_LEN = 128
SPS = 8  # samples per symbol for linear digital modulations


# ---------------------------------------------------------------------------
# Pulse shaping
# ---------------------------------------------------------------------------

def _rrc_taps(beta: float = 0.35, span: int = 8, sps: int = SPS) -> np.ndarray:
    """Root-raised-cosine filter taps."""
    n = span * sps
    t = (np.arange(-n // 2, n // 2 + 1)) / sps
    taps = np.zeros_like(t)
    for i, ti in enumerate(t):
        if abs(ti) < 1e-9:
            taps[i] = 1.0 - beta + 4 * beta / np.pi
        elif abs(abs(4 * beta * ti) - 1.0) < 1e-9:
            taps[i] = (beta / np.sqrt(2)) * (
                (1 + 2 / np.pi) * np.sin(np.pi / (4 * beta))
                + (1 - 2 / np.pi) * np.cos(np.pi / (4 * beta))
            )
        else:
            num = np.sin(np.pi * ti * (1 - beta)) + 4 * beta * ti * np.cos(np.pi * ti * (1 + beta))
            den = np.pi * ti * (1 - (4 * beta * ti) ** 2)
            taps[i] = num / den
    return taps / np.sqrt(np.sum(taps**2))


_RRC = _rrc_taps()

_GAUSS_BT = 0.35


def _gaussian_taps(bt: float = _GAUSS_BT, span: int = 4, sps: int = SPS) -> np.ndarray:
    t = np.arange(-span * sps // 2, span * sps // 2 + 1) / sps
    sigma = np.sqrt(np.log(2)) / (2 * np.pi * bt)
    taps = np.exp(-(t**2) / (2 * sigma**2))
    return taps / taps.sum()


_GAUSS = _gaussian_taps()

# ---------------------------------------------------------------------------
# Constellations
# ---------------------------------------------------------------------------

def _psk_points(m: int) -> np.ndarray:
    k = np.arange(m)
    return np.exp(1j * (2 * np.pi * k / m + np.pi / m))


def _qam_points(m: int) -> np.ndarray:
    side = int(np.sqrt(m))
    re, im = np.meshgrid(np.arange(side), np.arange(side))
    pts = (2 * re - side + 1) + 1j * (2 * im - side + 1)
    pts = pts.ravel()
    return pts / np.sqrt((np.abs(pts) ** 2).mean())


def _pam_points(m: int) -> np.ndarray:
    pts = 2 * np.arange(m) - m + 1
    return (pts / np.sqrt((pts**2).mean())).astype(complex)


_CONSTELLATIONS = {
    "BPSK": _psk_points(2),
    "QPSK": _psk_points(4),
    "8PSK": _psk_points(8),
    "PAM4": _pam_points(4),
    "QAM16": _qam_points(16),
    "QAM64": _qam_points(64),
}

# ---------------------------------------------------------------------------
# Sources
# ---------------------------------------------------------------------------

def _audio_like(rng: np.random.Generator, n: int) -> np.ndarray:
    """Speech-like lowpass AR(2) source, normalized to unit peak."""
    w = rng.normal(size=n + 64)
    x = np.zeros_like(w)
    a1, a2 = 1.6, -0.72  # poles well inside unit circle, lowpass
    for i in range(2, len(w)):
        x[i] = w[i] + a1 * x[i - 1] + a2 * x[i - 2]
    x = x[64:]
    return x / (np.max(np.abs(x)) + 1e-9)


def _modulate_linear(rng: np.random.Generator, scheme: str, n: int) -> np.ndarray:
    const = _CONSTELLATIONS[scheme]
    n_sym = n // SPS + len(_RRC) // SPS + 4
    syms = const[rng.integers(0, len(const), n_sym)]
    up = np.zeros(n_sym * SPS, dtype=complex)
    up[::SPS] = syms
    shaped = np.convolve(up, _RRC, mode="same")
    start = len(_RRC) // 2
    return shaped[start : start + n]


def _modulate_fsk(rng: np.random.Generator, scheme: str, n: int) -> np.ndarray:
    n_sym = n // SPS + 8
    bits = rng.integers(0, 2, n_sym) * 2.0 - 1.0
    freq = np.repeat(bits, SPS)
    if scheme == "GFSK":
        freq = np.convolve(freq, _GAUSS, mode="same")
    h = 0.5  # modulation index
    phase = np.cumsum(freq) * np.pi * h / SPS
    sig = np.exp(1j * phase)
    return sig[:n]


def _modulate_analog(rng: np.random.Generator, scheme: str, n: int) -> np.ndarray:
    x = _audio_like(rng, n)
    if scheme == "WBFM":
        kf = 0.4
        phase = 2 * np.pi * kf * np.cumsum(x)
        return np.exp(1j * phase)
    if scheme == "AM-DSB":
        m = 0.8
        return (1.0 + m * x).astype(complex)
    if scheme == "AM-SSB":
        # upper sideband via discrete Hilbert transform
        X = np.fft.fft(x)
        h = np.zeros(n)
        h[0] = 1
        if n % 2 == 0:
            h[n // 2] = 1
            h[1 : n // 2] = 2
        else:
            h[1 : (n + 1) // 2] = 2
        analytic = np.fft.ifft(X * h)
        return analytic
    raise ValueError(scheme)


# ---------------------------------------------------------------------------
# Channel
# ---------------------------------------------------------------------------

def _apply_channel(
    rng: np.random.Generator, sig: np.ndarray, snr_db: float,
    max_cfo: float = 0.01, phase_noise: bool = True,
) -> np.ndarray:
    n = len(sig)
    # random carrier frequency + phase offset
    cfo = rng.uniform(-max_cfo, max_cfo)
    phi0 = rng.uniform(0, 2 * np.pi)
    sig = sig * np.exp(1j * (2 * np.pi * cfo * np.arange(n) + phi0))
    if phase_noise:
        pn = np.cumsum(rng.normal(scale=2e-3, size=n))
        sig = sig * np.exp(1j * pn)
    # normalize signal power then add AWGN at requested SNR
    p_sig = np.mean(np.abs(sig) ** 2) + 1e-12
    sig = sig / np.sqrt(p_sig)
    p_noise = 10 ** (-snr_db / 10)
    noise = (rng.normal(size=n) + 1j * rng.normal(size=n)) * np.sqrt(p_noise / 2)
    return sig + noise


def generate_sample(
    seed: int, modulation: str, snr_db: float, frame_len: int = FRAME_LEN
) -> np.ndarray:
    """One (2, frame_len) float32 I/Q frame, deterministic in seed."""
    rng = np.random.default_rng(seed)
    if modulation in _CONSTELLATIONS:
        sig = _modulate_linear(rng, modulation, frame_len)
    elif modulation in ("GFSK", "CPFSK"):
        sig = _modulate_fsk(rng, modulation, frame_len)
    else:
        sig = _modulate_analog(rng, modulation, frame_len)
    sig = _apply_channel(rng, sig, snr_db)
    out = np.stack([sig.real, sig.imag]).astype(np.float32)
    # match RadioML's roughly unit-energy frames
    return out / (np.sqrt(np.mean(out**2)) * np.sqrt(2) + 1e-9)


def generate_batch(
    seed: int,
    batch: int,
    snr_db: Optional[float] = None,
    classes: Optional[Tuple[int, ...]] = None,
    frame_len: int = FRAME_LEN,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (iq (B, 2, L) f32, labels (B,) i32, snrs (B,) f32)."""
    rng = np.random.default_rng(seed)
    cls_pool = np.asarray(classes if classes is not None else range(N_CLASSES))
    labels = cls_pool[rng.integers(0, len(cls_pool), batch)]
    snrs = (
        np.full(batch, snr_db, dtype=np.float32)
        if snr_db is not None
        else np.asarray(rng.choice(SNR_GRID, batch), dtype=np.float32)
    )
    iq = np.stack([
        generate_sample(int(seed * 1_000_003 + i), MODULATIONS[labels[i]], float(snrs[i]), frame_len)
        for i in range(batch)
    ])
    return iq.astype(np.float32), labels.astype(np.int32), snrs


@dataclasses.dataclass
class RadioMLDataset:
    """Deterministic infinite stream of (iq, label, snr) batches."""

    batch_size: int
    seed: int = 0
    snr_db: Optional[float] = None  # None -> uniform over the SNR grid
    frame_len: int = FRAME_LEN

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield generate_batch(
                self.seed + step, self.batch_size, self.snr_db, frame_len=self.frame_len
            )
            step += 1
