"""Serving: batched streaming AMC inference engine."""

from .engine import AMCServeEngine, ServeStats
