"""Precomputed execution plans (paper §III-C.4 made structural).

The accelerator's defining trick is that *everything derivable from the
fixed kernels is derived offline*: COO layouts, iteration schedules,
empty/extra slots — the streaming pipeline executes with zero dynamic
control flow.  This package is the software analogue:

* :func:`compile_plan` precomputes every layer's bind-time artifacts (COO
  kernels, Algorithm-2 schedules, block-sparse tilings, cost-model priors)
  once into an immutable :class:`ExecutionPlan`, content-hashed on
  (config, weight bytes, mask bytes) with an on-disk cache so repeated
  binds — trainer eval loops, serve-engine restarts — are near-free;
* :func:`run_streaming` threads **all** layers' membrane states through a
  single ``lax.scan`` over timesteps (the jax analogue of the paper's
  fused inter-layer pipeline), numerically equal to the layer-by-layer
  path for every backend;
* plans support heterogeneous per-layer backend ``assignment`` maps
  (e.g. ``{"conv1": "pallas", "fc1": "dense"}``), which the serving
  tier's per-layer autotuner produces.
"""
from repro.plan.cache import PlanCache, default_cache, set_default_cache
from repro.plan.compile import (
    ExecutionPlan,
    LayerPlan,
    artifact_build_count,
    compile_plan,
)
from repro.plan.streaming import init_stream_states, run_streaming

__all__ = [
    "PlanCache",
    "default_cache",
    "set_default_cache",
    "ExecutionPlan",
    "LayerPlan",
    "artifact_build_count",
    "compile_plan",
    "init_stream_states",
    "run_streaming",
]
