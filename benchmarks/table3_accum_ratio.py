"""Paper Table III: accumulation-count ratio vs spatial sparsity, L1-L4.

Method (matching the paper): stream the same Σ-Δ-encoded RadioML frames
through the network; per layer, count GOAP accumulations with the kernel
pruned to each density and report the ratio to the dense count.  The paper
finds the ratio tracks (1 - sparsity) within ~1% — spatial sparsity
converts one-for-one into skipped accumulations because enable-map length
is independent of which weights survive.

Layer 5 (FC2, 128x11) is excluded as in the paper: its tiny dimension
makes per-run variability dominate.
"""
from __future__ import annotations

import numpy as np

import jax

from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.core.saocds import max_pool_spikes, pad_same, saocds_conv_layer
from repro.core.sparse_format import coo_from_dense
from repro.data.pipeline import sigma_delta_encode_np
from repro.data.radioml import generate_batch
from repro.models.snn import init_snn

import jax.numpy as jnp

NAME = "table3_accum_ratio"

SPARSITIES = (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)
PAPER_TABLE3 = {  # sparsity -> ratios for L1..L4 (percent)
    0.0: (100.00, 100.00, 100.00, 100.00),
    0.1: (89.70, 89.73, 89.74, 90.00),
    0.2: (79.83, 79.96, 79.95, 79.96),
    0.3: (69.80, 69.65, 70.02, 70.13),
    0.4: (59.87, 59.92, 59.77, 59.85),
    0.5: (49.85, 49.91, 49.80, 50.10),
    0.6: (39.74, 39.03, 40.19, 40.14),
    0.7: (29.80, 30.39, 29.47, 29.97),
    0.8: (20.01, 19.72, 20.02, 20.07),
    0.9: (9.89, 9.44, 10.14, 9.79),
}


def _window_sums(frames: np.ndarray, kw: int) -> np.ndarray:
    """frames (N, IC, WIpad) -> P[N, IC, KW] where P[n, ic, ci] = number of
    ones in the enable map of a weight at (ic, ci)."""
    n, ic, wip = frames.shape
    oi = wip - kw + 1
    cs = np.concatenate(
        [np.zeros((n, ic, 1), frames.dtype), np.cumsum(frames, axis=2)], axis=2
    )
    return np.stack([cs[:, :, ci + oi] - cs[:, :, ci] for ci in range(kw)], axis=2)


def _prune_mask(w: np.ndarray, sparsity: float, rng) -> np.ndarray:
    """L1-magnitude pruning mask at the requested sparsity."""
    flat = np.abs(w).ravel()
    k = int(round(sparsity * flat.size))
    if k == 0:
        return np.ones_like(w, dtype=bool)
    thresh = np.partition(flat, k - 1)[k - 1]
    keep = np.abs(w) > thresh
    # break ties deterministically to hit the exact count
    n_extra = keep.sum() - (flat.size - k)
    return keep


def run(n_samples: int = 16, seed: int = 0) -> dict:
    cfg = SNN_CONFIG
    params = init_snn(jax.random.PRNGKey(seed), cfg)
    iq, _, _ = generate_batch(seed, n_samples, snr_db=10.0)
    frames = sigma_delta_encode_np(iq, cfg.osr if hasattr(cfg, "osr") else cfg.timesteps)
    # flatten (B, T) into a stream of (IC, W) frames, propagate DENSE
    stream = frames.reshape(-1, *frames.shape[2:]).astype(np.float32)

    rng = np.random.default_rng(seed)
    layer_inputs = []        # per conv layer: padded input frames (N, IC, WIpad)
    x = jnp.asarray(stream)
    for li, layer in enumerate(params["conv"]):
        kw = layer["w"].shape[0]
        padded = np.asarray(pad_same(x, kw))
        layer_inputs.append(padded)
        coo = coo_from_dense(np.asarray(layer["w"]))
        out, _ = saocds_conv_layer(jnp.asarray(padded), coo, layer["lif"])
        x = max_pool_spikes(out, cfg.pool)
    fc_input = np.asarray(x.reshape(x.shape[0], -1))  # (N, 1024)

    ratios = {s: [] for s in SPARSITIES}
    for li, layer in enumerate(params["conv"]):
        w = np.asarray(layer["w"])
        kw = w.shape[0]
        # em[ic, ci] = total ones inside the enable map of a weight at
        # (ic, ci), summed over the whole frame stream
        em = _window_sums(layer_inputs[li], kw).sum(axis=0)   # (IC, KW)
        dense = float(em.sum() * w.shape[2])                  # every slot x OC
        for s in SPARSITIES:
            keep = _prune_mask(w, s, rng)                     # (KW, IC, OC)
            accum = float((keep * em.T[:, :, None]).sum())
            ratios[s].append(accum / dense)

    # L4 = FC1 with the weight-mask method: accum = sum over active inputs
    # of surviving weights in their rows
    w_fc = np.asarray(params["fc"][0]["w"])          # (1024, 128)
    act_counts = fc_input.sum(axis=0)                 # per-input activations
    dense_fc = float((act_counts[:, None] * np.ones_like(w_fc)).sum())
    for s in SPARSITIES:
        keep = _prune_mask(w_fc, s, rng)
        accum = float((act_counts[:, None] * keep).sum())
        ratios[s].append(accum / dense_fc)

    rows = []
    for s in SPARSITIES:
        got = [r * 100 for r in ratios[s]]
        paper = PAPER_TABLE3[s]
        rows.append({
            "sparsity": s,
            "ratios_pct": got,
            "paper_pct": paper,
            "max_err_vs_linear": max(abs(g - (1 - s) * 100) for g in got),
        })
    return {"rows": rows, "n_frames": int(stream.shape[0])}


def format_table(res: dict) -> str:
    lines = [
        f"Table III — accumulation ratio vs spatial sparsity "
        f"({res['n_frames']} frames; paper row in [])",
        f"  {'sparsity':>8s} {'L1':>7s} {'L2':>7s} {'L3':>7s} {'L4':>7s}"
        f"   {'max |err| vs (1-s)':>18s}",
    ]
    for r in res["rows"]:
        got = "".join(f"{g:7.2f}" for g in r["ratios_pct"])
        pap = "/".join(f"{p:.1f}" for p in r["paper_pct"])
        lines.append(f"  {r['sparsity']:8.1f}{got}   "
                     f"{r['max_err_vs_linear']:6.2f}%   [{pap}]")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
