"""Golden-counter regression: the stream backend's Tables I/III numbers.

The ``stream`` backend interprets the precomputed Algorithm-2 schedule and
returns the compute/extra/empty iteration counts the paper reports in
Tables I and III.  Everything here is deterministic — paper config
(``configs/saocds_amc.py``), seeded init, magnitude masks at 50% density,
seeded input frames — so the totals are pinned to literal values: any
change to the COO sort order, the schedule builder, the mask rule, or the
interpreter that shifts these numbers (and hence the paper-table
reproductions) fails loudly instead of drifting silently.

Regenerate after an *intentional* semantic change with:

    PYTHONPATH=src python tests/test_stream_golden.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.api import compile_snn, init_snn, stream_totals
from repro.configs.saocds_amc import CONFIG
from repro.train.pruning import make_mask_pytree

DENSITY = 0.5

# Per-layer static schedule geometry (input-independent: fixed by the
# masked weights alone) and the gated accumulation counts for the seeded
# input below.  nnz at 50%: conv1 11*2*16/2 = 176, conv2 11*16*32/2 =
# 2816, conv3 5*32*64/2 = 5120 (+1 empty stall slot while I[1] streams in).
GOLDEN_LAYERS = {
    "conv1": {"reps_per_timestep": 176, "compute_iters": 176,
              "extra_iters": 0, "empty_iters": 0, "accumulations": 88895},
    "conv2": {"reps_per_timestep": 2816, "compute_iters": 2816,
              "extra_iters": 0, "empty_iters": 0, "accumulations": 437602},
    "conv3": {"reps_per_timestep": 5121, "compute_iters": 5120,
              "extra_iters": 0, "empty_iters": 1, "accumulations": 263433},
}
GOLDEN_TOTALS = {"compute_iters": 8112, "extra_iters": 0, "empty_iters": 1,
                 "reps_per_timestep": 8113, "accumulations": 789930}


def _setup():
    program = compile_snn(CONFIG)
    params = init_snn(jax.random.PRNGKey(0), CONFIG)
    masks = make_mask_pytree(params, DENSITY)
    rng = np.random.default_rng(0)
    frames = jnp.asarray(
        (rng.random((CONFIG.timesteps, CONFIG.conv_specs[0][1],
                     CONFIG.input_width)) < 0.5).astype(np.float32))
    return program, params, masks, frames


def _run():
    program, params, masks, frames = _setup()
    _, counters = program.apply(params, frames, "stream", masks=masks,
                                return_counters=True)
    return counters


def _assert_golden(counters):
    assert set(counters) == set(GOLDEN_LAYERS)
    for name, golden in GOLDEN_LAYERS.items():
        got = counters[name]
        assert int(np.asarray(got["timesteps"])) == CONFIG.timesteps
        for key, want in golden.items():
            assert int(np.asarray(got[key])) == want, (
                f"{name}.{key}: got {int(np.asarray(got[key]))}, "
                f"golden {want} — Tables I/III reproduction drifted")
    totals = stream_totals(counters)
    for key, want in GOLDEN_TOTALS.items():
        assert int(np.asarray(totals[key])) == want
    # schedule invariant: every slot is exactly one of the three kinds
    assert (GOLDEN_TOTALS["compute_iters"] + GOLDEN_TOTALS["extra_iters"]
            + GOLDEN_TOTALS["empty_iters"]
            == GOLDEN_TOTALS["reps_per_timestep"])


def test_stream_counters_match_golden_paper_config():
    _assert_golden(_run())


def test_stream_counters_match_golden_through_fused_plan():
    """The fused single-scan executor must reproduce the exact same
    Tables I/III counters as the layer-by-layer path."""
    from repro.api import compile_plan
    from repro.plan import PlanCache

    program, params, masks, frames = _setup()
    plan = compile_plan(program, params, masks=masks, assignment="stream",
                        cache=PlanCache(disk_dir=""))
    _, counters = plan.run_streaming(frames)
    _assert_golden(counters)


def test_stream_counters_match_golden_with_quantized_weights():
    """16-bit weight quantization must not move the Table I schedule.

    The Algorithm-2 schedule is built from the *positions* of surviving
    weights, never their magnitudes; every weight the 50%-density mask
    keeps has |w| at or above the layer median, orders of magnitude above
    the LSQ step, so fake-quant rounds none of them to zero.  If
    quantization ever perturbed nnz — and with it reps/compute/empty —
    the paper-table reproduction would silently depend on weight values.

    Accumulation counts are pinned only for conv1 (its input is the fixed
    seeded frame): downstream layers see quantization-perturbed spike
    trains, so their gated-accumulation totals legitimately shift by the
    activity delta — bounded here to <1% of the float goldens.
    """
    from repro.train.lsq import init_lsq_scales, make_serving_quant_fn

    program, params, masks, frames = _setup()
    quant_fn = make_serving_quant_fn(init_lsq_scales(params, 16), 16)
    _, counters = program.apply(params, frames, "stream", masks=masks,
                                quant_fn=quant_fn, return_counters=True)
    assert set(counters) == set(GOLDEN_LAYERS)
    schedule_keys = ("reps_per_timestep", "compute_iters", "extra_iters",
                     "empty_iters")
    for name, golden in GOLDEN_LAYERS.items():
        got = counters[name]
        for key in schedule_keys:
            assert int(np.asarray(got[key])) == golden[key], (
                f"{name}.{key}: quantization moved the static schedule "
                f"({int(np.asarray(got[key]))} != {golden[key]})")
        drift = abs(int(np.asarray(got["accumulations"]))
                    - golden["accumulations"])
        if name == "conv1":
            assert drift == 0
        else:
            assert drift <= 0.01 * golden["accumulations"], (
                f"{name}: accumulation count drifted {drift} "
                f"(> 1% of {golden['accumulations']})")


if __name__ == "__main__":  # regeneration helper
    for name, c in _run().items():
        print(name, {k: int(np.asarray(v)) for k, v in c.items()})
