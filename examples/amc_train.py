"""End-to-end AMC driver: train the paper's 5-layer SNN for a few hundred
steps with the full recipe — Σ-Δ encoding, surrogate-grad BPTT, joint L1
pruning on the 20/60/20 schedule to the paper's best mixed-density config
(Table V: 25-20-15-20-25), 16-bit LSQ QAT, checkpoints — then evaluate
across SNR and report the compression numbers.

Run:  PYTHONPATH=src python examples/amc_train.py [--steps 300]
"""
import argparse
import tempfile

import numpy as np

from repro.configs.saocds_amc import CONFIG as SNN_CONFIG
from repro.models.snn import density_report
from repro.train.trainer import SNNTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=48)
    args = ap.parse_args()

    # paper Table V best trade-off: per-layer densities 25-20-15-20-25 (%)
    per_layer = {"conv1": 0.25, "conv2": 0.20, "conv3": 0.15,
                 "fc1": 0.20, "fc2": 0.25}
    with tempfile.TemporaryDirectory() as ckpt_dir:
        cfg = TrainerConfig(
            total_steps=args.steps, batch_size=args.batch, lr=2e-3,
            per_layer_density=per_layer, use_lsq=True, quant_bits=16,
            ckpt_dir=ckpt_dir, ckpt_every=100, snr_db=10.0,
        )
        trainer = SNNTrainer(SNN_CONFIG, cfg)
        print(f"training {args.steps} steps (prune 20/60/20 to "
              f"{per_layer}, LSQ 16-bit, ckpt every 100)")
        hist = trainer.run()
        print(f"final train loss {hist['loss'][-1]:.4f} "
              f"acc {hist['acc'][-1]:.3f}")
        print("densities:", {k: round(v, 3) for k, v in
                             density_report(trainer.params, trainer.masks).items()})

        print("accuracy vs SNR (paper Fig. 8 protocol):")
        for snr in (-20, -10, 0, 10, 18):
            acc = trainer.evaluate(n_batches=3, snr_db=float(snr))
            print(f"  {snr:+4d} dB: {acc:.3f}")
        # checkpoint restart proof
        step_before = trainer.step
        trainer2 = SNNTrainer(SNN_CONFIG, cfg)
        assert trainer2.resume() and trainer2.step == step_before
        same = all(
            np.allclose(a, b) for a, b in zip(
                np.asarray(trainer.params["fc"][0]["w"]).ravel()[None],
                np.asarray(trainer2.params["fc"][0]["w"]).ravel()[None]))
        print(f"checkpoint resume at step {trainer2.step}: params match {same}")


if __name__ == "__main__":
    main()
