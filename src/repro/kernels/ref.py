"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the corresponding kernel's contract exactly (same
argument/return shapes, including padding behaviour) so tests can
``assert_allclose`` across shape/dtype sweeps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["goap_conv_block_sparse_ref", "wm_fc_matmul_ref", "lif_update_fused_ref"]


def goap_conv_block_sparse_ref(
    blocks: jax.Array,      # (n_oc_tiles, max_tiles, BO, BK)
    block_cols: jax.Array,  # (n_oc_tiles, max_tiles)
    x: jax.Array,           # (K_padded, OI_padded)
) -> jax.Array:
    """out[r*BO:(r+1)*BO] = sum_t blocks[r, t] @ x[cols[r,t]*BK : +BK]."""
    n_oc_tiles, max_tiles, bo, bk = blocks.shape
    _, oi = x.shape
    xt = x.reshape(-1, bk, oi)  # (n_k_tiles, BK, OI)

    def row(r_blocks, r_cols):
        tiles = xt[r_cols]  # (max_tiles, BK, OI)
        return jnp.einsum(
            "tok,tki->oi", r_blocks, tiles.astype(r_blocks.dtype),
            preferred_element_type=blocks.dtype,
        )

    out = jax.vmap(row)(blocks, block_cols)  # (n_oc_tiles, BO, OI)
    return out.reshape(n_oc_tiles * bo, oi)


def wm_fc_matmul_ref(spikes: jax.Array, weights: jax.Array) -> jax.Array:
    return spikes.astype(weights.dtype) @ weights


def lif_update_fused_ref(currents, v0, alpha, theta, v_th):
    """Matches repro.core.lif dynamics (hardware write-back convention)."""
    def step(v, c):
        v = alpha * v + c
        s = (v > v_th).astype(v.dtype)
        v = v - theta * s
        return v, s

    v_fin, spikes = jax.lax.scan(step, v0, currents)
    return spikes, v_fin
