"""Assigned-architecture configs + shape registry."""

from .registry import (
    ARCH_IDS,
    SHAPES,
    get_config,
    reduced_config,
    all_cells,
    cell_applicable,
)
