"""The paper's 5-layer SNN classifier (Fig. 7, shapes fixed by Table II).

    input (T, 2, 128) binary sigma-delta frames
      Conv1 k=11,  2->16, same pad  + LIF -> MaxPool2
      Conv2 k=11, 16->32, same pad  + LIF -> MaxPool2
      Conv3 k=5,  32->64, same pad  + LIF -> MaxPool2
      FC1   1024 -> 128 (weight-mask method) + LIF
      FC2    128 -> 11
    readout: sum over T of FC2 input currents ("current_sum", default) or
             FC2 LIF spike counts ("spike_count").

Two forward paths:

* ``snn_forward``        — dense/differentiable (training): conv via the
  im2col oracle with an optional pruning mask applied to the weights; LIF
  with surrogate gradients; supports LSQ fake-quantization of weights.
* ``snn_forward_sparse`` — inference: pruned kernels converted to COO, conv
  via the vectorized GOAP path (identical numerics, sparsity-aware
  semantics).  Used by the serving engine and the streaming emulator.

All LIF parameters (alpha, theta, v_th) are trainable: per-channel for conv
layers, per-neuron for FC layers (paper §IV-B).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.goap import conv1d_dense_oracle, goap_conv_nnz
from repro.core.lif import LIFParams, init_lif_params, lif_step
from repro.core.saocds import max_pool_spikes, pad_same
from repro.core.sparse_format import CooKernel, coo_from_dense

__all__ = ["SNNConfig", "init_snn", "snn_forward", "snn_forward_sparse",
           "sparsify_params", "param_count", "density_report"]


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    """Paper model by default; reducible for smoke tests."""

    conv_specs: Tuple[Tuple[int, int, int], ...] = ((11, 2, 16), (11, 16, 32), (5, 32, 64))
    pool: int = 2
    fc_specs: Tuple[Tuple[int, int], ...] = ((1024, 128), (128, 11))
    input_width: int = 128
    timesteps: int = 8           # = sigma-delta OSR
    n_classes: int = 11
    readout: str = "current_sum"  # or "spike_count"
    lif_alpha: float = 0.9
    lif_theta: float = 1.0
    lif_v_th: float = 1.0

    def feature_widths(self) -> List[int]:
        """Spatial width after each conv+pool stage."""
        w = self.input_width
        widths = []
        for _ in self.conv_specs:
            w = w // self.pool
            widths.append(w)
        return widths

    def validate(self) -> "SNNConfig":
        w = self.input_width
        ic = self.conv_specs[0][1]
        for kw, c_in, c_out in self.conv_specs:
            assert c_in == ic, f"conv chain broken: {c_in} != {ic}"
            ic = c_out
            w = w // self.pool
        flat = ic * w
        assert self.fc_specs[0][0] == flat, (
            f"FC1 input {self.fc_specs[0][0]} != flattened conv output {flat}"
        )
        assert self.fc_specs[-1][1] == self.n_classes
        return self


def init_snn(key: jax.Array, cfg: SNNConfig, dtype=jnp.float32) -> Dict[str, Any]:
    """He-style init; params is a plain nested dict pytree."""
    cfg.validate()
    params: Dict[str, Any] = {"conv": [], "fc": []}
    for kw, ic, oc in cfg.conv_specs:
        key, k1 = jax.random.split(key)
        fan_in = kw * ic
        w = jax.random.normal(k1, (kw, ic, oc), dtype) * jnp.sqrt(2.0 / fan_in)
        params["conv"].append({
            "w": w,
            "lif": init_lif_params((oc, 1), cfg.lif_alpha, cfg.lif_theta, cfg.lif_v_th, dtype),
        })
    for i, (din, dout) in enumerate(cfg.fc_specs):
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (din, dout), dtype) * jnp.sqrt(2.0 / din)
        params["fc"].append({
            "w": w,
            "lif": init_lif_params((dout,), cfg.lif_alpha, cfg.lif_theta, cfg.lif_v_th, dtype),
        })
    return params


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


def _masked(w: jax.Array, mask: Optional[jax.Array]) -> jax.Array:
    return w if mask is None else w * mask


def snn_forward(
    params: Dict[str, Any],
    frames: jax.Array,
    cfg: SNNConfig,
    masks: Optional[Dict[str, Any]] = None,
    quant_fn=None,
) -> jax.Array:
    """Dense (training) forward for one sample.

    frames: (T, IC0, W) binary. Returns logits (n_classes,).
    masks: optional pruning masks matching params structure.
    quant_fn: optional fake-quant fn applied to each weight (LSQ).
    """
    x = frames  # (T, C, W)

    def maybe_quant(w):
        return w if quant_fn is None else quant_fn(w)

    for li, layer in enumerate(params["conv"]):
        kw = layer["w"].shape[0]
        w = maybe_quant(_masked(layer["w"], masks["conv"][li] if masks else None))
        padded = pad_same(x, kw)  # (T, C, W + kw - 1)

        def conv_step(v, ifm, w=w, lif=layer["lif"]):
            cur = conv1d_dense_oracle(ifm, w)
            return lif_step(v, cur, lif)

        oc = w.shape[2]
        oi = x.shape[-1]
        v0 = jnp.zeros((oc, oi), dtype=w.dtype)
        _, spikes = jax.lax.scan(conv_step, v0, padded)
        x = max_pool_spikes(spikes, cfg.pool)  # (T, OC, W//pool)

    x = x.reshape(x.shape[0], -1)  # (T, flat)

    logits_acc = jnp.zeros((cfg.n_classes,), dtype=x.dtype)
    for fi, layer in enumerate(params["fc"]):
        w = maybe_quant(_masked(layer["w"], masks["fc"][fi] if masks else None))
        is_last = fi == len(params["fc"]) - 1

        def fc_step(v, s, w=w, lif=layer["lif"]):
            cur = s.astype(w.dtype) @ w
            v_next, out = lif_step(v, cur, lif)
            return v_next, (out, cur)

        v0 = jnp.zeros((w.shape[1],), dtype=w.dtype)
        _, (spikes, currents) = jax.lax.scan(fc_step, v0, x)
        if is_last:
            if cfg.readout == "current_sum":
                logits_acc = currents.sum(axis=0)
            else:
                logits_acc = spikes.sum(axis=0)
        else:
            x = spikes
    return logits_acc


def snn_forward_batch(params, frames_b, cfg, masks=None, quant_fn=None):
    """(B, T, C, W) -> (B, n_classes)."""
    return jax.vmap(lambda f: snn_forward(params, f, cfg, masks, quant_fn))(frames_b)


# ---------------------------------------------------------------------------
# Sparse (inference) path.
# ---------------------------------------------------------------------------

def sparsify_params(params: Dict[str, Any], masks: Optional[Dict[str, Any]] = None):
    """Convert (optionally masked) dense params into the COO inference form."""
    sp = {"conv": [], "fc": []}
    for li, layer in enumerate(params["conv"]):
        w = np.asarray(_masked(layer["w"], masks["conv"][li] if masks else None))
        sp["conv"].append({"coo": coo_from_dense(w), "lif": layer["lif"]})
    for fi, layer in enumerate(params["fc"]):
        w = np.asarray(_masked(layer["w"], masks["fc"][fi] if masks else None))
        sp["fc"].append({"w": jnp.asarray(w), "lif": layer["lif"]})
    return sp


def density_report(params, masks=None) -> Dict[str, float]:
    out = {}
    for li, layer in enumerate(params["conv"]):
        w = np.asarray(_masked(layer["w"], masks["conv"][li] if masks else None))
        out[f"conv{li + 1}"] = float((w != 0).mean())
    for fi, layer in enumerate(params["fc"]):
        w = np.asarray(_masked(layer["w"], masks["fc"][fi] if masks else None))
        out[f"fc{fi + 1}"] = float((w != 0).mean())
    return out


def snn_forward_sparse(sparse_params, frames: jax.Array, cfg: SNNConfig) -> jax.Array:
    """GOAP inference forward for one sample: (T, IC0, W) -> (n_classes,)."""
    x = frames

    for layer in sparse_params["conv"]:
        coo: CooKernel = layer["coo"]
        padded = pad_same(x, coo.kw)

        def conv_step(v, ifm, coo=coo, lif=layer["lif"]):
            cur = goap_conv_nnz(ifm, coo)
            return lif_step(v, cur, lif)

        v0 = jnp.zeros((coo.oc, x.shape[-1]), dtype=jnp.float32)
        _, spikes = jax.lax.scan(conv_step, v0, padded)
        x = max_pool_spikes(spikes, cfg.pool)

    x = x.reshape(x.shape[0], -1)

    logits = jnp.zeros((cfg.n_classes,), dtype=jnp.float32)
    for fi, layer in enumerate(sparse_params["fc"]):
        w = layer["w"]
        is_last = fi == len(sparse_params["fc"]) - 1

        def fc_step(v, s, w=w, lif=layer["lif"]):
            cur = s.astype(w.dtype) @ w
            v_next, out = lif_step(v, cur, lif)
            return v_next, (out, cur)

        v0 = jnp.zeros((w.shape[1],), dtype=w.dtype)
        _, (spikes, currents) = jax.lax.scan(fc_step, v0, x)
        if is_last:
            logits = currents.sum(axis=0) if cfg.readout == "current_sum" else spikes.sum(axis=0)
        else:
            x = spikes
    return logits
