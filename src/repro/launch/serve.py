"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

* ``--arch saocds-amc`` — the paper's deployment mode: a stream of I/Q
  frames is Σ-Δ encoded and classified through the async serving tier
  (``repro.serve.AsyncAMCServeEngine``: request queue -> dynamic
  micro-batcher -> autotuned backend, sharded across local devices),
  reporting throughput, latency percentiles, and the activity counters
  that feed the power model.  ``--engine sync`` runs the legacy per-chunk
  loop instead.
* ``--arch <assigned-lm-id>`` — batched greedy generation on the reduced
  config: one prefill (cache-building) + N decode steps against the
  sharded-layout decode state, reporting tokens/s.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, reduced_config

__all__ = ["generate", "main"]


def generate(cfg, params, prompts: jax.Array, n_new: int):
    """Greedy decode: prompts (B, S) -> (B, S + n_new) tokens."""
    from repro.models.lm import lm_decode_step, lm_prefill

    b, s = prompts.shape
    patch = None
    if cfg.family == "vlm":
        patch = jnp.zeros((b, cfg.n_patches, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(lambda p, t: lm_prefill(p, t, cfg, patch_embeds=patch,
                                              cache_headroom=n_new))
    step = jax.jit(lambda p, st, t: lm_decode_step(p, st, t, cfg))

    def greedy(logits):
        return jnp.argmax(logits[:, -1, : cfg.vocab], axis=-1
                          ).astype(jnp.int32)[:, None]

    logits, states = prefill(params, prompts)
    out = [prompts]
    token = greedy(logits)
    for _ in range(n_new):
        out.append(token)
        logits, states = step(params, states, token)
        token = greedy(logits)
    return jnp.concatenate(out, axis=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True,
                    choices=list(ARCH_IDS) + ["saocds-amc"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64,
                    help="saocds-amc: number of I/Q frames to classify")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--density", type=float, default=0.5)
    ap.add_argument("--engine", choices=["async", "sync"], default="async",
                    help="saocds-amc: async micro-batched tier or the "
                         "legacy per-chunk loop")
    ap.add_argument("--backend", default="auto",
                    help="saocds-amc: execution backend ('dense'/'goap'/"
                         "'pallas'/'stream'/'fixed'), 'auto' to race the "
                         "candidates at bind time, or 'per-layer' to race "
                         "them layer by layer and serve the heterogeneous "
                         "assignment through the fused streaming plan "
                         "(async engine only); 'fixed' serves genuinely "
                         "integer inference (hardware-parity tier)")
    ap.add_argument("--quant-bits", type=int, choices=(8, 16), default=None,
                    help="saocds-amc: weight quantization width for the "
                         "fixed/LSQ serving paths (default: the registry "
                         "version's setting, else 16)")
    ap.add_argument("--max-delay-ms", type=float, default=5.0)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="saocds-amc: serve from a model registry instead "
                         "of fresh random weights")
    ap.add_argument("--model", default="amc", metavar="NAME[@VER|@ALIAS]",
                    help="registry spec to serve (default: 'amc', which "
                         "resolves through the production alias)")
    ap.add_argument("--canary", default=None, metavar="NAME@VER",
                    help="registry spec to bind as a canary next to the "
                         "primary (async engine only)")
    ap.add_argument("--canary-pct", type=float, default=10.0,
                    help="percent of batches routed to the canary")
    args = ap.parse_args(argv)

    if args.arch == "saocds-amc":
        from repro.configs.saocds_amc import CONFIG
        from repro.data.radioml import generate_batch
        from repro.models.snn import init_snn
        from repro.serve import AMCServeEngine, AsyncAMCServeEngine
        from repro.train.pruning import make_mask_pytree

        SNN_CONFIG = CONFIG
        registry = canary_loaded = None
        version_label = "adhoc"
        lsq_scales, quant_bits = None, 16
        if args.registry:
            from repro.deploy import ModelRegistry

            registry = ModelRegistry(args.registry)
            loaded = registry.load(args.model)
            params, masks = loaded.params, loaded.masks
            lsq_scales = loaded.lsq_scales
            quant_bits = loaded.version.quant_bits
            SNN_CONFIG = loaded.cfg
            version_label = loaded.version.spec
            print(f"registry: serving {version_label} "
                  f"(digest {loaded.version.digest[:12]}…)")
            if args.canary:
                if args.engine == "sync":
                    print("--canary requires the async engine "
                          "(--engine async)")
                    return 1
                canary_loaded = registry.load(args.canary)
                if canary_loaded.cfg != SNN_CONFIG:
                    print("canary config differs from the primary's; "
                          "a config change is a redeploy, not a canary")
                    return 1
        else:
            if args.canary:
                print("--canary requires --registry")
                return 1
            params = init_snn(jax.random.PRNGKey(0), SNN_CONFIG)
            masks = make_mask_pytree(params, args.density)
        if args.quant_bits is not None:
            quant_bits = args.quant_bits
        if args.backend == "fixed":
            src = "trained LSQ steps" if lsq_scales is not None else \
                "max-abs calibration"
            print(f"fixed-point tier: {quant_bits}-bit integer inference "
                  f"({src})")
        iq, labels, _ = generate_batch(0, args.requests, snr_db=10.0,
                                       frame_len=SNN_CONFIG.input_width)
        if args.engine == "sync":
            backend = args.backend
            if backend in ("auto", "per-layer"):
                print(f"(sync engine does not support --backend {backend}; "
                      "using goap)")
                backend = "goap"
            engine = AMCServeEngine(params, SNN_CONFIG, masks=masks,
                                    batch_size=args.batch,
                                    count_activity=True, backend=backend,
                                    lsq_scales=lsq_scales,
                                    quant_bits=quant_bits)
            preds = engine.classify(iq)
        else:
            engine = AsyncAMCServeEngine(
                params, SNN_CONFIG, masks=masks, backend=args.backend,
                max_batch=args.batch, max_delay_ms=args.max_delay_ms,
                workers=args.workers, count_activity=True,
                version_label=version_label, lsq_scales=lsq_scales,
                quant_bits=quant_bits)
            if engine.autotune is not None:
                t = ", ".join(f"{k}={v:.1f}ms"
                              for k, v in engine.autotune.timings_ms.items())
                print(f"autotune[{t}] -> {engine.backend}")
            if engine.perlayer is not None:
                a = ", ".join(f"{k}={v}"
                              for k, v in engine.assignment.items())
                print(f"per-layer autotune -> [{a}] (fused streaming plan)")
            if canary_loaded is not None:
                from repro.deploy import canary_router

                clabel = canary_loaded.version.spec
                if clabel == version_label:
                    print(f"canary {clabel} is the primary version; "
                          "skipping the split")
                else:
                    engine.bind_version(
                        clabel, canary_loaded.params, canary_loaded.masks,
                        lsq_scales=canary_loaded.lsq_scales,
                        quant_bits=canary_loaded.version.quant_bits)
                    engine.set_router(canary_router(version_label, clabel,
                                                    args.canary_pct))
                    print(f"canary: {clabel} at {args.canary_pct:.0f}% of "
                          "batches")
            preds = engine.classify(iq)
            for label, vstats in engine.version_stats().items():
                marker = "*" if label == engine.active_version else " "
                print(f"  {marker}{label:24s} backend={vstats.backend:9s} "
                      f"requests={vstats.requests:5d} "
                      f"batches={vstats.batches:4d} "
                      f"p99={vstats.p99_ms:.1f}ms")
            engine.close()
        st = engine.stats
        print(f"requests={st.requests} batches={st.batches} "
              f"backend={st.backend} "
              f"throughput={st.throughput_samples_per_s() / 1e3:.1f} kS/s "
              f"({st.throughput_fps():.0f} frames/s)")
        print(f"latency p50={st.p50_ms:.1f}ms p95={st.p95_ms:.1f}ms "
              f"p99={st.p99_ms:.1f}ms  mean queue depth "
              f"{st.mean_queue_depth():.1f}  padded {st.padded_frames}")
        print(f"activity: accum={st.accumulations} "
              f"fetched_bits={st.fetched_bits}")
        print(f"(untrained net) agreement with labels: "
              f"{float((preds == labels).mean()):.3f}")
        return 0

    from repro.models.lm import init_lm

    cfg = reduced_config(args.arch)
    if cfg.family == "encdec":
        print("whisper serving demo lives in examples/; use --arch of a "
              "decoder-only config here")
        return 1
    params = init_lm(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(
            0, cfg.vocab, size=(args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.perf_counter()
    tokens = generate(cfg, params, prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    n_gen = args.batch * args.new_tokens
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({n_gen / dt:.1f} tok/s incl. compile)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
