"""Model lifecycle subsystem: registry round-trips, zero-downtime hot-swap
under concurrent load, canary routing proportions, monitor auto-rollback /
auto-promote, and the batcher drain barrier.

Tiny reduced config throughout (same as test_serve) so binds stay cheap.
"""
import dataclasses
import tempfile
import threading
import time

import numpy as np
import pytest

import jax

from repro.api import SNNConfig, init_snn
from repro.deploy import (
    CanaryMonitor,
    ModelRegistry,
    MonitorConfig,
    WeightedRouter,
    canary_router,
    hot_swap,
    hot_swap_async,
    hot_swap_from_registry,
    publish_from_checkpoint,
    publish_from_trainer,
)
from repro.serve import AsyncAMCServeEngine, MicroBatcher
from repro.train.pruning import make_mask_pytree

CFG = SNNConfig(
    conv_specs=((3, 2, 4), (3, 4, 8)),
    pool=2,
    fc_specs=((32, 16), (16, 5)),
    input_width=16,
    timesteps=3,
    n_classes=5,
)
FRAME_SHAPE = (2, CFG.input_width)


@pytest.fixture(scope="module")
def models():
    p1 = init_snn(jax.random.PRNGKey(0), CFG)
    p2 = init_snn(jax.random.PRNGKey(1), CFG)
    m1 = make_mask_pytree(p1, 0.5)
    return p1, m1, p2


@pytest.fixture
def registry(tmp_path):
    return ModelRegistry(str(tmp_path / "registry"))


def _iq(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n,) + FRAME_SHAPE).astype(np.float32)


def _permuted_head(params):
    """Injected regression: rolling the last FC's output columns shifts
    every logit by one class, so the canary's argmax disagrees with the
    source model on (nearly) every frame."""
    w = np.roll(np.asarray(params["fc"][1]["w"]), 1, axis=1)
    return {"conv": params["conv"],
            "fc": [params["fc"][0], dict(params["fc"][1], w=w)]}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_publish_load_roundtrip(registry, models):
    p1, m1, _ = models
    v = registry.publish("amc", p1, CFG, masks=m1, assignment="dense",
                         metrics={"acc": 0.9}, alias="production")
    assert v.version == 1 and v.spec == "amc@1"
    assert v.plan_digest  # plan compiled + cache warmed at publish time
    loaded = registry.load("amc@production")
    assert loaded.cfg == CFG
    assert loaded.version.metrics["acc"] == 0.9
    for a, b in zip(jax.tree_util.tree_leaves(loaded.params),
                    jax.tree_util.tree_leaves(p1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(loaded.masks),
                    jax.tree_util.tree_leaves(m1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_registry_content_addressing_dedups(registry, models):
    p1, m1, p2 = models
    v1 = registry.publish("amc", p1, CFG, masks=m1)
    again = registry.publish("amc", p1, CFG, masks=m1)
    assert again.version == v1.version  # identical content -> same version
    v2 = registry.publish("amc", p2, CFG)
    assert v2.version == v1.version + 1
    assert registry.versions("amc") == [1, 2]


def test_registry_aliases_and_resolve(registry, models):
    p1, m1, p2 = models
    registry.publish("amc", p1, CFG, alias="production")
    registry.publish("amc", p2, CFG, alias="staging")
    assert registry.resolve("amc") == ("amc", 1)          # production alias
    assert registry.resolve("amc@staging") == ("amc", 2)
    assert registry.resolve("amc@2") == ("amc", 2)
    assert registry.resolve("amc@v2") == ("amc", 2)
    registry.set_alias("amc", "production", 2)
    assert registry.resolve("amc") == ("amc", 2)
    with pytest.raises(KeyError):
        registry.resolve("amc@nope")
    with pytest.raises(KeyError):
        registry.resolve("amc@7")
    with pytest.raises(KeyError):
        registry.set_alias("amc", "production", 7)
    # version-shaped aliases would shadow resolve()'s numeric forms
    with pytest.raises(ValueError):
        registry.set_alias("amc", "v2", 1)
    with pytest.raises(ValueError):
        registry.set_alias("amc", "2", 1)


def test_registry_resolve_without_alias_uses_latest(registry, models):
    p1, _, p2 = models
    registry.publish("amc", p1, CFG)
    registry.publish("amc", p2, CFG)
    assert registry.resolve("amc") == ("amc", 2)


def test_checkpoint_to_registry_to_serve_roundtrip(registry):
    """The full bridge: train -> checkpoint -> publish -> load -> serve."""
    from repro.train.trainer import SNNTrainer, TrainerConfig

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=4, batch_size=8, seed=0,
                             final_density=0.5, ckpt_dir=ckpt_dir,
                             ckpt_every=2)
        trainer = SNNTrainer(CFG, tcfg)
        trainer.run()
        v = publish_from_checkpoint(registry, "amc", CFG, tcfg,
                                    assignment="dense", alias="production")
        assert v.metrics["source_step"] == trainer.step
        assert v.has_masks
        loaded = registry.load("amc@production")
        for a, b in zip(jax.tree_util.tree_leaves(loaded.params),
                        jax.tree_util.tree_leaves(trainer.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # live-trainer publish of identical state dedups to the same version
        assert publish_from_trainer(registry, "amc",
                                    trainer).version == v.version
    with AsyncAMCServeEngine(loaded.params, loaded.cfg, masks=loaded.masks,
                             backend="dense", max_batch=8, max_delay_ms=2.0,
                             version_label=v.spec) as engine:
        preds = engine.classify(_iq(12))
    assert preds.shape == (12,) and engine.stats.requests == 12


def test_lsq_state_round_trips_to_serving(registry, models):
    """LSQ scales published to the registry must reach the served step:
    the engine's logits match the fake-quant reference, and the plan
    digest differs from the unquantized bind (the quant was applied)."""
    import jax.numpy as jnp

    from repro.api import compile_snn
    from repro.data.pipeline import sigma_delta_encode_np
    from repro.train.lsq import init_lsq_scales, make_serving_quant_fn

    p1, m1, _ = models
    lsq = init_lsq_scales(p1, bits=8)
    v = registry.publish("amc", p1, CFG, masks=m1, lsq_scales=lsq,
                         quant_bits=8, assignment="dense")
    assert v.has_lsq and v.quant_bits == 8
    loaded = registry.load("amc@1")

    iq = _iq(8, seed=3)
    frames = jnp.asarray(sigma_delta_encode_np(iq, CFG.timesteps))
    program = compile_snn(CFG)
    ref = np.asarray(program.apply_batch(
        p1, frames, "dense", masks=m1,
        quant_fn=make_serving_quant_fn(lsq, 8)))
    with AsyncAMCServeEngine(loaded.params, CFG, masks=loaded.masks,
                             backend="dense", max_batch=8,
                             lsq_scales=loaded.lsq_scales,
                             quant_bits=loaded.version.quant_bits) as eng:
        quant_digest = eng.plan.digest
        preds = eng.classify(iq)
    np.testing.assert_array_equal(preds, ref.argmax(-1))
    with AsyncAMCServeEngine(loaded.params, CFG, masks=loaded.masks,
                             backend="dense", max_batch=8) as eng:
        assert eng.plan.digest != quant_digest


# ---------------------------------------------------------------------------
# hot swap
# ---------------------------------------------------------------------------

def test_hot_swap_under_concurrent_load_zero_failures(models):
    """Acceptance bar: live hot-swap with zero dropped/failed requests."""
    p1, m1, p2 = models
    engine = AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                                 max_batch=8, max_delay_ms=1.0,
                                 version_label="v1")
    futures, stop = [], threading.Event()
    lock = threading.Lock()

    def pump(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            f = engine.submit(
                rng.normal(size=FRAME_SHAPE).astype(np.float32))
            with lock:
                futures.append(f)
            # pace the offered load below serving capacity: unpaced tight
            # loops on a 1-core host grow the backlog without bound, and
            # the post-flip drain can then never finish inside its budget
            time.sleep(0.001)

    threads = [threading.Thread(target=pump, args=(i,)) for i in range(3)]
    for t in threads:
        t.start()
    try:
        # ensure in-flight traffic at the flip AND that v1 has actually
        # served (a busy-spin here starves the worker on 1-core hosts,
        # letting the flip land before v1's first batch completes)
        deadline = time.perf_counter() + 60.0
        while ((len(futures) < 64 or engine.stats.requests == 0)
               and time.perf_counter() < deadline):
            time.sleep(0.001)
        assert engine.stats.requests > 0, "v1 never served before the flip"
        report = hot_swap(engine, p2, label="v2", backend="dense",
                          drain_timeout=30.0)
        # keep traffic flowing until the new primary has demonstrably
        # served (the barrier just drained the backlog, so stopping the
        # producers at the flip can leave v2 with zero requests)
        deadline = time.perf_counter() + 60.0
        while (engine.version_stats()["v2"].requests == 0
               and time.perf_counter() < deadline):
            time.sleep(0.001)
    finally:
        stop.set()
        for t in threads:
            t.join()
    results = [f.result(timeout=60.0) for f in futures]  # raises on failure

    assert report.old_label == "v1" and report.new_label == "v2"
    assert report.drained
    assert engine.active_version == "v2"
    assert len(results) == len(futures)
    stats = engine.version_stats()
    # both versions actually served traffic around the flip
    assert stats["v1"].requests > 0 and stats["v2"].requests > 0
    assert stats["v1"].requests + stats["v2"].requests == \
        engine.stats.requests
    engine.close()


def test_hot_swap_changes_served_predictions(models):
    p1, m1, p2 = models
    iq = _iq(16, seed=7)
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="v1") as engine:
        before = engine.classify(iq)
        hot_swap(engine, p2, label="v2", backend="dense")
        after = engine.classify(iq)
        # reference: the new params served directly
    with AsyncAMCServeEngine(p2, CFG, backend="dense", max_batch=8,
                             version_label="ref") as ref_engine:
        ref = ref_engine.classify(iq)
    np.testing.assert_array_equal(after, ref)
    assert before.shape == after.shape


def test_hot_swap_async_and_registry_path(registry, models):
    p1, m1, p2 = models
    registry.publish("amc", p1, CFG, masks=m1, assignment="dense",
                     alias="production")
    registry.publish("amc", p2, CFG, assignment="dense", alias="staging")
    loaded = registry.load("amc@production")
    with AsyncAMCServeEngine(loaded.params, CFG, masks=loaded.masks,
                             backend="dense", max_batch=8,
                             version_label="amc@1") as engine:
        report = hot_swap_from_registry(engine, registry, "amc@staging")
        assert report.new_label == "amc@2"
        assert engine.active_version == "amc@2"
        # async flavor: returns a future resolving to the report
        fut = hot_swap_async(engine, p1, masks=m1, label="v1-again",
                             backend="dense")
        assert fut.result(timeout=60.0).new_label == "v1-again"
        assert engine.active_version == "v1-again"


def test_hot_swap_rejects_duplicate_label_and_config_drift(registry, models):
    p1, m1, p2 = models
    other_cfg = dataclasses.replace(CFG, timesteps=4)
    registry.publish("amc", p2, other_cfg, assignment="dense")
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="v1") as engine:
        with pytest.raises(ValueError, match="already bound"):
            hot_swap(engine, p2, label="v1", backend="dense")
        with pytest.raises(ValueError, match="SNNConfig"):
            hot_swap_from_registry(engine, registry, "amc@1")


def test_remove_and_swap_guards(models):
    p1, m1, p2 = models
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="v1") as engine:
        with pytest.raises(KeyError):
            engine.swap_to("nope")
        with pytest.raises(ValueError, match="primary"):
            engine.remove_version("v1")
        # no autotuned assignment to inherit -> explicit error, not a
        # silent uniform fallback mislabeled "per-layer"
        with pytest.raises(ValueError, match="per-layer"):
            engine.bind_version("v3", p2, backend="per-layer")
        engine.bind_version("v2", p2, backend="dense")
        engine.swap_to("v2")
        engine.remove_version("v1")
        assert set(engine.versions()) == {"v2"}


# ---------------------------------------------------------------------------
# drain barrier
# ---------------------------------------------------------------------------

def test_drain_barrier_waits_for_preexisting_backlog():
    b = MicroBatcher(frame_shape=FRAME_SHAPE, max_batch=4, max_delay_ms=1.0)
    for _ in range(6):
        b.submit(np.zeros(FRAME_SHAPE, np.float32))
    assert not b.drain_barrier(timeout=0.05)  # nothing consumed yet
    assert b.get_batch(timeout=1.0) is not None  # 4 of 6
    assert not b.drain_barrier(timeout=0.05)
    assert b.get_batch(timeout=1.0) is not None  # remaining 2
    assert b.drain_barrier(timeout=1.0)
    # trivially true when nothing is pending
    assert b.drain_barrier(timeout=0.05)


def test_drain_barrier_released_by_close_drain():
    b = MicroBatcher(frame_shape=FRAME_SHAPE, max_batch=4, max_delay_ms=1.0)
    futs = [b.submit(np.zeros(FRAME_SHAPE, np.float32)) for _ in range(3)]
    released = threading.Event()

    def wait():
        if b.drain_barrier(timeout=10.0):
            released.set()

    t = threading.Thread(target=wait)
    t.start()
    b.close()
    drained = b.drain()
    assert len(drained) == 3
    t.join(timeout=5.0)
    assert released.is_set()
    del futs


# ---------------------------------------------------------------------------
# canary routing
# ---------------------------------------------------------------------------

def test_weighted_router_exact_proportions():
    r = WeightedRouter({"a": 75.0, "b": 25.0})
    picks = [r() for _ in range(100)]
    assert picks.count("a") == 75 and picks.count("b") == 25
    # smooth: the 25% label is interleaved, not bursty
    assert all("b" in picks[i:i + 4] for i in range(0, 100, 4))
    assert r.fractions() == {"a": 0.75, "b": 0.25}


def test_canary_router_edges():
    assert canary_router("p", "c", 0.0) is None
    assert canary_router("p", "c", 100.0)() == "c"
    with pytest.raises(ValueError):
        canary_router("p", "c", 150.0)


def test_engine_routes_canary_fraction(models):
    p1, m1, p2 = models
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=4, max_delay_ms=1.0,
                             version_label="prod") as engine:
        engine.bind_version("canary", p2, backend="dense")
        router = canary_router("prod", "canary", 25.0)
        engine.set_router(router)
        engine.classify(_iq(64))
        stats = engine.version_stats()
        assert stats["canary"].batches > 0 and stats["prod"].batches > 0
        total = stats["canary"].batches + stats["prod"].batches
        assert stats["canary"].batches == pytest.approx(0.25 * total,
                                                        abs=1.0)
        # a router naming a missing label degrades to the primary
        engine.set_router(lambda: "gone")
        preds = engine.classify(_iq(8))
        assert preds.shape == (8,)


# ---------------------------------------------------------------------------
# canary monitor
# ---------------------------------------------------------------------------

def _monitor_cfg(**kw):
    base = dict(snr_bins=(0.0, 10.0), frames_per_bin=8, window=3,
                min_rounds=2, promote_after=3, score="agreement")
    base.update(kw)
    return MonitorConfig(**base)


def test_monitor_rolls_back_injected_accuracy_regression(models):
    """Acceptance bar: auto-rollback on a per-SNR accuracy regression."""
    p1, m1, _ = models
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="prod") as engine:
        engine.bind_version("canary", _permuted_head(p1), backend="dense")
        engine.set_router(canary_router("prod", "canary", 25.0))
        mon = CanaryMonitor(engine, baseline="prod", canary="canary",
                            config=_monitor_cfg())
        decision = mon.run(max_rounds=8)
        assert decision == "rollback"
        assert "regression" in mon.reason
        assert "canary" not in engine.versions()     # canary evicted
        assert engine.active_version == "prod"       # production untouched
        assert engine._router is None                # traffic restored
        # post-rollback the engine still serves
        assert engine.classify(_iq(8)).shape == (8,)


def test_monitor_rollback_in_labels_mode(models):
    """Same regression, scored against ground-truth labels: the frame
    source labels frames with production's own predictions (a replay
    buffer distilled from the fleet baseline), so the baseline scores
    1.0 and the permuted canary scores ~0."""
    p1, m1, _ = models
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="prod") as engine:
        engine.bind_version("canary", _permuted_head(p1), backend="dense")
        prod = engine.get_version("prod")

        def source(seed, n, snr):
            rng = np.random.default_rng(seed)
            iq = rng.normal(size=(n,) + FRAME_SHAPE).astype(np.float32)
            import jax.numpy as jnp

            labels = np.asarray(prod.step(jnp.asarray(iq))).argmax(-1)
            return iq, labels

        mon = CanaryMonitor(engine, baseline="prod", canary="canary",
                            config=_monitor_cfg(score="labels"),
                            frame_source=source)
        assert mon.run(max_rounds=8) == "rollback"
        h = mon.history[-1]
        assert all(v == 1.0 for v in h.baseline_acc.values())
        s = mon.summary()
        assert any(s["windowed_canary"][snr]
                   < s["windowed_baseline"][snr] - 0.05
                   for snr in s["windowed_baseline"])


def test_monitor_promotes_clean_canary_and_advances_alias(registry, models):
    p1, m1, _ = models
    registry.publish("amc", p1, CFG, masks=m1, alias="production",
                     assignment="dense")
    # the canary: identical weights, no masks — a distinct registry
    # version whose predictions match the (unmasked) baseline exactly
    p_can = jax.tree_util.tree_map(lambda x: np.asarray(x), p1)
    registry.publish("amc", p_can, CFG, assignment="dense", alias="staging")
    with AsyncAMCServeEngine(p1, CFG, backend="dense",
                             max_batch=8, version_label="amc@1") as engine:
        engine.bind_version("amc@2", p_can, backend="dense")
        engine.set_router(canary_router("amc@1", "amc@2", 25.0))
        mon = CanaryMonitor(engine, baseline="amc@1", canary="amc@2",
                            config=_monitor_cfg(min_rounds=1,
                                                promote_after=2),
                            registry=registry, canary_spec="amc@2")
        assert mon.run(max_rounds=8) == "promote"
        assert engine.active_version == "amc@2"
        assert engine._router is None
    assert registry.resolve("amc") == ("amc", 2)  # production advanced


def test_monitor_rolls_back_latency_regression(models):
    p1, m1, p2 = models
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="prod") as engine:
        engine.bind_version("canary", p2, backend="dense")
        stats = engine.version_stats()
        stats["prod"].record_latencies([0.001] * 64)
        stats["canary"].record_latencies([0.050] * 64)
        mon = CanaryMonitor(
            engine, baseline="prod", canary="canary",
            config=_monitor_cfg(acc_drop_tol=1.1, min_rounds=1,
                                p99_factor=2.0))
        assert mon.run(max_rounds=4) == "rollback"
        assert "latency" in mon.reason


def test_monitor_fails_fast_on_unbound_labels(models):
    p1, m1, _ = models
    with AsyncAMCServeEngine(p1, CFG, masks=m1, backend="dense",
                             max_batch=8, version_label="prod") as engine:
        with pytest.raises(KeyError):
            CanaryMonitor(engine, baseline="prod", canary="missing")
