"""Validate the trip-count-aware HLO analyzer against XLA's own numbers.

The roofline numbers stand on this parser, so it gets its own ground-truth
check: on a program WITHOUT loops, our dot-FLOPs must match XLA's
``cost_analysis`` flops; on a scanned program, ours must be ~trip-count
times larger (XLA counts while bodies once).
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def _compiled_text(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    return c.as_text(), float(ca.get("flops", 0.0))


def test_matches_xla_on_straightline_matmuls():
    d = 128
    a = jax.ShapeDtypeStruct((8, d), jnp.float32)
    w1 = jax.ShapeDtypeStruct((d, d), jnp.float32)
    w2 = jax.ShapeDtypeStruct((d, d), jnp.float32)

    def fn(a, w1, w2):
        return jnp.tanh(a @ w1) @ w2

    text, xla_flops = _compiled_text(fn, a, w1, w2)
    ours = analyze_hlo(text)
    expected = 2 * 8 * d * d * 2  # two matmuls
    assert ours.dot_flops == pytest.approx(expected, rel=0.01)
    # XLA counts elementwise flops too; dots dominate
    assert ours.dot_flops <= xla_flops <= ours.dot_flops * 1.2


def test_trip_count_multiplies_scan_body():
    d, L = 64, 12
    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)

    def scanned(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    text, xla_flops = _compiled_text(scanned, ws, x)
    ours = analyze_hlo(text)
    per_layer = 2 * 4 * d * d
    assert ours.dot_flops == pytest.approx(L * per_layer, rel=0.05)
    # XLA visits the body once: ~1/L of the true count
    assert xla_flops < ours.dot_flops / (L / 2)
    assert not ours.warnings


def test_nested_scans_compose_trip_counts():
    d, outer, inner = 32, 5, 7
    ws = jax.ShapeDtypeStruct((outer, inner, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((2, d), jnp.float32)

    def fn(ws, x):
        def outer_body(h, w_in):
            def inner_body(g, w):
                return jnp.tanh(g @ w), None
            g, _ = jax.lax.scan(inner_body, h, w_in)
            return g, None
        h, _ = jax.lax.scan(outer_body, x, ws)
        return h

    text, _ = _compiled_text(fn, ws, x)
    ours = analyze_hlo(text)
    expected = outer * inner * 2 * 2 * d * d
    assert ours.dot_flops == pytest.approx(expected, rel=0.05)


def test_bytes_and_contrib_are_positive_and_consistent():
    d = 256
    a = jax.ShapeDtypeStruct((16, d), jnp.float32)
    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    text, _ = _compiled_text(lambda a, w: jax.nn.relu(a @ w), a, w)
    ours = analyze_hlo(text)
    assert ours.bytes_accessed > (16 * d + d * d) * 4  # at least one read
    assert sum(ours.byte_contrib.values()) <= ours.bytes_accessed + 1
