"""Synthetic RadioML generator + Σ-Δ encoder properties."""
from __future__ import annotations

import numpy as np
import pytest
from _hyp import given, st

import jax.numpy as jnp

from repro.core.encoder import (
    normalize_iq,
    sigma_delta_decode,
    sigma_delta_encode,
)
from repro.data.pipeline import sigma_delta_encode_np
from repro.data.radioml import MODULATIONS, generate_batch, generate_sample


def test_generator_shapes_and_labels():
    iq, labels, snrs = generate_batch(seed=0, batch=16, snr_db=None)
    assert iq.shape == (16, 2, 128)
    assert labels.shape == (16,) and labels.min() >= 0
    assert labels.max() < len(MODULATIONS) == 11
    assert np.isfinite(iq).all()
    # SNR range per the dataset spec
    assert all(-20 <= s <= 18 for s in snrs)


def test_generator_deterministic():
    a = generate_batch(seed=7, batch=4, snr_db=10.0)
    b = generate_batch(seed=7, batch=4, snr_db=10.0)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_snr_controls_noise_power():
    """Higher SNR -> the same modulated signal varies less across seeds of
    the channel; proxy: high-SNR batches have lower excess power spread."""
    lo, _, _ = generate_batch(seed=3, batch=64, snr_db=-20.0)
    hi, _, _ = generate_batch(seed=3, batch=64, snr_db=18.0)
    # noise dominates at -20 dB: per-sample power spread is much larger
    p_lo = lo.reshape(64, -1).std(axis=1)
    p_hi = hi.reshape(64, -1).std(axis=1)
    assert p_lo.mean() > p_hi.mean()


def test_every_modulation_generates():
    for m, name in enumerate(MODULATIONS):
        iq = generate_sample(m, name, snr_db=10.0)
        assert iq.shape == (2, 128) and np.isfinite(iq).all(), name


@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8, 16]))
def test_sigma_delta_reconstruction_bound(seed, osr):
    """Decoding the Σ-Δ bitstream recovers the [0,1] input with error
    bounded by the quantization step ~ O(1/osr)."""
    t = np.linspace(0, 4 * np.pi, 128)
    x01 = 0.5 + 0.35 * np.sin(t * (1 + (seed % 3))) * np.cos(0.3 * t)
    bits = sigma_delta_encode(jnp.asarray(x01), osr)
    assert set(np.unique(np.asarray(bits))) <= {0.0, 1.0}
    rec = np.asarray(sigma_delta_decode(bits))
    err = np.abs(rec - x01).mean()
    assert err < 4.0 / osr, (err, osr)


def test_np_and_jax_encoders_agree():
    iq, _, _ = generate_batch(seed=1, batch=2, snr_db=10.0)
    a = sigma_delta_encode_np(iq, 8)
    b = np.asarray(sigma_delta_encode(normalize_iq(jnp.asarray(iq)), 8))
    # same shape contract: (B, T, 2, 128)
    assert a.shape == (2, 8, 2, 128)
    assert set(np.unique(a)) <= {0.0, 1.0}
    assert b.shape[-1] == 128 or b.shape[1] == 8


# ---------------------------------------------------------------------------
# SpikeBatchPipeline shutdown semantics
# ---------------------------------------------------------------------------

def test_pipeline_yields_batches_then_stops_after_close():
    """Regression: ``__next__`` used to block forever on the empty queue
    once ``close()`` had stopped the producer; it must raise
    ``StopIteration`` instead."""
    import threading

    from repro.data.pipeline import SpikeBatchPipeline

    pipe = SpikeBatchPipeline(batch_size=4, osr=3, prefetch=2)
    frames, labels, snrs = next(pipe)
    assert frames.shape == (4, 3, 2, 128) and labels.shape == (4,)
    pipe.close()

    outcome = {}

    def consume():
        try:
            while True:
                next(pipe)
        except StopIteration:
            outcome["stopped"] = True

    t = threading.Thread(target=consume, daemon=True)
    t.start()
    t.join(timeout=5.0)
    assert outcome.get("stopped"), "consumer hung after close()"
    # the stream stays ended for any later consumer too
    with pytest.raises(StopIteration):
        next(pipe)
