"""The paper's own model: 5-layer SNN AMC classifier (Fig. 7 / Table II)."""
from repro.models.snn import SNNConfig

CONFIG = SNNConfig()  # paper defaults: (11,2,16)(11,16,32)(5,32,64) + FCs

# Table V layer-wise density configurations
DENSITY_CONFIGS = {
    "saocds-100": 1.00,
    "saocds-75": 0.75,
    "saocds-50": 0.50,
    "saocds-25": 0.25,
    "saocds-20": 0.20,
    "saocds-15": 0.15,
    "saocds-10": 0.10,
    "saocds-5": 0.05,
    "saocds-25-20-15-20-25": {
        "conv1": 0.25, "conv2": 0.20, "conv3": 0.15, "fc1": 0.20, "fc2": 0.25
    },
    "saocds-20-15-10-15-20": {
        "conv1": 0.20, "conv2": 0.15, "conv3": 0.10, "fc1": 0.15, "fc2": 0.20
    },
}
