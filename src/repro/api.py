"""Public facade: one model definition, four interchangeable backends.

Everything an application needs to train, compress, and serve the paper's
SNN AMC classifier through the unified layer-graph API:

    from repro.api import SNNConfig, compile_snn, init_snn

    cfg = SNNConfig()
    program = compile_snn(cfg)                    # LayerSpec graph, compiled once
    params = init_snn(jax.random.PRNGKey(0), cfg)

    logits = program.apply(params, frames)                     # dense oracle
    logits = program.apply(params, frames, backend="goap")     # COO streaming
    logits = program.apply(params, frames, backend="pallas")   # TPU block-sparse
    logits, counters = program.apply(params, frames, backend="stream",
                                     return_counters=True)     # Tables I/III

With concrete weights, the plan compiler precomputes every bind-time
artifact once (content-hashed, disk-cached) and fuses all layers into a
single scan over timesteps — the paper's control-free inter-layer
pipeline — with optional per-layer backend assignment:

    from repro.api import compile_plan

    plan = compile_plan(program, params, masks=masks,
                        assignment={"conv1": "pallas", "fc1": "dense"},
                        default_backend="goap")
    logits, counters = plan.run_streaming(frames)   # fused single scan
    preds = plan.batch(frames_b)

New execution strategies plug in via ``register_backend`` without touching
the model definition.

The fixed-point tier serves genuinely integer inference bit-identical to
the FPGA datapath's golden interpreter:

    from repro.api import FixedQuantFn, build_golden

    plan = compile_plan(program, params, masks=masks,
                        quant_fn=FixedQuantFn(lsq_scales, bits=16),
                        assignment="fixed")
    int_logits = plan.bound.batch(fixed_encode_batch(iq, cfg.timesteps))
    golden = build_golden(cfg, params, masks=masks,
                          quant_fn=FixedQuantFn(lsq_scales, bits=16))
    assert (np.asarray(int_logits) ==
            np.stack([golden.forward_iq(f) for f in iq])).all()
"""
from __future__ import annotations

from repro.models.graph import (
    BoundProgram,
    Conv1dLIF,
    FCLIF,
    LayerCell,
    LayerSpec,
    MaxPool,
    Readout,
    SNNProgram,
    available_backends,
    build_layer_graph,
    compile_snn,
    get_backend,
    register_backend,
    stream_totals,
)
from repro.plan import (
    ExecutionPlan,
    PlanCache,
    compile_plan,
    run_streaming,
)
from repro.models.snn import (
    SNNConfig,
    density_report,
    init_snn,
    param_count,
    sparsify_params,
)
from repro.channel import (
    SCENARIOS,
    ChannelScenario,
    apply_scenario,
    make_frame_source,
)
from repro.eval import RobustnessConfig, evaluate_robustness
from repro.fixed import (
    FixedQuantFn,
    build_golden,
    fixed_encode_batch,
    fixed_logit_scale,
    quantize_codes,
)

__all__ = [
    # graph / program
    "LayerSpec",
    "LayerCell",
    "Conv1dLIF",
    "MaxPool",
    "FCLIF",
    "Readout",
    "build_layer_graph",
    "SNNProgram",
    "BoundProgram",
    "compile_snn",
    # plan compiler / fused streaming executor
    "ExecutionPlan",
    "PlanCache",
    "compile_plan",
    "run_streaming",
    # backend registry
    "register_backend",
    "available_backends",
    "get_backend",
    "stream_totals",
    # model definition / params
    "SNNConfig",
    "init_snn",
    "sparsify_params",
    "param_count",
    "density_report",
    # channel scenarios / robustness evaluation
    "ChannelScenario",
    "SCENARIOS",
    "apply_scenario",
    "make_frame_source",
    "RobustnessConfig",
    "evaluate_robustness",
    # fixed-point hardware-parity tier
    "FixedQuantFn",
    "build_golden",
    "fixed_encode_batch",
    "fixed_logit_scale",
    "quantize_codes",
]
