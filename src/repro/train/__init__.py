"""Training substrate: optimizers, pruning, LSQ quantization, checkpointing,
and the surrogate-gradient BPTT trainer."""

from .optimizer import adamw, sgd, clip_by_global_norm, apply_updates
from .pruning import (
    target_density_at,
    magnitude_masks,
    make_mask_pytree,
    mask_density,
)
from .lsq import lsq_fake_quant, init_lsq_scales, quantize_to_int, dequantize
from .checkpoint import CheckpointManager
from .trainer import SNNTrainer, TrainerConfig
