"""Gated one-to-all product (GOAP) convolution (paper §III-C).

Convention (matches paper Fig. 3): the input feature map is **pre-padded**,
I: (IC, WI) binary; the kernel is (KW, IC, OC); valid convolution gives
O: (OC, OI) with OI = WI - KW + 1, stride 1 (the paper's RF signals are 1-D,
H = 1 everywhere).

Three implementations, all equal to the dense oracle:

* ``conv1d_dense_oracle``  — im2col matmul, the mathematical ground truth
  and the sliding-window (SW) baseline compute.
* ``goap_conv_nnz``        — vectorized weight-priority iteration: every
  non-zero weight w@(oc, ic, ci) contributes ``w * I[ic, ci:ci+OI]`` to
  output row oc (its *enable map*); gathered + segment-summed, jittable.
* ``goap_conv_reference``  — literal Algorithm-1 numpy loop (tests only).

``build_shift_buffer`` produces the binary shifted-input matrix
X'(IC*KW, OI) with X'[ic*KW + ci, oi] = I[ic, oi + ci]; dense conv is then
``W'(OC, IC*KW) @ X'`` which is the layout the TPU block-sparse kernel uses.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .sparse_format import CooKernel

__all__ = [
    "conv1d_dense_oracle",
    "build_shift_buffer",
    "goap_conv_nnz",
    "goap_conv_reference",
]


def build_shift_buffer(ifm: jax.Array, kw: int) -> jax.Array:
    """(IC, WI) -> X'(IC*KW, OI): row ic*KW+ci holds I[ic] shifted by ci."""
    ic, wi = ifm.shape
    oi = wi - kw + 1
    if oi <= 0:
        raise ValueError(f"input width {wi} < kernel width {kw}")
    # windows[ci, oi] = I[:, oi + ci]
    idx = jnp.arange(kw)[:, None] + jnp.arange(oi)[None, :]  # (KW, OI)
    shifted = ifm[:, idx]  # (IC, KW, OI)
    return shifted.reshape(ic * kw, oi)


def conv1d_dense_oracle(ifm: jax.Array, kernel: jax.Array) -> jax.Array:
    """Dense valid 1-D conv: (IC, WI) x (KW, IC, OC) -> (OC, OI)."""
    kw, ic, oc = kernel.shape
    x = build_shift_buffer(ifm, kw)                     # (IC*KW, OI)
    w = jnp.transpose(kernel, (2, 1, 0)).reshape(oc, ic * kw)  # W'
    return w @ x.astype(w.dtype)


def goap_conv_nnz(ifm: jax.Array, coo: CooKernel) -> jax.Array:
    """Vectorized GOAP: iterate non-zero weights, accumulate enable maps.

    Faithful to the paper's dataflow: for each nnz weight, fetch its EM
    (OI contiguous binary inputs starting at its kernel column) and add
    ``w * EM`` into output row oc.  Gating by the binary input is the
    multiplication by {0,1}.
    """
    kw = coo.kw
    icn = coo.ic
    _, wi = ifm.shape
    oi = wi - kw + 1
    if coo.nnz == 0:
        return jnp.zeros((coo.oc, oi), dtype=jnp.result_type(jnp.float32))

    w = jnp.asarray(coo.data, dtype=jnp.float32)        # (nnz,)
    oc_idx = jnp.asarray(coo.row_idx // icn)            # (nnz,)
    ic_idx = jnp.asarray(coo.row_idx % icn)             # (nnz,)
    ci_idx = jnp.asarray(coo.col_idx)                   # (nnz,)

    # EM gather: ems[n, oi] = I[ic_n, oi + ci_n]
    cols = ci_idx[:, None] + jnp.arange(oi)[None, :]    # (nnz, OI)
    ems = ifm[ic_idx[:, None], cols].astype(jnp.float32)
    contrib = w[:, None] * ems                          # (nnz, OI)
    return jax.ops.segment_sum(contrib, oc_idx, num_segments=coo.oc)


def goap_conv_reference(ifm: np.ndarray, coo: CooKernel) -> np.ndarray:
    """Literal Algorithm-1 loop (numpy; tests/small shapes only)."""
    icn, wi = ifm.shape
    oi = wi - coo.kw + 1
    out = np.zeros((coo.oc, oi), dtype=np.float64)
    for n in range(coo.nnz):
        oc = int(coo.row_idx[n]) // icn
        ic = int(coo.row_idx[n]) % icn
        ci = int(coo.col_idx[n])
        w = float(coo.data[n])
        for o in range(oi):              # enable-map iteration
            if ifm[ic, o + ci] != 0:     # temporal-sparsity gate
                out[oc, o] += w
    return out
