"""Optional-``hypothesis`` shim so the tier-1 suite runs in minimal envs.

Test modules import ``given`` / ``settings`` / ``st`` from here instead of
from ``hypothesis`` directly.  When hypothesis is installed the real
objects are re-exported; when it is absent, ``given`` turns each property
test into a cleanly skipped test and ``st``/``settings`` become inert
stand-ins so module-level strategy definitions still evaluate.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Absorbs any attribute access / call used to build strategies."""

        def __getattr__(self, name):
            return _Strategy()

        def __call__(self, *args, **kwargs):
            return _Strategy()

    st = _Strategy()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco

    class _Settings:
        """Stands in for both ``@settings(...)`` and the profile API."""

        def __call__(self, *args, **kwargs):
            return lambda fn: fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    settings = _Settings()
